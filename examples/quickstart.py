"""Quickstart: the paper's technique in ~60 lines.

1. Build a small relational database (university schema: students, courses,
   profs, Registered/RA relationships) with planted dependencies.
2. Run statistical-relational model discovery with the HYBRID counts cache
   (the paper's contribution): positive ct-tables are pre-counted per
   relationship-chain lattice point, negation is post-counted per family via
   the Möbius join.
3. Print the learned first-order Bayesian network and the counting stats.

Under the hood every hill-climbing round fetches its family tables through
the counting service (`repro/serve/`): the round's positive contractions
are bucketed by plan signature and executed as stacked/vmapped batches.
To drive that layer directly — many clients flooding one shared counting
cache — see ``examples/serve_counting.py``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.database import paper_benchmark_db
from repro.core.search import discover_model
from repro.core.strategies import make_strategy


def main():
    db = paper_benchmark_db("UW", seed=0)          # 712-row university DB
    print(f"database: UW-like, {db.total_rows} rows, "
          f"{len(db.relations)} relationships")

    strategy = make_strategy("HYBRID")
    models, strategy = discover_model(db, strategy,
                                      max_chain_length=2, max_parents=2)

    print("\nlearned first-order Bayesian networks (per lattice point):")
    for point, model in models.items():
        rels = ",".join(sorted(point.rels))
        print(f"  lattice point [{rels}]  score={model.score:.1f}")
        for parent, child in model.edges():
            print(f"    {parent} -> {child}")

    st = strategy.stats.as_dict()
    print("\ncounting stats (the paper's metrics):")
    print(f"  table JOIN sweeps      : {st['joins']}")
    print(f"  edge rows scanned      : {st['rows_scanned']}")
    print(f"  positive-ct time       : {st['time_positive']:.2f}s  (pre-counted)")
    print(f"  negative-ct time       : {st['time_negative']:.2f}s  (Möbius, post-counted)")
    print(f"  peak ct-cache bytes    : {st['peak_bytes']:,}")


if __name__ == "__main__":
    main()
