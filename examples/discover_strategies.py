"""Compare PRECOUNT / ONDEMAND / HYBRID end-to-end on a paper database.

Full model discovery (lattice construction, strategy pre-phase, bottom-up
hill-climbing with BDeu) is run once per strategy; all three must find the
same model (counting strategy changes *cost*, never *counts* — asserted
here), while time/memory differ as in the paper's Figs. 3-4.

Run:  PYTHONPATH=src python examples/discover_strategies.py [dataset] [scale]
      dataset in {UW, Mondial, Hepatitis, Mutagenesis, MovieLens, Financial,
                  IMDb, VisualGenome}; default UW at full scale.
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.database import paper_benchmark_db
from repro.core.search import discover_model
from repro.core.strategies import make_strategy


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "UW"
    scale = float(sys.argv[2]) if len(sys.argv) > 2 else 1.0
    db = paper_benchmark_db(name, seed=0, scale=scale)
    print(f"database: {name} (scale {scale}), {db.total_rows} rows")

    results = {}
    for sname in ("PRECOUNT", "ONDEMAND", "HYBRID", "TUPLEID"):
        t0 = time.perf_counter()
        models, strat = discover_model(db, make_strategy(sname),
                                       max_chain_length=2, max_parents=2)
        wall = time.perf_counter() - t0
        st = strat.stats.as_dict()
        edge_sets = {p: frozenset(m.edges()) for p, m in models.items()}
        total = sum(m.score for m in models.values())
        results[sname] = (edge_sets, total)
        print(f"{sname:9s} wall={wall:7.2f}s  "
              f"meta={st['time_metadata']:5.2f} pos={st['time_positive']:6.2f} "
              f"neg={st['time_negative']:6.2f}  joins={st['joins']:4d}  "
              f"peakMB={st['peak_bytes'] / 1e6:8.2f}  score={total:.1f}",
              flush=True)

    # counting strategy must not change the discovered model
    ref_edges, ref_score = results["PRECOUNT"]
    for sname, (edges, score) in results.items():
        assert edges == ref_edges, f"{sname} found a different model!"
        assert abs(score - ref_score) < 1e-3 * max(1.0, abs(ref_score))
    print("\nall four strategies discovered the SAME model "
          "(same edges, same score) — only cost differs.")


if __name__ == "__main__":
    main()
