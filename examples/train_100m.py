"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the local mesh, with the full substrate — sharded parameters,
microbatch gradient accumulation, AdamW with warmup+cosine, deterministic
sharded data pipeline with prefetch, and atomic checkpoint/resume.

Fault tolerance demo: the run checkpoints every ``--ckpt-every`` steps; kill
it at any point and re-run with the same command — it resumes from the last
checkpoint (the data pipeline is keyed by step, so the token stream continues
exactly where it left off).

Run:   PYTHONPATH=src python examples/train_100m.py --steps 300
Quick: PYTHONPATH=src python examples/train_100m.py --steps 30 --tiny
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.models.config import ModelConfig


def config_100m() -> ModelConfig:
    # ~110M params: granite/llama-style dense decoder
    return ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768,
        block="attn", mlp="swiglu", rope="rope",
        attn_chunk=256, remat=False, scan_layers=True)


def config_tiny() -> ModelConfig:
    return config_100m().replace(name="demo-tiny", n_layers=2, d_model=128,
                                 n_heads=4, n_kv_heads=2, d_ff=512,
                                 vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="2-layer stand-in for a fast smoke run")
    args = ap.parse_args()

    cfg = config_tiny() if args.tiny else config_100m()
    print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.1f}M params")

    # reuse the production launcher end-to-end (this is the public API)
    from repro.configs import register_config
    from repro.launch import train as train_launcher
    register_config(cfg.name, cfg)
    losses = train_launcher.run([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--microbatch", str(args.microbatch),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", str(args.ckpt_every),
        "--resume",
    ])
    if losses:
        k = max(1, len(losses) // 10)
        first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
        print(f"\nloss: first-{k}-avg {first:.3f} -> last-{k}-avg {last:.3f}")
        assert last < first, "loss did not decrease"
        print("training makes progress — loss decreased.")


if __name__ == "__main__":
    main()
