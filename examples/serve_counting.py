"""Serve counting queries to concurrent clients: a CountingService flood
demo (the counting-engine analogue of ``examples/serve_batched.py`` for
models).

Several client threads flood one :class:`~repro.serve.service
.CountingService` with positive-count queries over a schema whose
relationships share one shape — the service coalesces duplicate
in-flight queries, short-circuits cache residents, buckets the rest by
plan signature, and executes each bucket as a single stacked/vmapped
contraction against the shared byte-budgeted ct-cache.  For comparison
the same query stream is first answered per-query through the bare
executor.

Run:  PYTHONPATH=src python examples/serve_counting.py [n_clients] [n_rels]
      default: 4 clients x 24 queries each, 8 relationships, sparse backend.
"""

import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        CostStats, CountingEngine, build_lattice, synth_db)
from repro.serve import CountingService


def flood_schema(n_rels: int) -> Schema:
    att = lambda n: Attribute(n, 3)
    ents = (EntityType("item", 500, (att("a0"), att("a1"))),
            EntityType("tag", 300, (att("b0"),)))
    rels = tuple(Relationship(f"Rel{i}", "item", "tag", (att(f"e{i}"),))
                 for i in range(n_rels))
    return Schema(ents, rels)


def main():
    n_clients = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    n_rels = int(sys.argv[2]) if len(sys.argv) > 2 else 8
    per_client = 60

    schema = flood_schema(n_rels)
    db = synth_db(schema, {f"Rel{i}": 3000 for i in range(n_rels)}, seed=0)
    points = build_lattice(schema, 1)
    print(f"database: {db.total_rows} rows, {n_rels} same-shape "
          f"relationships -> {len(points)} distinct count queries")

    # ---- baseline: per-query dispatch, no batching ----------------------
    eng = CountingEngine(db, "sparse", CostStats())
    rng = np.random.default_rng(0)
    stream = [points[i] for i in
              rng.integers(len(points), size=n_clients * per_client)]
    t0 = time.perf_counter()
    for p in stream:
        eng.executor.positive(db, eng.plan(p, None))
    t_pq = time.perf_counter() - t0
    print(f"per-query : {len(stream)} queries in {t_pq*1e3:7.0f} ms "
          f"({len(stream)/t_pq:7.0f} q/s)")

    # ---- service: concurrent clients, micro-batched ---------------------
    eng = CountingEngine(db, "sparse", CostStats(),
                         cache_budget_bytes=64 << 20)
    svc = CountingService(eng, max_batch_size=n_rels)
    # warm the stacked evaluator (a long-running service compiles once,
    # then serves); drop the warmed tables so clients do real work
    for burst in (points, points[:4], points[:2], points[:1]):
        svc.count_many([(p, None) for p in burst])
        eng.cache.evict_all()
    svc.metrics = type(svc.metrics)()

    def client(cid: int):
        crng = np.random.default_rng(cid)
        for _ in range(per_client // 6):
            # submit a burst of tickets, then resolve them — bursts from
            # concurrent clients land in one signature bucket
            tickets = [svc.submit(points[int(crng.integers(len(points)))])
                       for _ in range(6)]
            for t in tickets:
                t.result()

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t_svc = time.perf_counter() - t0
    n = n_clients * per_client
    print(f"service   : {n} queries in {t_svc*1e3:7.0f} ms "
          f"({n/t_svc:7.0f} q/s) from {n_clients} client threads")

    snap = svc.stats()
    print("\nservice health:")
    print(f"  requests / cache hits / coalesced : "
          f"{snap['requests']} / {snap['cache_hits']} / {snap['coalesced']}")
    print(f"  batches x mean size               : {snap['batches']} x "
          f"{snap['batched_queries'] / max(snap['batches'], 1):.1f}")
    print(f"  bucket exec throughput            : {snap['qps']:.0f} q/s")
    print(f"  ct-cache                          : {snap['cache']}")
    print("OK — counting service flood works end-to-end.")


if __name__ == "__main__":
    main()
