"""Serve a small model with batched requests: prefill + decode loop.

A toy continuous-batching server core: a batch of prompts is prefilled once
(building the KV cache), then tokens are decoded step-by-step with greedy
sampling against the preallocated, fixed-shape cache — the same
``prefill`` / ``decode_step`` code paths the 512-chip dry-run lowers, here on
the local CPU mesh with a reduced config.

Run:  PYTHONPATH=src python examples/serve_batched.py [arch] [n_new_tokens]
      default: qwen2.5-3b (reduced), 24 new tokens, batch of 4 requests.
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.train.step import make_decode_step, make_prefill_step


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2.5-3b"
    n_new = int(sys.argv[2]) if len(sys.argv) > 2 else 24
    cfg = get_reduced(arch)
    if cfg.enc_dec or cfg.embeds_input:
        print(f"{arch} needs a frontend stub; use a decoder-only arch")
        return
    model = build_model(cfg)
    mesh = make_local_mesh()

    batch_size, prompt_len, max_len = 4, 16, 16 + n_new
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (batch_size, prompt_len),
                           dtype=np.int32)

    with jax.sharding.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))

        # ---- prefill all requests at once -------------------------------
        prefill = jax.jit(make_prefill_step(model))
        t0 = time.perf_counter()
        logits, prompt_cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0
        print(f"prefill: {batch_size} requests x {prompt_len} tokens "
              f"in {t_prefill * 1e3:.0f} ms")

        # ---- copy the prompt KV into the preallocated max-length cache --
        cache = model.init_cache(batch_size, max_len)
        for k in ("k", "v"):
            if k in cache:
                cache[k] = jax.lax.dynamic_update_slice(
                    cache[k], prompt_cache[k].astype(cache[k].dtype),
                    (0,) * cache[k].ndim)
        for k in prompt_cache:
            if k not in ("k", "v"):
                cache[k] = prompt_cache[k]

        # ---- decode loop (greedy) ---------------------------------------
        decode = jax.jit(make_decode_step(model, mesh=mesh, seq_sharded=False),
                         donate_argnums=(1,))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        for i in range(n_new - 1):
            pos = jnp.asarray(prompt_len + i, jnp.int32)
            logits, cache = decode(params, cache, {"token": tok, "pos": pos})
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        toks_per_s = batch_size * (n_new - 1) / t_decode
        print(f"decode: {n_new - 1} steps x {batch_size} requests in "
              f"{t_decode * 1e3:.0f} ms  ({toks_per_s:.0f} tok/s batched)")

    gen = np.concatenate(out, axis=1)
    for b in range(batch_size):
        print(f"request {b}: prompt={prompts[b, :6].tolist()}... "
              f"generated={gen[b, :10].tolist()}...")
    assert gen.shape == (batch_size, n_new)
    print("OK — batched serving path works end-to-end.")


if __name__ == "__main__":
    main()
