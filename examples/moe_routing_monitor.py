"""The paper's technique INSIDE the training framework: monitor MoE routing
with hybrid count-caching.

A probe batch is traced through a (reduced) qwen3-MoE model; each layer's
top-k assignments become a relational database (tokens x experts with a
``Routed`` relationship), and the HYBRID strategy answers contingency
questions — including *negative* relationships ("expert e did NOT see bucket
b tokens"), which is the paper's negation problem solved by the Möbius join
with zero extra passes over the trace.

Run:  PYTHONPATH=src python examples/moe_routing_monitor.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.model import build_model
from repro.train.monitor import routing_ct, routing_db, routing_trace


def main():
    cfg = get_reduced("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    b, s = 4, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    trace = routing_trace(model, params, {"tokens": tokens})
    print(f"model: {cfg.name} ({cfg.n_experts} experts, top-{cfg.top_k}); "
          f"trace shape {trace.shape}  [L, B, S, K]")

    buckets = (tokens % 4).astype(jnp.int32)       # token-id buckets
    for layer in (0, cfg.n_layers - 1):
        db = routing_db(trace[layer], buckets, cfg.n_experts)
        tab, stats = routing_ct(db)
        print(f"\nlayer {layer}: Routed(token, expert) — "
              f"{db.relations['Routed'].num_edges} edges")
        print(f"  complete ct-table axes: "
              f"{[str(v) for v in tab.vars]}  shape {tab.counts.shape}")
        print(f"  routed pairs {stats['routed_pairs']:.0f} / "
              f"possible {stats['pairs_total']:.0f} "
              f"(fraction {stats['routed_fraction']:.4f}) — "
              f"negative counts from the Möbius join, "
              f"{stats['joins']} JOIN sweep(s)")
    print("\nOK — hybrid count-caching is serving the training loop.")


if __name__ == "__main__":
    main()
