"""Counting-strategy benchmarks — one function per paper table/figure.

The paper's experiment (Figs. 3-4, Table 5) measures ct-table construction
inside a FACTORBASE structure-learning run, per caching strategy.  The search
loop itself is strategy-independent (the same family stream is scored), so we
benchmark each strategy against a *fixed, deterministic family workload*:
``prepare()`` (the pre-search phase) followed by ``family_ct`` + BDeu for an
enumerated set of (child, parents) families per lattice point.  This isolates
exactly the quantity the paper reports — ct construction time — without the
hill-climb's move-evaluation noise.

Each (dataset x strategy) run yields all three artefacts at once:
  * fig3_runtime  — time decomposition metadata / positive ct / negative ct
  * fig4_memory   — peak cache footprint (resident ct bytes)
  * table5_sizes  — summed family-ct rows vs the global PRECOUNT ct rows

plus the serve-layer dimensions:
  * service_flood — same-signature query flood, per-query executor
    dispatch vs the CountingService's signature-bucketed stacked path
    (the serve subsystem's headline speedup).
  * negative_flood — same-signature COMPLETE-CT flood (positive + Möbius
    negative phase): per-family ``complete_ct`` dispatch vs the
    service's fully batched complete path (stacked positives + one
    butterfly transform per shape group).
  * sharded_flood (``--shards``) — the same flood against a horizontally
    hash-partitioned database behind the CountingRouter (one service per
    shard, counts merged at the front-end) vs the single-database
    service, sparse executor on both sides.
  * tenant_flood — a multi-tenant fleet (N logical databases behind one
    TenantRegistry, tiered GREEN/YELLOW/RED workloads): per-tenant
    serial dispatch vs cross-tenant batched dispatch (same-shape plans
    from different tenants stacked into one jit).
  * mutation_flood — an insert-heavy write flood against warmed caches:
    delta count maintenance (fine-grained invalidation + in-place
    updates over just the delta edges) vs recount-from-scratch (the
    pre-mutations freshness model: every write flushes the cache and the
    next read re-contracts from raw data).

Output layout: ``results/bench/counting.json`` is the ONE canonical
artifact (runs, paper views, flood records, and the ``trajectory``
section).  ``BENCH_counting.json`` at the repo root is *derived* from the
trajectory section — new rows are appended to whatever is already
recorded there, so the file accumulates the cross-PR perf trajectory.
"""

from __future__ import annotations

import itertools
import json
import statistics
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.bdeu import family_score
from repro.core.contract import CostStats
from repro.core.database import (PAPER_DATASETS, RelationalDB,
                                 paper_benchmark_db, synth_db)
from repro.core.engine import CountingEngine
from repro.core.schema import Attribute, EntityType, Relationship, Schema
from repro.core.strategies import STRATEGIES, make_strategy
from repro.core.variables import build_lattice

# Per-dataset scale factors: keep CPU wall-time per (dataset x strategy) run
# in the tens of seconds while preserving the paper's size *ordering*
# (UW < ... < VisualGenome).  --scale multiplies these.
DEFAULT_SCALES: Dict[str, float] = {
    "UW": 1.0, "Mondial": 1.0, "Mutagenesis": 1.0, "Hepatitis": 1.0,
    "MovieLens": 1.0, "Financial": 0.4, "IMDb": 0.15, "VisualGenome": 0.05,
}
# ONDEMAND re-runs the JOINs per family; the paper reports it timing out on
# the two largest databases.  We enforce the same behaviour with a soft
# per-run budget (seconds) checked between families.
TIME_BUDGET_S = 300.0


def family_workload(db: RelationalDB, lattice, max_parents: int = 3,
                    per_point: int = 400) -> List[Tuple]:
    """Deterministic stream of (point, keep) families, mimicking what
    hill-climbing generates: every child with parent sets of size 0..k,
    round-robin over children, capped per lattice point.  The cap is sized
    so each point sees a realistic search stream (hundreds of families) —
    this is what makes ONDEMAND re-run its JOINs, as in the paper."""
    out: List[Tuple] = []
    for point in lattice:
        nodes = list(point.all_ct_vars(db.schema, include_rind=True))
        fams = []
        for child in nodes:
            others = [v for v in nodes if v != child]
            for k in range(0, max_parents + 1):
                for parents in itertools.combinations(others[:7], k):
                    fams.append((point, tuple(sorted(parents)) + (child,)))
        # interleave children so truncation keeps diversity
        fams.sort(key=lambda f: (len(f[1]), str(f[1][-1])))
        out.extend(fams[:per_point])
    return out


@dataclass
class RunRecord:
    dataset: str
    strategy: str
    executor: str
    rows: int
    families: int
    completed: bool
    wall_s: float
    time_metadata: float
    time_positive: float
    time_negative: float
    joins: int
    rows_scanned: int
    peak_bytes: int
    ct_rows: int

    def as_dict(self) -> dict:
        return self.__dict__.copy()


def run_one(name: str, strategy_name: str, scale: Optional[float] = None,
            budget_s: float = TIME_BUDGET_S, seed: int = 0,
            use_kernel_mobius: bool = False, executor: str = "dense",
            cache_budget_bytes: Optional[int] = None) -> RunRecord:
    scale = DEFAULT_SCALES[name] if scale is None else scale
    db = paper_benchmark_db(name, seed=seed, scale=scale)
    lattice = build_lattice(db.schema, max_length=2)
    work = family_workload(db, lattice)

    kw = {"executor": executor, "cache_budget_bytes": cache_budget_bytes}
    if use_kernel_mobius:
        from repro.kernels.ops import mobius_nd
        kw["mobius_fn"] = mobius_nd
    strat = make_strategy(strategy_name, **kw)

    t0 = time.perf_counter()
    completed = True
    strat.prepare(db, lattice)
    done = 0
    for point, keep in work:
        if time.perf_counter() - t0 > budget_s:
            completed = False            # the paper's "exceeded runtime limit"
            break
        tab = strat.family_ct(point, keep)
        family_score(tab, keep[-1])
        done += 1
    wall = time.perf_counter() - t0
    st = strat.stats
    return RunRecord(
        dataset=name, strategy=strategy_name, executor=executor,
        rows=db.total_rows,
        families=done, completed=completed, wall_s=round(wall, 2),
        time_metadata=round(st.time_metadata, 3),
        time_positive=round(st.time_positive, 3),
        time_negative=round(st.time_negative, 3),
        joins=st.joins, rows_scanned=st.rows_scanned,
        peak_bytes=st.peak_bytes, ct_rows=st.ct_rows)


def run_all(datasets: Sequence[str] = PAPER_DATASETS,
            strategies: Sequence[str] = ("PRECOUNT", "ONDEMAND", "HYBRID"),
            scale: Optional[float] = None,
            budget_s: float = TIME_BUDGET_S,
            executors: Sequence[str] = ("dense", "sparse"),
            cache_budget_bytes: Optional[int] = None) -> List[RunRecord]:
    recs = []
    for name in datasets:
        for s in strategies:
            for ex in executors:
                r = run_one(name, s, scale=scale, budget_s=budget_s,
                            executor=ex,
                            cache_budget_bytes=cache_budget_bytes)
                flag = "" if r.completed else "  [TIMEOUT]"
                print(f"[counting] {name:13s} {s:9s} {ex:6s} "
                      f"wall={r.wall_s:7.2f}s "
                      f"meta={r.time_metadata:6.2f} pos={r.time_positive:6.2f} "
                      f"neg={r.time_negative:6.2f} joins={r.joins:5d} "
                      f"peakMB={r.peak_bytes / 1e6:9.2f}{flag}", flush=True)
                recs.append(r)
    return recs


# ------------------------------------------------------------- paper views --

def fig3_runtime(recs: List[RunRecord]) -> List[dict]:
    """Fig. 3: stacked time decomposition per (dataset, strategy, executor)."""
    return [{"dataset": r.dataset, "strategy": r.strategy,
             "executor": r.executor,
             "metadata_s": r.time_metadata, "positive_s": r.time_positive,
             "negative_s": r.time_negative,
             "total_s": round(r.time_metadata + r.time_positive
                              + r.time_negative, 3),
             "completed": r.completed} for r in recs]


def fig4_memory(recs: List[RunRecord]) -> List[dict]:
    """Fig. 4: peak resident ct-cache bytes per (dataset, strategy,
    executor)."""
    return [{"dataset": r.dataset, "strategy": r.strategy,
             "executor": r.executor,
             "peak_mb": round(r.peak_bytes / 1e6, 3)} for r in recs]


def table5_sizes(recs: List[RunRecord]) -> List[dict]:
    """Table 5: summed family-ct rows (ONDEMAND/HYBRID) vs global-ct rows
    (PRECOUNT) per dataset (first executor seen; ct sizes are
    backend-invariant)."""
    by = {}
    for r in recs:
        by.setdefault((r.dataset, r.strategy), r)
    out = []
    for name in dict.fromkeys(r.dataset for r in recs):
        row = {"dataset": name}
        h = by.get((name, "HYBRID"))
        p = by.get((name, "PRECOUNT"))
        if h:
            row["ct_family_rows"] = h.ct_rows
        if p:
            row["ct_database_rows"] = p.ct_rows
        out.append(row)
    return out


def bench_trajectory(recs: List[RunRecord]) -> List[dict]:
    """The cross-PR perf trajectory: (strategy × dataset × executor) →
    wall time / peak bytes / ct rows.  Written to BENCH_counting.json."""
    return [{"dataset": r.dataset, "strategy": r.strategy,
             "executor": r.executor, "wall_s": r.wall_s,
             "peak_bytes": r.peak_bytes, "ct_rows": r.ct_rows,
             "completed": r.completed} for r in recs]


# ------------------------------------------------------- serve dimension --

def _flood_db(n_rels: int, edges: int, seed: int = 0) -> RelationalDB:
    """``n_rels`` identically-shaped relationships: every single-atom
    lattice point compiles to a stack-compatible plan — the ideal
    same-signature flood (symmetric schemas like VisualGenome's predicate
    sets are the realistic analogue)."""
    att = lambda n, c=3: Attribute(n, c)
    ents = (EntityType("fa", 400, (att("a0"), att("a1"))),
            EntityType("fb", 300, (att("b0"),)))
    rels = tuple(Relationship(f"F{i}", "fa", "fb", (att(f"e{i}"),))
                 for i in range(n_rels))
    schema = Schema(ents, rels)
    return synth_db(schema, {f"F{i}": edges for i in range(n_rels)},
                    seed=seed)


def bench_service_flood(n_rels: int = 16, edges: int = 2000,
                        rounds: int = 5,
                        executors: Sequence[str] = ("dense", "sparse"),
                        seed: int = 0) -> List[dict]:
    """Same-signature query flood: per-query executor dispatch vs the
    counting service's signature-bucketed stacked execution.

    Each round answers the same ``n_rels`` distinct positive queries cold
    (the ct-cache is cleared between rounds, so every round re-executes);
    the batched path keeps its jitted vmapped evaluator across rounds the
    way a long-running service would.  Reports queries/s per mode and the
    batched-over-per-query speedup.
    """
    from repro.serve import CountingService

    db = _flood_db(n_rels, edges, seed=seed)
    lattice = build_lattice(db.schema, 1)
    config = f"flood{n_rels}x{edges}r{rounds}"
    out: List[dict] = []
    for ex in executors:
        eng = CountingEngine(db, ex, CostStats())
        plans = [eng.plan(p, None) for p in lattice]
        n_queries = rounds * len(plans)

        # ---- per-query dispatch (warm one round, then timed) -------------
        jax.block_until_ready([eng.executor.positive(db, p).counts
                               for p in plans])
        t0 = time.perf_counter()
        for _ in range(rounds):
            jax.block_until_ready([eng.executor.positive(db, p).counts
                                   for p in plans])
        wall_pq = time.perf_counter() - t0
        qps_pq = n_queries / wall_pq

        # ---- service-batched (same engine; cold cache every round) -------
        svc = CountingService(eng, max_batch_size=max(n_rels, 1))
        queries = [(p, None) for p in lattice]
        eng.cache.evict_all()
        jax.block_until_ready([t.counts for t in svc.count_many(queries)])
        t0 = time.perf_counter()
        for _ in range(rounds):
            eng.cache.evict_all()
            jax.block_until_ready([t.counts
                                   for t in svc.count_many(queries)])
        wall_b = time.perf_counter() - t0
        qps_b = n_queries / wall_b

        speedup = qps_b / qps_pq if qps_pq > 0 else float("inf")
        print(f"[flood] {config} {ex:6s} per_query={qps_pq:8.1f} q/s  "
              f"batched={qps_b:8.1f} q/s  speedup={speedup:5.2f}x",
              flush=True)
        for mode, wall, qps in (("per_query", wall_pq, qps_pq),
                                ("batched", wall_b, qps_b)):
            rec = {"bench": "service_flood", "config": config,
                   "dataset": "synthflood", "strategy": "SERVICE",
                   "executor": ex, "mode": mode, "queries": n_queries,
                   "wall_s": round(wall, 4), "qps": round(qps, 1),
                   "completed": True}
            if mode == "batched":
                rec["speedup_vs_per_query"] = round(speedup, 3)
            out.append(rec)
    return out


def bench_tenant_flood(n_tenants: int = 4, edges: int = 800,
                       rounds: int = 3,
                       executors: Sequence[str] = ("dense", "sparse"),
                       seed: int = 0) -> List[dict]:
    """Multi-tenant fleet flood: per-tenant serial dispatch vs the
    registry's cross-tenant batched dispatch.

    ``n_tenants`` logical databases share one schema (the tiered
    GREEN/YELLOW/RED supply-chain pattern space from
    ``benchmarks/workloads.py``) behind one
    :class:`~repro.serve.tenancy.TenantRegistry`.  Each round every
    tenant answers the full tiered mix cold (the shared cache is evicted
    between rounds).  The per-tenant baseline is STRONG — each tenant's
    ``count_many`` still signature-buckets and stacks within the
    tenant — so the measured speedup is purely the cross-tenant
    stacking win (same-shape plans from different tenants riding one
    jitted dispatch instead of one dispatch per tenant per shape).
    """
    try:
        from benchmarks.workloads import (supply_chain_schema,
                                          tenant_fleet, tiered_points)
    except ImportError:                 # run as a script from benchmarks/
        from workloads import (supply_chain_schema, tenant_fleet,
                               tiered_points)
    from repro.serve import TenantRegistry

    fleet = tenant_fleet(n_tenants, supply_chain_schema(), edges=edges,
                         seed=seed)
    schema = fleet[0][1].schema
    tiers = tiered_points(schema, 3)
    mix = tiers["GREEN"] + tiers["YELLOW"] + tiers["RED"]
    tier_counts = {t: len(v) for t, v in tiers.items()}
    config = f"tenants{n_tenants}x{edges}r{rounds}"
    out: List[dict] = []
    for ex in executors:
        reg = TenantRegistry(executor=ex)
        for tid, db in fleet:
            reg.add_tenant(tid, db)
        tenant_qs = [(p, None) for p in mix]
        all_qs = [(tid, p, None) for tid, _ in fleet for p in mix]
        n_queries = rounds * len(all_qs)

        def serial_round():
            reg.cache.evict_all()
            for tid, _ in fleet:
                jax.block_until_ready(
                    [t.counts for t in
                     reg.tenant(tid).service.count_many(tenant_qs)])

        def cross_round():
            reg.cache.evict_all()
            jax.block_until_ready(
                [t.counts for t in reg.count_many(all_qs)])

        serial_round()                  # warm jits/staging for both modes
        cross_round()
        t0 = time.perf_counter()
        for _ in range(rounds):
            serial_round()
        wall_s = time.perf_counter() - t0
        qps_s = n_queries / wall_s
        t0 = time.perf_counter()
        for _ in range(rounds):
            cross_round()
        wall_c = time.perf_counter() - t0
        qps_c = n_queries / wall_c

        speedup = qps_c / qps_s if qps_s > 0 else float("inf")
        print(f"[tenants] {config} {ex:6s} "
              f"per_tenant={qps_s:8.1f} q/s  "
              f"cross_tenant={qps_c:8.1f} q/s  speedup={speedup:5.2f}x",
              flush=True)
        for mode, wall, qps in (("per_tenant", wall_s, qps_s),
                                ("cross_tenant", wall_c, qps_c)):
            rec = {"bench": "tenant_flood", "config": config,
                   "dataset": "synthfleet", "strategy": "REGISTRY",
                   "executor": ex, "mode": mode, "tenants": n_tenants,
                   "queries": n_queries, "tier_mix": tier_counts,
                   "wall_s": round(wall, 4), "qps": round(qps, 1),
                   "completed": True}
            if mode == "cross_tenant":
                rec["speedup_vs_per_tenant"] = round(speedup, 3)
            out.append(rec)
        reg.shutdown()
    return out


def bench_tiered_schedule(schemas: Sequence[str] = ("social", "fmcg",
                                                    "supply_chain"),
                          edges: int = 400, n_queries: int = 60,
                          rounds: int = 3, executor: str = "sparse",
                          seed: int = 0) -> List[dict]:
    """The paper's pre/post schedule choice, driven per complexity tier
    (the ``tiered`` trajectory dimension).

    Each example schema's tier-weighted query mix (``benchmarks/
    workloads.py``) is answered two ways on identical data:

    * **scheduled** — the counting strategy follows the tier: GREEN
      (single-atom) queries pre-count through ``PRECOUNT`` (complete
      table once, every projection free), RED (long/self-relationship
      chains) post-count through ``ONDEMAND`` (never materialise the
      expensive complete tables), YELLOW takes ``HYBRID``.
    * **hybrid** — the uniform baseline: every tier through one
      ``HYBRID`` strategy, the paper's default.

    Caches are evicted between rounds so every round re-executes both
    phases.  Reports queries/s per mode per schema and the
    scheduled-over-hybrid ratio.  This dimension is recorded, not gated:
    the paper's claim is that HYBRID dominates both pure schedules, so a
    ratio below 1 — per-tier scheduling losing to uniform hybrid — is
    the expected, paper-consistent outcome, and the trajectory keeps the
    measured margin honest across revisions.
    """
    try:
        from benchmarks.workloads import EXAMPLE_SCHEMAS, classify, query_mix
    except ImportError:                 # run as a script from benchmarks/
        from workloads import EXAMPLE_SCHEMAS, classify, query_mix

    schedule = {"GREEN": "PRECOUNT", "YELLOW": "HYBRID", "RED": "ONDEMAND"}
    out: List[dict] = []
    for name in schemas:
        schema = EXAMPLE_SCHEMAS[name]()
        db = synth_db(schema, {r.name: edges for r in schema.relationships},
                      seed=seed)
        lattice = build_lattice(schema, 3)
        mix = query_mix(schema, n_queries, seed=seed)
        # every occurrence projects a DIFFERENT random axis subset (all
        # indicators + some attrs): the realistic discovery read pattern
        # that pre-counting exists for — one complete table serves every
        # projection, while on-demand recounts per distinct keep
        import random as _random
        krng = _random.Random(seed + 1)
        queries = []
        for p in mix:
            axes = [v for v in p.all_ct_vars(schema, include_rind=True)
                    if v.kind != "edge"]
            rinds = [v for v in axes if v.kind == "rind"]
            attrs = [v for v in axes if v.kind == "attr"]
            chosen = (krng.sample(attrs, krng.randint(1, len(attrs)))
                      if attrs else [])
            keep = tuple(v for v in axes if v in rinds or v in chosen)
            queries.append((p, keep))
        tier_of = {p: classify(schema, p) for p in set(mix)}
        tier_counts: Dict[str, int] = {}
        for p in mix:
            tier_counts[tier_of[p]] = tier_counts.get(tier_of[p], 0) + 1
        config = f"tiered-{name}e{edges}n{n_queries}r{rounds}"

        by_tier = {}
        for tier, sname in schedule.items():
            st = make_strategy(sname, executor=executor)
            st.prepare(db, lattice)
            by_tier[tier] = st
        hy = make_strategy("HYBRID", executor=executor)
        hy.prepare(db, lattice)

        def scheduled_round():
            for st in by_tier.values():
                st.engine.cache.evict_all()
            jax.block_until_ready(
                [by_tier[tier_of[p]].family_ct(p, keep).counts
                 for p, keep in queries])

        def hybrid_round():
            hy.engine.cache.evict_all()
            jax.block_until_ready(
                [hy.family_ct(p, keep).counts for p, keep in queries])

        scheduled_round()               # warm jits for both modes
        hybrid_round()
        walls = {}
        for mode, fn in (("scheduled", scheduled_round),
                         ("hybrid", hybrid_round)):
            t0 = time.perf_counter()
            for _ in range(rounds):
                fn()
            walls[mode] = time.perf_counter() - t0
        total = rounds * len(mix)
        ratio = (walls["hybrid"] / walls["scheduled"]
                 if walls["scheduled"] > 0 else float("inf"))
        print(f"[tiered] {config} {executor:6s} "
              f"scheduled={total / walls['scheduled']:8.1f} q/s  "
              f"hybrid={total / walls['hybrid']:8.1f} q/s  "
              f"ratio={ratio:5.2f}x", flush=True)
        for mode in ("scheduled", "hybrid"):
            rec = {"bench": "tiered_schedule", "config": config,
                   "dataset": name, "strategy": ("SCHEDULE" if mode ==
                                                 "scheduled" else "HYBRID"),
                   "executor": executor, "mode": mode, "queries": total,
                   "tier_mix": tier_counts,
                   "wall_s": round(walls[mode], 4),
                   "qps": round(total / walls[mode], 1)
                   if walls[mode] > 0 else 0.0,
                   "completed": True}
            if mode == "scheduled":
                rec["ratio_vs_hybrid"] = round(ratio, 3)
            out.append(rec)
    return out


def bench_negative_flood(n_rels: int = 16, edges: int = 2000,
                         rounds: int = 5,
                         executors: Sequence[str] = ("dense", "sparse"),
                         seed: int = 0) -> List[dict]:
    """Same-signature complete-CT flood: per-family Möbius joins vs the
    service's fully batched complete path.

    Each query asks for the COMPLETE table (attribute + relationship
    indicator axes — the butterfly case the paper says must be
    post-counted).  The per-family baseline answers them one
    :func:`~repro.core.mobius.complete_ct` at a time (per-query positive
    contraction + per-query transform); the batched side routes the same
    flood through :meth:`~repro.serve.service.CountingService
    .complete_many` (stacked positive dispatches + ONE butterfly
    transform per shape group).  The ct-cache is cleared between rounds,
    so every round re-executes both phases.  Reports queries/s per mode
    and the batched-over-per-family speedup.
    """
    from repro.core.engine import OnDemandPositives
    from repro.core.mobius import complete_ct
    from repro.serve import CountingService

    db = _flood_db(n_rels, edges, seed=seed)
    lattice = build_lattice(db.schema, 1)
    # attr + indicator axes: a kept edge-attr axis would force the
    # blockwise join on both sides (complete_ct semantics, not batching)
    keeps = [tuple(v for v in p.all_ct_vars(db.schema, include_rind=True)
                   if v.kind != "edge") for p in lattice]
    queries = list(zip(lattice, keeps))
    n_queries = rounds * len(queries)
    config = f"negflood{n_rels}x{edges}r{rounds}"
    out: List[dict] = []
    for ex in executors:
        # ---- per-family dispatch (warm one round, then timed) ------------
        eng = CountingEngine(db, ex, CostStats())
        policy = OnDemandPositives(eng)

        def per_family_round():
            eng.cache.evict_all()
            jax.block_until_ready([complete_ct(p, k, policy,
                                               mobius_fn=eng.mobius_fn()
                                               ).counts
                                   for p, k in queries])

        per_family_round()
        t0 = time.perf_counter()
        for _ in range(rounds):
            per_family_round()
        wall_pf = time.perf_counter() - t0
        qps_pf = n_queries / wall_pf

        # ---- service-batched complete path (cold cache every round) ------
        eng_b = CountingEngine(db, ex, CostStats())
        svc = CountingService(eng_b, max_batch_size=max(n_rels, 1))

        def batched_round():
            eng_b.cache.evict_all()
            jax.block_until_ready([t.counts
                                   for t in svc.complete_many(queries)])

        batched_round()
        t0 = time.perf_counter()
        for _ in range(rounds):
            batched_round()
        wall_b = time.perf_counter() - t0
        qps_b = n_queries / wall_b

        speedup = qps_b / qps_pf if qps_pf > 0 else float("inf")
        print(f"[negflood] {config} {ex:6s} per_family={qps_pf:8.1f} q/s  "
              f"batched={qps_b:8.1f} q/s  speedup={speedup:5.2f}x",
              flush=True)
        for mode, wall, qps in (("per_family", wall_pf, qps_pf),
                                ("batched", wall_b, qps_b)):
            rec = {"bench": "negative_flood", "config": config,
                   "dataset": "synthflood", "strategy": "SERVICE",
                   "executor": ex, "mode": mode, "queries": n_queries,
                   "wall_s": round(wall, 4), "qps": round(qps, 1),
                   "completed": True}
            if mode == "batched":
                rec["speedup_vs_per_family"] = round(speedup, 3)
            out.append(rec)
    return out


def bench_sharded_flood(n_shards: int = 2, n_rels: int = 16,
                        edges: int = 2000, rounds: int = 5,
                        seed: int = 0, trace: bool = False) -> List[dict]:
    """Sharded-vs-single sparse counting throughput (the ``--shards``
    dimension).

    The same cold-cache query flood is answered two ways: by one
    CountingService over the whole database, and by a CountingRouter over
    a ``n_shards``-way hash-partitioned copy (one service per shard,
    fan-out + count merging at the front-end).  Both sides run the sparse
    executor.  Reports queries/s per mode and the sharded-over-single
    ratio — on one host this measures the routing/merge overhead; across
    real hosts each shard scans 1/``n_shards`` of the edge rows.

    ``trace=True`` (the ``--trace`` flag) runs the sharded side with a
    request tracer (slow threshold 0, so every query is offered) and
    dumps the slow-query log — which queries were the tail, and which
    dispatch path answered them.
    """
    from repro.core.database import shard_database
    from repro.serve import CountingRouter, CountingService

    db = _flood_db(n_rels, edges, seed=seed)
    lattice = build_lattice(db.schema, 1)
    queries = [(p, None) for p in lattice]
    n_queries = rounds * len(queries)
    config = f"shard{n_shards}x{n_rels}x{edges}r{rounds}"
    out: List[dict] = []

    # Each round is timed on its own and the *median* round wall drives the
    # reported q/s: one flood round is only a few ms, so a single scheduler
    # hiccup or GC pause in a summed wall would swing the sharded/single
    # ratio by 2x.  ``wall_s`` in the records stays the summed wall.

    # ---- single-database service (the baseline) ----------------------------
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=max(n_rels, 1))
    eng.cache.evict_all()
    jax.block_until_ready([t.counts for t in svc.count_many(queries)])
    walls: List[float] = []
    for _ in range(rounds):
        eng.cache.evict_all()
        t0 = time.perf_counter()
        jax.block_until_ready([t.counts for t in svc.count_many(queries)])
        walls.append(time.perf_counter() - t0)
    wall_single = sum(walls)
    qps_single = len(queries) / statistics.median(walls)

    # ---- sharded router ----------------------------------------------------
    sdb = shard_database(db, n_shards)
    tracer = None
    if trace:
        from repro.obs import Tracer
        tracer = Tracer(capacity=1 << 15, slow_threshold_s=0.0)
    router = CountingRouter(sdb, executor="sparse",
                            max_batch_size=max(n_rels, 1),
                            tracer=tracer)
    jax.block_until_ready([t.counts for t in router.count_many(queries)])
    walls = []
    for _ in range(rounds):
        for e in router.engines:
            e.cache.evict_all()
        router.invalidate()      # keep measuring fan-out+merge, not the
        t0 = time.perf_counter()  # router's own result cache
        jax.block_until_ready([
            t.counts for t in router.count_many(queries)])
        walls.append(time.perf_counter() - t0)
    wall_sharded = sum(walls)
    qps_sharded = len(queries) / statistics.median(walls)

    ratio = qps_sharded / qps_single if qps_single > 0 else float("inf")
    rs = router.stats()["router"]
    print(f"[shards] {config} sparse single={qps_single:8.1f} q/s  "
          f"sharded={qps_sharded:8.1f} q/s  ratio={ratio:5.2f}x  "
          f"fanout={rs['fanout_requests']} merged={rs['merged_tables']}",
          flush=True)
    slow_dump: List[dict] = []
    if tracer is not None:
        slow_dump = tracer.slow.as_dicts()[:10]
        print(f"[shards] {config} slow-query log "
              f"(top {len(slow_dump)} of {tracer.slow.offered} offered, "
              f"{tracer.recorded} spans traced):", flush=True)
        for q in slow_dump:
            info = " ".join(f"{k}={v}" for k, v in q["info"].items())
            print(f"[shards]   {q['duration_s'] * 1e3:8.3f}ms "
                  f"{q['name']}  {info}", flush=True)
    for mode, wall, qps in (("single", wall_single, qps_single),
                            ("sharded", wall_sharded, qps_sharded)):
        rec = {"bench": "sharded_flood", "config": config,
               "dataset": "synthflood", "strategy": "ROUTER",
               "executor": "sparse", "mode": mode, "shards": n_shards,
               "queries": n_queries, "wall_s": round(wall, 4),
               "qps": round(qps, 1), "completed": True}
        if mode == "sharded":
            rec["ratio_vs_single"] = round(ratio, 3)
            if slow_dump:
                rec["slow_queries"] = slow_dump
        out.append(rec)
    return out


def _fresh_edge_batches(db: RelationalDB, rels: Sequence[str], rounds: int,
                        delta_edges: int, seed: int) -> List[dict]:
    """Pre-generated insert batches (new (src, dst) pairs + random attrs),
    identical across the modes being compared."""
    import numpy as np
    rng = np.random.default_rng(seed)
    have = {r: db.relations[r].pair_set() for r in rels}
    out: List[dict] = []
    for _ in range(rounds):
        per = {}
        for r in rels:
            tab = db.relations[r]
            ns = db.entities[tab.type.src].size
            nd = db.entities[tab.type.dst].size
            pairs = []
            while len(pairs) < delta_edges:
                s, d = int(rng.integers(ns)), int(rng.integers(nd))
                if (s, d) not in have[r]:
                    have[r].add((s, d))
                    pairs.append((s, d))
            per[r] = (np.array([p[0] for p in pairs], np.int32),
                      np.array([p[1] for p in pairs], np.int32),
                      {a.name: rng.integers(0, a.card, size=delta_edges)
                       .astype(np.int32) for a in tab.type.attrs})
        out.append(per)
    return out


def bench_mutation_flood(n_rels: int = 6, edges: int = 100000,
                         delta_edges: int = 128, rounds: int = 3,
                         executors: Sequence[str] = ("dense", "sparse"),
                         seed: int = 0) -> List[dict]:
    """Insert-heavy mutation flood: delta count maintenance vs
    recount-from-scratch (the ``mutflood`` trajectory dimension).

    The workload interleaves writes with reads against warmed caches:
    every write inserts ``delta_edges`` fresh edges into one
    relationship, then the full single-atom query set is re-read.  Two
    freshness models answer it:

    * **delta** — ``CountingService.insert_facts``: fenced write +
      fine-grained cache reconcile; the affected positive table is
      updated in place by one contraction over just the delta edges, and
      every read is a cache hit.
    * **recount** — the pre-mutations model: each write flushes the
      whole ct-cache (all-or-nothing invalidation was the only safe
      answer when entries carried no dependency metadata), so every
      read after a write re-contracts from the full edge lists.

    Both modes serve identical queries on identical data (same
    pre-generated edge batches).  Reports wall time and writes+reads/s
    per mode, and the delta-over-recount speedup.
    """
    from repro.serve import CountingService

    config = f"mutflood{n_rels}x{edges}d{delta_edges}r{rounds}"
    rels = [f"F{i}" for i in range(n_rels)]
    out: List[dict] = []
    for ex in executors:
        walls = {}
        for mode in ("delta", "recount"):
            db = _flood_db(n_rels, edges, seed=seed)
            batches = _fresh_edge_batches(db, rels, rounds, delta_edges,
                                          seed=seed + 1)
            eng = CountingEngine(db, ex, CostStats())
            svc = CountingService(eng, max_batch_size=max(n_rels, 1))
            lattice = build_lattice(db.schema, 1)
            queries = [(p, None) for p in lattice]
            jax.block_until_ready([t.counts                    # warm
                                   for t in svc.count_many(queries)])
            t0 = time.perf_counter()
            for rnd in batches:
                for r in rels:
                    src, dst, attrs = rnd[r]
                    if mode == "delta":
                        svc.insert_facts(r, src, dst, attrs)
                    else:
                        with svc.fence():
                            eng.db.insert_facts(r, src, dst, attrs)
                            eng.cache.invalidate()   # all-or-nothing flush
                    jax.block_until_ready(
                        [t.counts for t in svc.count_many(queries)])
            walls[mode] = time.perf_counter() - t0
        n_ops = rounds * n_rels * (1 + len(rels))    # writes + reads
        speedup = (walls["recount"] / walls["delta"]
                   if walls["delta"] > 0 else float("inf"))
        print(f"[mutflood] {config} {ex:6s} "
              f"delta={walls['delta']:7.3f}s  "
              f"recount={walls['recount']:7.3f}s  "
              f"speedup={speedup:5.2f}x", flush=True)
        for mode in ("delta", "recount"):
            rec = {"bench": "mutation_flood", "config": config,
                   "dataset": "synthflood", "strategy": "SERVICE",
                   "executor": ex, "mode": mode,
                   "queries": n_ops, "wall_s": round(walls[mode], 4),
                   "qps": round(n_ops / walls[mode], 1)
                   if walls[mode] > 0 else 0.0,
                   "completed": True}
            if mode == "delta":
                rec["speedup_vs_recount"] = round(speedup, 3)
            out.append(rec)
    return out


def bench_mutation_negative_flood(n_rels: int = 6, edges: int = 100000,
                                  delta_edges: int = 128, rounds: int = 3,
                                  executors: Sequence[str] = ("dense",
                                                              "sparse"),
                                  seed: int = 0) -> List[dict]:
    """Write-heavy flood over COMPLETE-CT reads: fused butterfly delta
    propagation vs flush-and-recount (the ``mutnegflood`` trajectory
    dimension).

    Same interleaving as :func:`bench_mutation_flood`, but every read
    asks for the complete table (attribute + indicator axes — the
    negative phase), served through :meth:`~repro.serve.service
    .CountingService.complete_many` into the ``"fam"`` cache namespace:

    * **delta** — ``CountingService.insert_facts``: the resident family
      tables are updated IN PLACE by pushing per-corner block deltas
      (contractions over just the delta edges) through ONE fused
      butterfly dispatch per (shape, perm) group; reads after a write
      are cache hits.
    * **recount** — the pre-delta model: each write flushes the whole
      ct-cache, so every read round re-runs the full Möbius join
      (positive contractions over the full edge lists + transform).

    Both modes serve identical queries on identical data.  Reports wall
    time and writes+reads/s per mode, and the delta-over-recount
    speedup — the headline number for "writes stop flushing the
    negative phase".
    """
    from repro.serve import CountingService

    config = f"mutnegflood{n_rels}x{edges}d{delta_edges}r{rounds}"
    rels = [f"F{i}" for i in range(n_rels)]
    out: List[dict] = []
    for ex in executors:
        walls = {}
        for mode in ("delta", "recount"):
            db = _flood_db(n_rels, edges, seed=seed)
            batches = _fresh_edge_batches(db, rels, rounds, delta_edges,
                                          seed=seed + 1)
            eng = CountingEngine(db, ex, CostStats())
            svc = CountingService(eng, max_batch_size=max(n_rels, 1))
            lattice = build_lattice(db.schema, 1)
            # attr + indicator axes: the butterfly-eligible complete CT
            queries = [(p, tuple(v for v in p.all_ct_vars(db.schema,
                                                          include_rind=True)
                                 if v.kind != "edge")) for p in lattice]
            jax.block_until_ready([t.counts                    # warm
                                   for t in svc.complete_many(queries)])
            t0 = time.perf_counter()
            for rnd in batches:
                for r in rels:
                    src, dst, attrs = rnd[r]
                    if mode == "delta":
                        svc.insert_facts(r, src, dst, attrs)
                    else:
                        with svc.fence():
                            eng.db.insert_facts(r, src, dst, attrs)
                            eng.cache.invalidate()   # all-or-nothing flush
                    jax.block_until_ready(
                        [t.counts for t in svc.complete_many(queries)])
            walls[mode] = time.perf_counter() - t0
        n_ops = rounds * n_rels * (1 + len(rels))    # writes + reads
        speedup = (walls["recount"] / walls["delta"]
                   if walls["delta"] > 0 else float("inf"))
        print(f"[mutnegflood] {config} {ex:6s} "
              f"delta={walls['delta']:7.3f}s  "
              f"recount={walls['recount']:7.3f}s  "
              f"speedup={speedup:5.2f}x", flush=True)
        for mode in ("delta", "recount"):
            rec = {"bench": "mutation_negative_flood", "config": config,
                   "dataset": "synthflood", "strategy": "SERVICE",
                   "executor": ex, "mode": mode,
                   "queries": n_ops, "wall_s": round(walls[mode], 4),
                   "qps": round(n_ops / walls[mode], 1)
                   if walls[mode] > 0 else 0.0,
                   "completed": True}
            if mode == "delta":
                rec["speedup_vs_recount"] = round(speedup, 3)
            out.append(rec)
    return out


def bench_discovery(dataset: str = "IMDb", scale: float = 0.05,
                    rounds: int = 3, seed: int = 0,
                    max_chain_length: int = 1, max_parents: int = 2,
                    strategy: str = "HYBRID") -> List[dict]:
    """Served vs local model-discovery throughput (the ``--discovery``
    dimension).

    The same hill-climbing discovery runs two ways on the IMDb-style
    schema: through a bare in-process strategy (the local oracle) and
    through a :class:`CountingService` (batched, coalesced, cached —
    the served path).  Each timed round drops the score memo but keeps
    the CT caches warm, so both modes redo identical BDeu scoring work
    over identical counts and the ratio isolates the serve layer's
    round-trip overhead on search traffic.  The two modes' timed rounds
    are interleaved and the reported ratio is the *median of per-pair*
    served/local families/s (each pair ran back-to-back, so ambient
    load cancels within a pair and the median drops pairs a scheduler
    blip hit one-sided — same reasoning as the tracing-overhead gate);
    per-mode rounds/s and families/s are best-of-``rounds``.  The
    perf-smoke gate requires ratio >= 0.9x.
    """
    from repro.discover import DiscoveryService
    from repro.serve import CountingService

    config = f"disc{dataset}s{scale}r{rounds}"
    out: List[dict] = []
    modes = ("local", "served")
    sigs: Dict[str, dict] = {}
    dsvcs: Dict[str, DiscoveryService] = {}
    for mode in modes:
        db = paper_benchmark_db(dataset, seed=seed, scale=scale)
        if mode == "local":
            dsvc = DiscoveryService(make_strategy(strategy), db=db,
                                    max_chain_length=max_chain_length,
                                    max_parents=max_parents)
        else:
            svc = CountingService(CountingEngine(db, "sparse", CostStats()))
            dsvc = DiscoveryService(svc,
                                    max_chain_length=max_chain_length,
                                    max_parents=max_parents)
        sigs[mode] = dsvc.discover().signature()   # warm CTs + jit caches
        dsvcs[mode] = dsvc
    walls: Dict[str, List[float]] = {m: [] for m in modes}
    round_counts: Dict[str, List[int]] = {m: [] for m in modes}
    fam_counts: Dict[str, List[int]] = {m: [] for m in modes}
    for _ in range(rounds):       # interleaved: drift hits both modes
        for mode in modes:
            dsvc = dsvcs[mode]
            dsvc.reset_memo()    # re-score everything over warm counts
            before = dsvc.metrics.snapshot()["rounds"]
            t0 = time.perf_counter()
            res = dsvc.discover()
            walls[mode].append(time.perf_counter() - t0)
            round_counts[mode].append(
                dsvc.metrics.snapshot()["rounds"] - before)
            fam_counts[mode].append(res.families_scored)
    perf: Dict[str, Tuple[float, float, float]] = {}
    for mode in modes:
        rounds_per_s = max(
            (r / w for r, w in zip(round_counts[mode], walls[mode])
             if w > 0), default=0.0)
        fams_per_s = max(
            (f / w for f, w in zip(fam_counts[mode], walls[mode])
             if w > 0), default=0.0)
        perf[mode] = (sum(walls[mode]), rounds_per_s, fams_per_s)
    assert sigs["served"] == sigs["local"], \
        "served discovery diverged from the local oracle"
    # Ratio = median of per-pair ratios: round i of each mode ran
    # back-to-back, so ambient load cancels within a pair, and the
    # median drops pairs where a scheduler blip hit only one side.
    pair_ratios = [
        (fam_counts["served"][i] / walls["served"][i])
        / (fam_counts["local"][i] / walls["local"][i])
        for i in range(len(walls["local"]))
        if walls["local"][i] > 0 and walls["served"][i] > 0
        and fam_counts["local"][i] > 0]
    ratio = statistics.median(pair_ratios) if pair_ratios else float("inf")
    print(f"[discovery] {config} local={perf['local'][2]:8.1f} fam/s "
          f"({perf['local'][1]:6.1f} rounds/s)  "
          f"served={perf['served'][2]:8.1f} fam/s "
          f"({perf['served'][1]:6.1f} rounds/s)  ratio={ratio:5.2f}x",
          flush=True)
    for mode in ("local", "served"):
        wall, rps, fps = perf[mode]
        rec = {"bench": "discovery", "config": config, "dataset": dataset,
               "strategy": strategy if mode == "local" else "SERVICE",
               "executor": "sparse", "mode": mode,
               "queries": rounds, "wall_s": round(wall, 4),
               "qps": round(fps, 1), "rounds_per_s": round(rps, 1),
               "families_per_s": round(fps, 1), "completed": True}
        if mode == "served":
            rec["ratio_vs_local"] = round(ratio, 3)
        out.append(rec)
    return out


def write_outputs(art: dict, out_dir: str = "results/bench",
                  bench_json: Optional[str] = "BENCH_counting.json") -> None:
    """One canonical artifact; the root trajectory file is derived.

    ``results/bench/counting.json`` holds the whole artifact (this run's
    source of truth).  ``BENCH_counting.json`` is its ``trajectory``
    section *appended* to whatever earlier PRs recorded — the
    accumulating cross-PR perf trajectory the CI perf-smoke gate reads.
    """
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "counting.json").write_text(json.dumps(art, indent=1))
    print(f"[counting] wrote {out / 'counting.json'} (canonical)")
    if bench_json:
        path = Path(bench_json)
        history: List[dict] = []
        if path.exists():
            try:
                history = json.loads(path.read_text())
            except json.JSONDecodeError:
                history = []
        history.extend(art["trajectory"])
        path.write_text(json.dumps(history, indent=1))
        print(f"[counting] wrote {path} (derived from trajectory, "
              f"{len(history)} rows)")


def main(out_dir: str = "results/bench", scale: Optional[float] = None,
         datasets: Sequence[str] = PAPER_DATASETS,
         budget_s: float = TIME_BUDGET_S, spotlight: bool = True,
         executors: Sequence[str] = ("dense", "sparse"),
         flood: bool = True,
         flood_kw: Optional[dict] = None,
         neg_flood: bool = True,
         neg_flood_kw: Optional[dict] = None,
         shards: Sequence[int] = (),
         shard_kw: Optional[dict] = None,
         mut_flood: bool = True,
         mut_flood_kw: Optional[dict] = None,
         mut_neg_flood: bool = True,
         mut_neg_flood_kw: Optional[dict] = None,
         tenant_flood: bool = False,
         tenant_flood_kw: Optional[dict] = None,
         tiered: bool = True,
         tiered_kw: Optional[dict] = None,
         discovery: bool = False,
         discovery_kw: Optional[dict] = None,
         trace: bool = False,
         bench_json: Optional[str] = "BENCH_counting.json") -> dict:
    recs = run_all(datasets=datasets, scale=scale, budget_s=budget_s,
                   executors=executors)
    art = {
        "runs": [r.as_dict() for r in recs],
        "fig3_runtime": fig3_runtime(recs),
        "fig4_memory": fig4_memory(recs),
        "table5_sizes": table5_sizes(recs),
    }
    if spotlight:
        # the paper's headline: hybrid counting scales to millions of facts.
        # Full-scale VisualGenome (15.8M rows) / IMDb (1.06M rows), HYBRID on
        # the sparse backend (positive phase scales in nnz, not entities×D).
        spot = []
        for name, sc in (("IMDb", 1.0), ("VisualGenome", 1.0)):
            r = run_one(name, "HYBRID", scale=sc, budget_s=1200.0,
                        executor="sparse")
            print(f"[spotlight] {name} rows={r.rows} HYBRID/sparse "
                  f"wall={r.wall_s}s pos={r.time_positive} "
                  f"neg={r.time_negative} completed={r.completed}",
                  flush=True)
            spot.append(r.as_dict())
            recs.append(r)
        art["spotlight_full_scale"] = spot
    flood_recs: List[dict] = []
    if flood:
        flood_recs = bench_service_flood(executors=tuple(executors),
                                         **(flood_kw or {}))
        art["service_flood"] = flood_recs
    neg_recs: List[dict] = []
    if neg_flood:
        neg_recs = bench_negative_flood(executors=tuple(executors),
                                        **(neg_flood_kw or {}))
        art["negative_flood"] = neg_recs
    shard_recs: List[dict] = []
    for n in shards:
        shard_recs.extend(bench_sharded_flood(n_shards=int(n), trace=trace,
                                              **(shard_kw or {})))
    if shard_recs:
        art["sharded_flood"] = shard_recs
    mut_recs: List[dict] = []
    if mut_flood:
        mut_recs = bench_mutation_flood(executors=tuple(executors),
                                        **(mut_flood_kw or {}))
        art["mutation_flood"] = mut_recs
    mutneg_recs: List[dict] = []
    if mut_neg_flood:
        mutneg_recs = bench_mutation_negative_flood(
            executors=tuple(executors), **(mut_neg_flood_kw or {}))
        art["mutation_negative_flood"] = mutneg_recs
    tenant_recs: List[dict] = []
    if tenant_flood:
        tenant_recs = bench_tenant_flood(executors=tuple(executors),
                                         **(tenant_flood_kw or {}))
        art["tenant_flood"] = tenant_recs
    tiered_recs: List[dict] = []
    if tiered:
        tiered_recs = bench_tiered_schedule(**(tiered_kw or {}))
        art["tiered_schedule"] = tiered_recs
    disc_recs: List[dict] = []
    if discovery:
        disc_recs = bench_discovery(**(discovery_kw or {}))
        art["discovery"] = disc_recs
    art["trajectory"] = (bench_trajectory(recs) + flood_recs + neg_recs
                         + shard_recs + mut_recs + mutneg_recs
                         + tenant_recs + tiered_recs + disc_recs)
    write_outputs(art, out_dir=out_dir, bench_json=bench_json)
    return art


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=None,
                    help="multiply the per-dataset DEFAULT_SCALES")
    ap.add_argument("--datasets", nargs="*", default=list(PAPER_DATASETS))
    ap.add_argument("--budget-s", type=float, default=TIME_BUDGET_S)
    ap.add_argument("--no-spotlight", action="store_true")
    ap.add_argument("--no-flood", action="store_true")
    ap.add_argument("--no-neg-flood", action="store_true")
    ap.add_argument("--no-mut-flood", action="store_true")
    ap.add_argument("--no-mut-neg-flood", action="store_true")
    ap.add_argument("--no-tiered", action="store_true")
    ap.add_argument("--shards", type=int, nargs="*", default=[],
                    metavar="N",
                    help="also run the sharded-vs-single sparse flood for "
                         "each shard count given (e.g. --shards 2 4)")
    ap.add_argument("--trace", action="store_true",
                    help="run the sharded flood with request tracing on "
                         "and dump its slow-query log")
    ap.add_argument("--discovery", action="store_true",
                    help="also run the served-vs-local model-discovery "
                         "throughput bench (rounds/s + families/s)")
    ap.add_argument("--tenant-flood", action="store_true",
                    help="also run the multi-tenant fleet flood "
                         "(cross-tenant batched vs per-tenant serial)")
    args = ap.parse_args()
    main(scale=args.scale, datasets=tuple(args.datasets),
         budget_s=args.budget_s, spotlight=not args.no_spotlight,
         flood=not args.no_flood, neg_flood=not args.no_neg_flood,
         shards=tuple(args.shards), mut_flood=not args.no_mut_flood,
         mut_neg_flood=not args.no_mut_neg_flood,
         tiered=not args.no_tiered,
         tenant_flood=args.tenant_flood,
         discovery=args.discovery, trace=args.trace)
