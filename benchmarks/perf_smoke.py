"""CI perf smoke: a small counting config + the service flood, gated
against the recorded trajectory.

Runs ``bench_counting.main`` on a reduced configuration (UW at scale
0.25 plus a small same-signature flood), which *appends* this run's rows
to ``BENCH_counting.json`` — the accumulating cross-PR trajectory — and
then fails (exit 1) when the batched serve path regresses.  The gate is
the *same-run* batched-over-per-query speedup (both sides measured on
the same machine in the same process, so the signal is comparable across
laptops and CI runners, unlike absolute q/s):

* the speedup must stay >= ``MIN_BATCHED_SPEEDUP`` (the serve layer's
  acceptance bar), and
* it must not fall more than ``REGRESSION_FACTOR``x below the *median*
  speedup previously recorded for the same flood config in the
  trajectory (median, not max: the trajectory mixes hosts of very
  different speeds, and one lucky fast-host run must not poison the
  gate for every slower host after it), and
* the reduced counting runs must complete within their budget, and
* the sharded flood must hold >= ``MIN_SHARDED_RATIO`` of single-DB
  throughput (the router's fan-out merge fast path), also
  regression-checked against the trajectory, and
* the multi-tenant flood's cross-tenant batched dispatch must beat the
  per-tenant serial baseline by >= ``MIN_TENANT_BATCHED_SPEEDUP``, and
  the default-tenant shim must stay free: the single-DB service flood
  may fall at most ``SHIM_REGRESSION_FACTOR`` below the *worst* speedup
  ever recorded for its config, and
* served model discovery must hold >= ``MIN_DISCOVERY_RATIO`` of the
  local oracle's families/s on identical warm-count scoring work (the
  serve layer must not tax the search loop), also regression-checked, and
* the Pallas segment-sum kernel must match the XLA scatter path
  bit-for-bit in interpret mode (CPU CI's only way to execute the
  kernel body), and
* request tracing must stay out of the serving path's way: the traced
  sharded flood must hold within ``OBS_OVERHEAD_MAX`` of the untraced
  flood (interleaved best-of-N rounds); the measured ratio plus a
  metrics/trace artifact is written to ``results/bench/obs.json``, and
* full-scale VisualGenome under a tight cache budget must complete
  within its budget (skippable via ``PERF_SMOKE_SKIP_VG=1``).

First run on a fresh history simply records the baseline and passes.

Run:  PYTHONPATH=src:. python benchmarks/perf_smoke.py
"""

from __future__ import annotations

import json
import statistics
import sys
from pathlib import Path

from benchmarks import bench_counting

BENCH_JSON = "BENCH_counting.json"
REGRESSION_FACTOR = 2.0
SMOKE_FLOOD = dict(n_rels=8, edges=800, rounds=3)
MIN_BATCHED_SPEEDUP = 2.0     # the serve layer's reason to exist
# the complete-CT (negative-phase) flood is gated the same way: batched
# positive + batched Möbius transform must beat per-family dispatch
SMOKE_NEG_FLOOD = dict(n_rels=8, edges=800, rounds=3)
MIN_NEG_BATCHED_SPEEDUP = 2.0
# sharded-vs-single IS gated: the router's fan-out fast path reassembles
# the shard packs into one single-cost dispatch, so even on one CI host
# (where no scan parallelism exists) sharding must not cost more than a
# 10% routing overhead — regressions here mean the merge path fell back
# to per-shard dispatch + host merging
SMOKE_SHARDS = (2,)
SMOKE_SHARD_KW = dict(n_rels=8, edges=800, rounds=3)
MIN_SHARDED_RATIO = 0.9
# the mutation flood gates the freshness model: fenced delta maintenance
# must beat flush-and-recount on an insert-heavy write/read mix
SMOKE_MUT_FLOOD = dict(n_rels=6, edges=100000, delta_edges=128, rounds=2)
MIN_MUT_SPEEDUP = 2.0
# the negative-phase mutation flood gates the butterfly delta path:
# writes interleaved with COMPLETE-CT reads must beat flush-and-recount
# (fused per-corner block deltas through one transform dispatch per
# shape group vs re-running the whole Möbius join after every write)
SMOKE_MUT_NEG_FLOOD = dict(n_rels=6, edges=100000, delta_edges=128,
                           rounds=2)
MIN_MUT_NEG_SPEEDUP = 2.0
# the multi-tenant fleet gates the tenancy layer both ways: cross-tenant
# batched dispatch must beat the (already within-tenant-batched)
# per-tenant serial baseline, AND the tenant dimension must be free for
# single-DB deployments — the default-tenant service flood's speedup may
# fall at most 5% below the WORST run ever recorded for its config
# (min, not median: same-run speedups still swing ~2x across runs, so a
# tight factor needs the floor of the observed range as its reference)
SMOKE_TENANTS = dict(n_tenants=4, edges=800, rounds=3)
MIN_TENANT_BATCHED_SPEEDUP = 1.5
SHIM_REGRESSION_FACTOR = 1.05
# model discovery through the serve layer must not tax the search loop:
# served families/s must hold >= MIN_DISCOVERY_RATIO of the local oracle
# on identical (warm-count, cold-memo) scoring work, regression-checked
# against the trajectory like every other dimension
SMOKE_DISCOVERY = dict(dataset="IMDb", scale=0.15, rounds=5,
                       max_chain_length=1, max_parents=2)
MIN_DISCOVERY_RATIO = 0.9
# observability must be off-by-default-cheap AND cheap when on: the
# traced sharded flood may cost at most 5% over the untraced one
# (interleaved rounds, best-of-N per mode; a small absolute slack keeps
# sub-ms jitter from flapping the gate).  The measured ratio + exported
# metrics/trace summary land in results/bench/obs.json.
SMOKE_OBS_FLOOD = dict(n_shards=2, n_rels=8, edges=4000, rounds=5, reps=4)
OBS_OVERHEAD_MAX = 1.05
OBS_OVERHEAD_SLACK_S = 2e-3
OBS_JSON = "results/bench/obs.json"
# the paper's headline config as a standing CI gate: full-scale
# VisualGenome (15.8M rows) under a deliberately tight cache budget —
# the LRU must keep evicting, so both counting phases and cache
# admission stay on the measured path.  Gated on completion within the
# budget plus a wall-clock regression check vs the recorded trajectory.
# Skippable for quick local iterations with PERF_SMOKE_SKIP_VG=1.
VG_FULL_SCALE = dict(dataset="VisualGenome", strategy="HYBRID",
                     executor="sparse", scale=1.0, budget_s=420.0,
                     cache_budget_bytes=64 * 1024 * 1024)


def flood_config_tag() -> str:
    f = SMOKE_FLOOD
    return f"flood{f['n_rels']}x{f['edges']}r{f['rounds']}"


def neg_flood_config_tag() -> str:
    f = SMOKE_NEG_FLOOD
    return f"negflood{f['n_rels']}x{f['edges']}r{f['rounds']}"


def mut_flood_config_tag() -> str:
    f = SMOKE_MUT_FLOOD
    return (f"mutflood{f['n_rels']}x{f['edges']}"
            f"d{f['delta_edges']}r{f['rounds']}")


def mut_neg_flood_config_tag() -> str:
    f = SMOKE_MUT_NEG_FLOOD
    return (f"mutnegflood{f['n_rels']}x{f['edges']}"
            f"d{f['delta_edges']}r{f['rounds']}")


def tenant_config_tag() -> str:
    f = SMOKE_TENANTS
    return f"tenants{f['n_tenants']}x{f['edges']}r{f['rounds']}"


def shard_config_tag(n_shards: int) -> str:
    f = SMOKE_SHARD_KW
    return f"shard{n_shards}x{f['n_rels']}x{f['edges']}r{f['rounds']}"


def discovery_config_tag() -> str:
    f = SMOKE_DISCOVERY
    return f"disc{f['dataset']}s{f['scale']}r{f['rounds']}"


def prior_sharded_ratio(history: list, config: str) -> float:
    """Median recorded sharded-over-single ratio for one shard config
    (median for the same cross-host robustness as
    ``prior_batched_speedup``)."""
    vals = [float(rec.get("ratio_vs_single", 0.0))
            for rec in history
            if (rec.get("bench") == "sharded_flood"
                and rec.get("mode") == "sharded"
                and rec.get("config") == config)]
    return statistics.median(vals) if vals else 0.0


def prior_vg_wall(history: list) -> float:
    """Median recorded full-scale VisualGenome wall seconds (median for
    the same cross-host robustness as ``prior_batched_speedup``)."""
    vals = [float(rec.get("wall_s", 0.0))
            for rec in history
            if (rec.get("bench") == "vg_full_scale"
                and rec.get("completed"))]
    return statistics.median(vals) if vals else 0.0


def check_kernel_parity() -> list:
    """CPU-CI kernel coverage: assert the backend probe resolves to the
    Pallas *interpreter* here (no accelerator), then force the sparse
    executors' scatter-add through the kernel (``REPRO_SEGSUM_PALLAS=1``)
    and require bit-identical counts vs the XLA segment-sum path.  This is
    what keeps the Mosaic/Triton code path honest on hosts that cannot
    lower it natively."""
    import os

    import numpy as np

    from repro.core.contract import CostStats
    from repro.core.database import paper_benchmark_db
    from repro.core.engine import CountingEngine
    from repro.core.variables import build_lattice
    from repro.kernels import ops

    failures = []
    if jax_backend() != "cpu":
        return failures                 # probe semantics covered by tests
    if ops.default_interpret() is not True:
        failures.append("kernel_parity: default_interpret() is not True on "
                        "a CPU host — the backend probe is broken")
        return failures
    db = paper_benchmark_db("UW", seed=0, scale=0.25)
    points = build_lattice(db.schema, 2)[:4]
    eng = CountingEngine(db, "sparse", CostStats())
    want = [np.asarray(eng.contract(p).counts) for p in points]
    os.environ["REPRO_SEGSUM_PALLAS"] = "1"
    try:
        eng_k = CountingEngine(db, "sparse", CostStats())
        for p, w in zip(points, want):
            got = np.asarray(eng_k.contract(p).counts)
            if not np.array_equal(got, w):
                failures.append(
                    f"kernel_parity: interpret-mode Pallas segment-sum "
                    f"diverges from XLA on {p}")
    finally:
        os.environ.pop("REPRO_SEGSUM_PALLAS", None)
    if not failures:
        print("[perf-smoke] kernel parity OK (Pallas segment-sum, "
              "interpret mode on CPU)", flush=True)
    return failures


def check_tracing_overhead() -> list:
    """Gate the observability stack's cost on the serving path: run the
    ``SMOKE_OBS_FLOOD`` sharded flood with tracing off and on in
    interleaved rounds (same process, same jit caches, same thermal
    state) and require the best traced round within ``OBS_OVERHEAD_MAX``
    of the best untraced one.  Writes ``results/bench/obs.json``: the
    measured ratio, per-span counts, the router's full metrics snapshot,
    and the Prometheus render size — the artifact CI keeps for the
    observability surface."""
    import time

    import jax

    from benchmarks.bench_counting import _flood_db
    from repro.core import build_lattice
    from repro.core.database import shard_database
    from repro.obs import MetricsRegistry, NULL_TRACER, Tracer
    from repro.serve import CountingRouter

    kw = SMOKE_OBS_FLOOD
    config = (f"shard{kw['n_shards']}x{kw['n_rels']}x{kw['edges']}"
              f"r{kw['rounds']}")
    db = _flood_db(kw["n_rels"], kw["edges"], seed=0)
    queries = [(p, None) for p in build_lattice(db.schema, 1)]
    sdb = shard_database(db, kw["n_shards"])
    router = CountingRouter(sdb, executor="sparse",
                            max_batch_size=max(kw["n_rels"], 1),
                            tracer=NULL_TRACER)
    tracer = Tracer(capacity=1 << 15)

    def flood_round() -> float:
        # several floods per timed round: a single flood is ~2 ms, far
        # too small for a 5% relative gate, so each round accumulates
        # ``reps`` floods (evictions excluded from the timed section)
        wall = 0.0
        for _ in range(kw["reps"]):
            for e in router.engines:
                e.cache.evict_all()
            router.invalidate()          # measure work, not result cache
            t0 = time.perf_counter()
            jax.block_until_ready([t.counts
                                   for t in router.count_many(queries)])
            wall += time.perf_counter() - t0
        return wall

    for tr in (NULL_TRACER, tracer):     # warm both modes (jit compiles)
        router.set_tracer(tr)
        flood_round()
    walls = {"disabled": [], "enabled": []}
    for _ in range(kw["rounds"]):        # interleaved, so drift hits both
        router.set_tracer(NULL_TRACER)
        walls["disabled"].append(flood_round())
        router.set_tracer(tracer)
        walls["enabled"].append(flood_round())
    best_dis = min(walls["disabled"])
    best_en = min(walls["enabled"])
    ratio = best_en / best_dis if best_dis > 0 else 1.0

    failures = []
    if ratio > OBS_OVERHEAD_MAX and best_en - best_dis > OBS_OVERHEAD_SLACK_S:
        failures.append(
            f"tracing_overhead/{config}: traced flood is {ratio:.3f}x the "
            f"untraced one, over the {OBS_OVERHEAD_MAX:.2f}x bar")

    span_counts: dict = {}
    for rec in tracer.records():
        span_counts[rec.name] = span_counts.get(rec.name, 0) + 1
    reg = MetricsRegistry()
    reg.register("router", router.stats)
    prom = reg.prometheus()
    art = {"bench": "tracing_overhead", "config": config,
           "walls_disabled_s": [round(w, 5) for w in walls["disabled"]],
           "walls_enabled_s": [round(w, 5) for w in walls["enabled"]],
           "overhead_ratio": round(ratio, 4),
           "gate": OBS_OVERHEAD_MAX,
           "reps_per_round": kw["reps"],
           "span_counts": span_counts,
           "tracer": tracer.snapshot(),
           "prometheus_lines": len(prom.splitlines()),
           "router_stats": reg.collect()["router"]}
    out = Path(OBS_JSON)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(art, indent=1, default=str))
    print(f"[perf-smoke] tracing overhead {ratio:.3f}x "
          f"(gate {OBS_OVERHEAD_MAX:.2f}x, {tracer.recorded} spans, "
          f"{len(prom.splitlines())} prometheus lines) -> {OBS_JSON}",
          flush=True)
    return failures


def jax_backend() -> str:
    import jax
    return jax.default_backend()


def prior_batched_speedup(history: list, config: str,
                          bench: str = "service_flood",
                          field: str = "speedup_vs_per_query",
                          mode: str = "batched") -> dict:
    """Median recorded speedup per executor for one flood config+mode.

    Median, not max: BENCH_counting.json accumulates runs from hosts of
    very different speeds, and a single lucky run on a fast machine
    would otherwise poison the regression gate for every slower host
    that follows.  The median self-corrects as the trajectory grows."""
    vals: dict = {}
    for rec in history:
        if (rec.get("bench") == bench
                and rec.get("mode") == mode
                and rec.get("config") == config
                and field in rec):
            vals.setdefault(rec.get("executor"), []).append(
                float(rec[field]))
    return {ex: statistics.median(v) for ex, v in vals.items()}


def prior_batched_floor(history: list, config: str,
                        bench: str = "service_flood",
                        field: str = "speedup_vs_per_query",
                        mode: str = "batched") -> dict:
    """MINIMUM recorded speedup per executor for one config+mode — the
    reference for tight (few-percent) regression factors, where the
    cross-host spread around the median is far wider than the factor."""
    vals: dict = {}
    for rec in history:
        if (rec.get("bench") == bench
                and rec.get("mode") == mode
                and rec.get("config") == config
                and field in rec):
            vals.setdefault(rec.get("executor"), []).append(
                float(rec[field]))
    return {ex: min(v) for ex, v in vals.items()}


def main() -> int:
    path = Path(BENCH_JSON)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except json.JSONDecodeError:
            history = []
    baseline = prior_batched_speedup(history, flood_config_tag())
    neg_baseline = prior_batched_speedup(
        history, neg_flood_config_tag(), bench="negative_flood",
        field="speedup_vs_per_family")
    mut_baseline = prior_batched_speedup(
        history, mut_flood_config_tag(), bench="mutation_flood",
        field="speedup_vs_recount", mode="delta")
    mut_neg_baseline = prior_batched_speedup(
        history, mut_neg_flood_config_tag(),
        bench="mutation_negative_flood",
        field="speedup_vs_recount", mode="delta")
    tenant_baseline = prior_batched_speedup(
        history, tenant_config_tag(), bench="tenant_flood",
        field="speedup_vs_per_tenant", mode="cross_tenant")
    shim_floor = prior_batched_floor(history, flood_config_tag())
    shard_baselines = {n: prior_sharded_ratio(history, shard_config_tag(n))
                       for n in SMOKE_SHARDS}
    disc_baseline = prior_batched_speedup(
        history, discovery_config_tag(), bench="discovery",
        field="ratio_vs_local", mode="served")
    vg_baseline = prior_vg_wall(history)

    art = bench_counting.main(
        datasets=("UW",), scale=0.25, budget_s=120.0, spotlight=False,
        flood=True, flood_kw=dict(SMOKE_FLOOD),
        neg_flood=True, neg_flood_kw=dict(SMOKE_NEG_FLOOD),
        shards=SMOKE_SHARDS, shard_kw=dict(SMOKE_SHARD_KW),
        mut_flood=True, mut_flood_kw=dict(SMOKE_MUT_FLOOD),
        mut_neg_flood=True, mut_neg_flood_kw=dict(SMOKE_MUT_NEG_FLOOD),
        tenant_flood=True, tenant_flood_kw=dict(SMOKE_TENANTS),
        discovery=True, discovery_kw=dict(SMOKE_DISCOVERY),
        bench_json=BENCH_JSON)

    failures = []
    gates = (("service_flood", "speedup_vs_per_query",
              MIN_BATCHED_SPEEDUP, baseline),
             ("negative_flood", "speedup_vs_per_family",
              MIN_NEG_BATCHED_SPEEDUP, neg_baseline),
             ("mutation_flood", "speedup_vs_recount",
              MIN_MUT_SPEEDUP, mut_baseline),
             ("mutation_negative_flood", "speedup_vs_recount",
              MIN_MUT_NEG_SPEEDUP, mut_neg_baseline),
             ("tenant_flood", "speedup_vs_per_tenant",
              MIN_TENANT_BATCHED_SPEEDUP, tenant_baseline))
    for bench, field, min_speedup, prior_best in gates:
        for rec in art.get(bench, []):
            if rec.get("mode") not in ("batched", "delta", "cross_tenant"):
                continue
            ex = rec["executor"]
            speedup = float(rec.get(field, 0.0))
            if speedup < min_speedup:
                failures.append(
                    f"{bench}/{ex}: batched speedup {speedup:.2f}x is "
                    f"below the {min_speedup:.1f}x bar")
            prior = prior_best.get(ex)
            if prior and speedup * REGRESSION_FACTOR < prior:
                failures.append(
                    f"{bench}/{ex}: batched speedup {speedup:.2f}x is a "
                    f">{REGRESSION_FACTOR:.0f}x regression vs recorded "
                    f"{prior:.2f}x")
    # the default-tenant shim must keep single-DB serving free of tenant
    # overhead: the service flood's same-run speedup may fall at most
    # SHIM_REGRESSION_FACTOR below the floor of its recorded range
    for rec in art.get("service_flood", []):
        if rec.get("mode") != "batched":
            continue
        ex = rec["executor"]
        speedup = float(rec.get("speedup_vs_per_query", 0.0))
        floor = shim_floor.get(ex)
        if floor and speedup * SHIM_REGRESSION_FACTOR < floor:
            failures.append(
                f"service_flood/{ex}: batched speedup {speedup:.2f}x fell "
                f">{(SHIM_REGRESSION_FACTOR - 1) * 100:.0f}% below the "
                f"recorded floor {floor:.2f}x — the tenant dimension is "
                f"taxing single-DB serving")
    for rec in art.get("sharded_flood", []):
        if rec.get("mode") != "sharded":
            continue
        ratio = float(rec.get("ratio_vs_single", 0.0))
        if ratio < MIN_SHARDED_RATIO:
            failures.append(
                f"sharded_flood/{rec['config']}: sharded throughput is "
                f"{ratio:.2f}x single-DB, below the "
                f"{MIN_SHARDED_RATIO:.1f}x bar — the fan-out merge fast "
                f"path is not engaging")
        prior = shard_baselines.get(int(rec.get("shards", 0)), 0.0)
        if prior and ratio * REGRESSION_FACTOR < prior:
            failures.append(
                f"sharded_flood/{rec['config']}: ratio {ratio:.2f}x is a "
                f">{REGRESSION_FACTOR:.0f}x regression vs recorded "
                f"{prior:.2f}x")
    for rec in art.get("discovery", []):
        if rec.get("mode") != "served":
            continue
        ratio = float(rec.get("ratio_vs_local", 0.0))
        if ratio < MIN_DISCOVERY_RATIO:
            failures.append(
                f"discovery/{rec['config']}: served discovery holds only "
                f"{ratio:.2f}x of local families/s, below the "
                f"{MIN_DISCOVERY_RATIO:.1f}x bar — the serve layer is "
                f"taxing the search loop")
        prior = disc_baseline.get(rec.get("executor"), 0.0)
        if prior and ratio * REGRESSION_FACTOR < prior:
            failures.append(
                f"discovery/{rec['config']}: ratio {ratio:.2f}x is a "
                f">{REGRESSION_FACTOR:.0f}x regression vs recorded "
                f"{prior:.2f}x")
    for rec in art["runs"]:
        if not rec["completed"]:
            failures.append(
                f"{rec['dataset']}/{rec['strategy']}/{rec['executor']}: "
                f"smoke run exceeded its budget")

    failures.extend(check_kernel_parity())
    failures.extend(check_tracing_overhead())

    import os
    if not os.environ.get("PERF_SMOKE_SKIP_VG"):
        vg_kw = dict(VG_FULL_SCALE)
        r = bench_counting.run_one(
            vg_kw.pop("dataset"), vg_kw.pop("strategy"), **vg_kw)
        vg_rec = {"bench": "vg_full_scale",
                  "config": "vg1.0cache64MB", **r.as_dict()}
        print(f"[perf-smoke] vg_full_scale rows={r.rows} "
              f"wall={r.wall_s}s completed={r.completed}", flush=True)
        if not r.completed:
            failures.append(
                f"vg_full_scale: VisualGenome scale=1.0 exceeded its "
                f"{VG_FULL_SCALE['budget_s']:.0f}s budget under the tight "
                f"cache budget")
        elif vg_baseline and r.wall_s > vg_baseline * REGRESSION_FACTOR:
            failures.append(
                f"vg_full_scale: wall {r.wall_s:.0f}s is a "
                f">{REGRESSION_FACTOR:.0f}x regression vs recorded "
                f"{vg_baseline:.0f}s")
        try:
            hist = json.loads(path.read_text()) if path.exists() else []
        except json.JSONDecodeError:
            hist = []
        hist.append(vg_rec)
        path.write_text(json.dumps(hist, indent=1))

    if failures:
        for f in failures:
            print(f"[perf-smoke] FAIL: {f}", flush=True)
        return 1
    parts = [
        f"{bench}:{ex}>={s / REGRESSION_FACTOR:.1f}x"
        for bench, prior_best in (("flood", baseline),
                                  ("negflood", neg_baseline),
                                  ("mutflood", mut_baseline),
                                  ("mutnegflood", mut_neg_baseline),
                                  ("tenants", tenant_baseline))
        for ex, s in prior_best.items()]
    parts += [
        f"shard{n}>={max(MIN_SHARDED_RATIO, r / REGRESSION_FACTOR):.2f}x"
        for n, r in shard_baselines.items() if r > 0]
    parts += [
        f"discovery>={max(MIN_DISCOVERY_RATIO, r / REGRESSION_FACTOR):.2f}x"
        for r in disc_baseline.values() if r > 0]
    gated = ", ".join(parts) or "baseline recorded"
    print(f"[perf-smoke] OK (speedup gate: {gated})", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
