"""CI perf smoke: a small counting config + the service flood, gated
against the recorded trajectory.

Runs ``bench_counting.main`` on a reduced configuration (UW at scale
0.25 plus a small same-signature flood), which *appends* this run's rows
to ``BENCH_counting.json`` — the accumulating cross-PR trajectory — and
then fails (exit 1) when the batched serve path regresses.  The gate is
the *same-run* batched-over-per-query speedup (both sides measured on
the same machine in the same process, so the signal is comparable across
laptops and CI runners, unlike absolute q/s):

* the speedup must stay >= ``MIN_BATCHED_SPEEDUP`` (the serve layer's
  acceptance bar), and
* it must not fall more than ``REGRESSION_FACTOR``x below the best
  speedup previously recorded for the same flood config in the
  trajectory, and
* the reduced counting runs must complete within their budget.

First run on a fresh history simply records the baseline and passes.

Run:  PYTHONPATH=src:. python benchmarks/perf_smoke.py
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks import bench_counting

BENCH_JSON = "BENCH_counting.json"
REGRESSION_FACTOR = 2.0
SMOKE_FLOOD = dict(n_rels=8, edges=800, rounds=3)
MIN_BATCHED_SPEEDUP = 2.0     # the serve layer's reason to exist
# the complete-CT (negative-phase) flood is gated the same way: batched
# positive + batched Möbius transform must beat per-family dispatch
SMOKE_NEG_FLOOD = dict(n_rels=8, edges=800, rounds=3)
MIN_NEG_BATCHED_SPEEDUP = 2.0
# sharded-vs-single is recorded (trajectory dimension), not gated: on one
# CI host the router measures merge overhead, not the n-hosts scan win
SMOKE_SHARDS = (2,)
SMOKE_SHARD_KW = dict(n_rels=8, edges=800, rounds=3)
# the mutation flood gates the freshness model: fenced delta maintenance
# must beat flush-and-recount on an insert-heavy write/read mix
SMOKE_MUT_FLOOD = dict(n_rels=6, edges=100000, delta_edges=128, rounds=2)
MIN_MUT_SPEEDUP = 2.0


def flood_config_tag() -> str:
    f = SMOKE_FLOOD
    return f"flood{f['n_rels']}x{f['edges']}r{f['rounds']}"


def neg_flood_config_tag() -> str:
    f = SMOKE_NEG_FLOOD
    return f"negflood{f['n_rels']}x{f['edges']}r{f['rounds']}"


def mut_flood_config_tag() -> str:
    f = SMOKE_MUT_FLOOD
    return (f"mutflood{f['n_rels']}x{f['edges']}"
            f"d{f['delta_edges']}r{f['rounds']}")


def prior_batched_speedup(history: list, config: str,
                          bench: str = "service_flood",
                          field: str = "speedup_vs_per_query",
                          mode: str = "batched") -> dict:
    """Best recorded speedup per executor for one flood config+mode."""
    best: dict = {}
    for rec in history:
        if (rec.get("bench") == bench
                and rec.get("mode") == mode
                and rec.get("config") == config
                and field in rec):
            ex = rec.get("executor")
            best[ex] = max(best.get(ex, 0.0), float(rec[field]))
    return best


def main() -> int:
    path = Path(BENCH_JSON)
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except json.JSONDecodeError:
            history = []
    baseline = prior_batched_speedup(history, flood_config_tag())
    neg_baseline = prior_batched_speedup(
        history, neg_flood_config_tag(), bench="negative_flood",
        field="speedup_vs_per_family")
    mut_baseline = prior_batched_speedup(
        history, mut_flood_config_tag(), bench="mutation_flood",
        field="speedup_vs_recount", mode="delta")

    art = bench_counting.main(
        datasets=("UW",), scale=0.25, budget_s=120.0, spotlight=False,
        flood=True, flood_kw=dict(SMOKE_FLOOD),
        neg_flood=True, neg_flood_kw=dict(SMOKE_NEG_FLOOD),
        shards=SMOKE_SHARDS, shard_kw=dict(SMOKE_SHARD_KW),
        mut_flood=True, mut_flood_kw=dict(SMOKE_MUT_FLOOD),
        bench_json=BENCH_JSON)

    failures = []
    gates = (("service_flood", "speedup_vs_per_query",
              MIN_BATCHED_SPEEDUP, baseline),
             ("negative_flood", "speedup_vs_per_family",
              MIN_NEG_BATCHED_SPEEDUP, neg_baseline),
             ("mutation_flood", "speedup_vs_recount",
              MIN_MUT_SPEEDUP, mut_baseline))
    for bench, field, min_speedup, prior_best in gates:
        for rec in art.get(bench, []):
            if rec.get("mode") not in ("batched", "delta"):
                continue
            ex = rec["executor"]
            speedup = float(rec.get(field, 0.0))
            if speedup < min_speedup:
                failures.append(
                    f"{bench}/{ex}: batched speedup {speedup:.2f}x is "
                    f"below the {min_speedup:.0f}x bar")
            prior = prior_best.get(ex)
            if prior and speedup * REGRESSION_FACTOR < prior:
                failures.append(
                    f"{bench}/{ex}: batched speedup {speedup:.2f}x is a "
                    f">{REGRESSION_FACTOR:.0f}x regression vs recorded "
                    f"{prior:.2f}x")
    for rec in art["runs"]:
        if not rec["completed"]:
            failures.append(
                f"{rec['dataset']}/{rec['strategy']}/{rec['executor']}: "
                f"smoke run exceeded its budget")

    if failures:
        for f in failures:
            print(f"[perf-smoke] FAIL: {f}", flush=True)
        return 1
    gated = ", ".join(
        f"{bench}:{ex}>={s / REGRESSION_FACTOR:.1f}x"
        for bench, prior_best in (("flood", baseline),
                                  ("negflood", neg_baseline),
                                  ("mutflood", mut_baseline))
        for ex, s in prior_best.items()) or "baseline recorded"
    print(f"[perf-smoke] OK (speedup gate: {gated})", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
