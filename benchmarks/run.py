"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--suite ...]``.

Suites (one per paper artefact + the perf report):
  counting — paper Fig. 3 (time decomposition), Fig. 4 (peak memory),
             Table 5 (ct sizes), via benchmarks.bench_counting
  kernels  — Pallas kernel shape sweeps vs jnp oracles
  roofline — re-summarise results/dryrun into the §Roofline table

Everything prints to stdout and writes JSON under results/bench/.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def roofline_summary(dryrun_dir: str = "results/dryrun",
                     out_dir: str = "results/bench") -> list:
    rows = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok") or rec.get("tag"):
            continue
        t = rec["roofline"]
        bound = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": round(t["t_compute_s"], 6),
            "memory_s": round(t["t_memory_s"], 6),
            "collective_s": round(t["t_collective_s"], 6),
            "bottleneck": t["bottleneck"],
            "roofline_frac": round(t["t_compute_s"] / bound, 4) if bound else None,
            "useful_flops_ratio": round(rec["useful_flops_ratio"], 3),
        })
    for r in rows:
        print("[roofline] " + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "roofline.json").write_text(json.dumps(rows, indent=1))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=["counting", "kernels", "roofline",
                                        "all"], default="all")
    ap.add_argument("--scale", type=float, default=None,
                    help="extra multiplier on the per-dataset scales")
    ap.add_argument("--budget", type=float, default=180.0,
                    help="per-(dataset,strategy) soft time budget, seconds")
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args()

    if args.suite in ("kernels", "all"):
        from benchmarks import bench_kernels
        bench_kernels.main(out_dir=args.out)
    if args.suite in ("counting", "all"):
        from benchmarks import bench_counting
        bench_counting.main(out_dir=args.out, scale=args.scale,
                            budget_s=args.budget)
    if args.suite in ("roofline", "all"):
        roofline_summary(out_dir=args.out)


if __name__ == "__main__":
    main()
