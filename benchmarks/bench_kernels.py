"""Pallas-kernel micro-benchmarks: shape sweeps, correctness vs the jnp
oracle, and us/call timings.

This container is CPU-only, so timings come from two paths:
  * ``interpret=True`` Pallas — correctness of the kernel *body* (what the
    dry-run cannot exercise);
  * the jnp reference — the XLA-compiled roofline stand-in on this host.

Real-TPU timing is out of scope here; the kernels' VMEM/BlockSpec reasoning
is recorded in EXPERIMENTS.md §Perf and the per-kernel headers.
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn: Callable, *args, reps: int = 5) -> float:
    fn(*args)                              # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench_mobius() -> List[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for k in (1, 2, 3, 4, 6):
        for d in (128, 2048, 16384):
            x = jax.random.uniform(key, (1 << k, d), jnp.float32) * 100
            want = ref.mobius_ref(x)
            got = ops.mobius(x, interpret=True)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
            us_ref = _time(jax.jit(ref.mobius_ref), x)
            us_int = _time(lambda a: ops.mobius(a, interpret=True), x)
            rows.append({"kernel": "mobius", "k": k, "d": d,
                         "us_ref": round(us_ref, 1),
                         "us_interpret": round(us_int, 1)})
    return rows


def bench_hist() -> List[dict]:
    rows = []
    key = jax.random.PRNGKey(1)
    for n, p, d in ((4096, 64, 128), (65536, 256, 128), (262144, 1024, 64)):
        codes = jax.random.randint(key, (n,), 0, p, jnp.int32)
        vals = jax.random.uniform(key, (n, d), jnp.float32)
        want = ref.segment_hist_ref(codes, vals, p)
        got = ops.segment_hist(codes, vals, p, interpret=True)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)
        us_ref = _time(lambda c, v: ref.segment_hist_ref(c, v, p), codes, vals)
        # interpret mode executes the kernel body in Python — time it only
        # for shapes where that stays in the seconds range (the big grid is
        # still correctness-checked above)
        us_int = (None if n > 100_000 else round(_time(
            lambda c, v: ops.segment_hist(c, v, p, interpret=True),
            codes, vals, reps=1), 1))
        rows.append({"kernel": "segment_hist", "n": n, "segments": p, "d": d,
                     "us_ref": round(us_ref, 1),
                     "us_interpret": us_int})
    return rows


def bench_segsum() -> List[dict]:
    """The sparse executors' scatter-add hop: Pallas one-hot contraction
    (interpret mode here) vs the ``jax.ops.segment_sum`` reference, over
    edge-count x segment-space shapes spanning leaf hops (``d`` absent,
    weighted ones) and dense-message hops.  Segment spaces mirror the
    flattened ``(parent, code)`` ids the executor actually emits,
    including out-of-range padding."""
    rows = []
    rng = np.random.default_rng(3)
    for n, p, d in ((800, 256, None), (4096, 1024, None),
                    (800, 256, 16), (4096, 2048, 64)):
        # +3: a few ids beyond the segment space, like edge-bucket padding
        seg = jnp.asarray(rng.integers(0, p + 3, size=n, dtype=np.int32))
        if d is None:
            w = jnp.asarray(rng.uniform(0, 2, size=n).astype(np.float32))
            want = ref.ones_segment_sum_ref(seg, w, p)
            got = ops.ones_segment_sum(seg, w, p, interpret=True)
            us_ref = _time(lambda s, v: ref.ones_segment_sum_ref(s, v, p),
                           seg, w)
            us_int = _time(lambda s, v: ops.ones_segment_sum(
                s, v, p, interpret=True), seg, w, reps=1)
        else:
            w = jnp.asarray(rng.uniform(0, 2, size=(n, d)).astype(np.float32))
            want = ref.edge_segment_sum_ref(seg, w, p)
            got = ops.edge_segment_sum(seg, w, p, interpret=True)
            us_ref = _time(lambda s, v: ref.edge_segment_sum_ref(s, v, p),
                           seg, w)
            us_int = _time(lambda s, v: ops.edge_segment_sum(
                s, v, p, interpret=True), seg, w, reps=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)
        rows.append({"kernel": "segment_sum", "n": n, "segments": p,
                     "d": d or 1,
                     "mode": "ones" if d is None else "rows",
                     "us_ref": round(us_ref, 1),
                     "us_interpret": round(us_int, 1)})
    return rows


def bench_bdeu() -> List[dict]:
    rows = []
    key = jax.random.PRNGKey(2)
    for q, r in ((64, 8), (1024, 16), (8192, 4)):
        nijk = jax.random.poisson(key, 3.0, (q, r)).astype(jnp.float32)
        want = ref.bdeu_ref(nijk, 1.0, q, r)
        got = ops.bdeu(nijk, ess=1.0, interpret=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)
        us_ref = _time(jax.jit(lambda x: ref.bdeu_ref(x, 1.0, q, r)), nijk)
        us_int = _time(lambda x: ops.bdeu(x, ess=1.0, interpret=True), nijk)
        rows.append({"kernel": "bdeu", "q": q, "r": r,
                     "us_ref": round(us_ref, 1),
                     "us_interpret": round(us_int, 1)})
    return rows


def main(out_dir: str = "results/bench",
         bench_json: str = "BENCH_counting.json") -> List[dict]:
    rows = bench_mobius() + bench_hist() + bench_segsum() + bench_bdeu()
    for r in rows:
        print("[kernels] " + ",".join(f"{k}={v}" for k, v in r.items()),
              flush=True)
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "kernels.json").write_text(json.dumps(rows, indent=1))
    print(f"[kernels] wrote {out / 'kernels.json'}")
    # the segment-sum rows also join the cross-PR counting trajectory:
    # they time the executors' innermost hop primitive, so a kernel-side
    # regression shows up next to the serve/flood history it would cause
    if bench_json:
        path = Path(bench_json)
        try:
            history = json.loads(path.read_text()) if path.exists() else []
        except json.JSONDecodeError:
            history = []
        history.extend({"bench": "kernel_segsum", **r} for r in rows
                       if r["kernel"] == "segment_sum")
        path.write_text(json.dumps(history, indent=1))
        print(f"[kernels] appended segment_sum rows to {path}")
    return rows


if __name__ == "__main__":
    main()
