"""Tiered multi-tenant workload generation for the serving benchmarks.

Pattern-space enumeration by complexity tier (the GREEN/YELLOW/RED
phasing used by ontology-driven pattern discovery):

* **GREEN** — single-relationship patterns: one atom, the cheapest
  ct-tables, always the first phase of a discovery run.
* **YELLOW** — two-relationship chains across DISTINCT entity types:
  medium fan-out joins.
* **RED** — everything expensive: chains of three or more atoms, or any
  chain through a self-relationship (same entity type on both ends —
  the recursive joins that dominate worst-case cost).

Three small example schemas with deliberately different shapes (a
social network with a self-relationship, an FMCG purchase graph, a
supply chain) stand in for distinct logical databases, and
:func:`tenant_fleet` builds the N-tenant database set the multi-tenant
bench floods through a :class:`~repro.serve.tenancy.TenantRegistry`.

Everything here is deterministic given ``seed``.

Usage::

    tiers = tiered_points(social_schema(), max_chain_length=3)
    mix = query_mix(schema, n=200, weights={"GREEN": 3, "YELLOW": 2,
                                            "RED": 1}, seed=7)
    fleet = tenant_fleet(4, schema, edges=800, seed=0)
"""

from __future__ import annotations

import random
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.database import (Attribute, EntityType, RelationalDB,
                                 Relationship, Schema, synth_db)
from repro.core.search import build_lattice
from repro.core.variables import LatticePoint

__all__ = [
    "GREEN", "YELLOW", "RED", "TIERS",
    "classify", "tiered_points", "query_mix", "tenant_fleet",
    "social_schema", "fmcg_schema", "supply_chain_schema",
    "EXAMPLE_SCHEMAS",
]

GREEN = "GREEN"
YELLOW = "YELLOW"
RED = "RED"
TIERS = (GREEN, YELLOW, RED)


# -- complexity tiers --------------------------------------------------------
def classify(schema: Schema, point: LatticePoint) -> str:
    """Assign one lattice point to its complexity tier.

    Args:
        schema: the relational schema the point was enumerated over.
        point: a non-empty lattice point.

    Returns:
        ``"GREEN"`` (one atom), ``"YELLOW"`` (two-atom chain with no
        self-relationship), or ``"RED"`` (>= 3 atoms, or any atom over
        a self-relationship).

    Usage::

        tier = classify(schema, point)
    """
    if not point.atoms:
        raise ValueError("cannot classify the empty lattice point")
    self_rel = any(schema.relationship(a.rel).src
                   == schema.relationship(a.rel).dst
                   for a in point.atoms)
    if self_rel or point.length >= 3:
        return RED
    return GREEN if point.length == 1 else YELLOW


def tiered_points(schema: Schema, max_chain_length: int = 3
                  ) -> Dict[str, List[LatticePoint]]:
    """Enumerate the pattern space and bucket it by tier.

    Args:
        schema: relational schema to enumerate chains over.
        max_chain_length: longest relationship chain to enumerate.

    Returns:
        ``{"GREEN": [...], "YELLOW": [...], "RED": [...]}`` — every
        tier key is present (possibly empty), and the union is exactly
        the non-empty lattice.

    Usage::

        tiers = tiered_points(social_schema())
        assert tiers["RED"]          # self-relationship chains land here
    """
    out: Dict[str, List[LatticePoint]] = {t: [] for t in TIERS}
    for point in build_lattice(schema, max_chain_length):
        if point.atoms:
            out[classify(schema, point)].append(point)
    return out


def query_mix(schema: Schema, n: int,
              weights: Optional[Mapping[str, float]] = None,
              max_chain_length: int = 3,
              seed: int = 0) -> List[LatticePoint]:
    """A deterministic tier-weighted query stream.

    Args:
        schema: relational schema to enumerate.
        n: number of queries to draw (with replacement).
        weights: relative draw weight per tier; tiers with no points are
            dropped from the draw.  Defaults to ``{GREEN: 3, YELLOW: 2,
            RED: 1}`` — the cheap-heavy mix a warm discovery loop emits.
        max_chain_length: pattern-space depth.
        seed: RNG seed (same seed, same stream).

    Returns:
        ``n`` lattice points.

    Usage::

        stream = query_mix(schema, 200, seed=3)
    """
    if weights is None:
        weights = {GREEN: 3.0, YELLOW: 2.0, RED: 1.0}
    tiers = tiered_points(schema, max_chain_length)
    pool = [(t, pts) for t, pts in tiers.items()
            if pts and weights.get(t, 0) > 0]
    if not pool:
        raise ValueError("no enumerable patterns for the requested mix")
    rng = random.Random(seed)
    names = [t for t, _ in pool]
    w = [float(weights[t]) for t in names]
    by_tier = dict(pool)
    return [rng.choice(by_tier[t])
            for t in rng.choices(names, weights=w, k=n)]


# -- example schemas ---------------------------------------------------------
def social_schema() -> Schema:
    """A social network: the ``Follows`` self-relationship makes its RED
    tier non-empty at chain length 2 already."""
    return Schema(
        [EntityType("User", 60, [Attribute("age", 3),
                                 Attribute("active", 2)]),
         EntityType("Post", 40, [Attribute("topic", 3)])],
        [Relationship("Follows", "User", "User", []),
         Relationship("Likes", "User", "Post", [Attribute("strength", 2)])])


def fmcg_schema() -> Schema:
    """A fast-moving-consumer-goods purchase graph (customers, products,
    stores)."""
    return Schema(
        [EntityType("Customer", 50, [Attribute("segment", 3)]),
         EntityType("Product", 30, [Attribute("brand", 2),
                                    Attribute("organic", 2)]),
         EntityType("Store", 20, [Attribute("region", 2)])],
        [Relationship("Buys", "Customer", "Product",
                      [Attribute("promo", 2)]),
         Relationship("Stocks", "Store", "Product", [])])


def supply_chain_schema() -> Schema:
    """A supply chain (suppliers, parts, plants) with a self-relationship
    on parts (bill-of-materials style ``ComponentOf``)."""
    return Schema(
        [EntityType("Supplier", 25, [Attribute("tier", 2)]),
         EntityType("Part", 45, [Attribute("critical", 2)]),
         EntityType("Plant", 15, [Attribute("country", 3)])],
        [Relationship("Supplies", "Supplier", "Part", []),
         Relationship("ComponentOf", "Part", "Part", []),
         Relationship("Uses", "Plant", "Part", [Attribute("volume", 2)])])


EXAMPLE_SCHEMAS = {
    "social": social_schema,
    "fmcg": fmcg_schema,
    "supply_chain": supply_chain_schema,
}


# -- tenant fleets -----------------------------------------------------------
def tenant_fleet(n_tenants: int, schema: Optional[Schema] = None,
                 edges: int = 800, seed: int = 0
                 ) -> List[Tuple[str, RelationalDB]]:
    """Build ``n_tenants`` logical databases over ONE shared schema.

    Sharing the schema OBJECT is deliberate: plan compilation caches by
    schema, so every tenant's identical query compiles to the same plan
    and cross-tenant signature buckets stack into one jitted dispatch
    (different edge sets per tenant — the data differs, the shapes
    align).

    Args:
        n_tenants: fleet size.
        schema: shared schema; defaults to :func:`social_schema`.
        edges: edges per relationship per tenant.
        seed: base seed; tenant ``i`` synthesises with ``seed + i``.

    Returns:
        ``[(tenant_id, db), ...]`` with ids ``"t0".."t{n-1}"``.

    Usage::

        fleet = tenant_fleet(4, edges=800)
        for tid, db in fleet:
            registry.add_tenant(tid, db)
    """
    if schema is None:
        schema = social_schema()
    edges_per_rel = {r.name: edges for r in schema.relationships}
    return [(f"t{i}", synth_db(schema, edges_per_rel, seed=seed + i))
            for i in range(n_tenants)]
