"""Docs link/reference checker (the CI docs job).

Walks ``README.md`` and ``docs/*.md`` and fails when documentation rots:

* **internal links** ``[text](path)`` must point at files/directories that
  exist (relative to the markdown file); ``#fragment`` anchors must match
  a heading of the target file (GitHub-style slugs);
* **code references** — inline-code spans that name this package
  (``repro.core.plan.ContractionPlan`` style) must import/resolve, and
  spans that look like repo paths (``src/repro/serve/router.py``,
  ``tests/``) must exist.

Fenced code blocks are ignored (examples are allowed to elide imports).
Exits 1 when any reference is broken.

Run:  python scripts/check_docs.py
"""

from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
DOTTED_RE = re.compile(r"^repro(\.[A-Za-z_][A-Za-z0-9_]*)+$")
PATH_RE = re.compile(r"^[A-Za-z0-9_.\-]+(/[A-Za-z0-9_.\-]*)+$")


def strip_fences(text: str) -> str:
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        out.append("" if fenced else line)
    return "\n".join(out)


def heading_slugs(path: Path) -> set:
    """GitHub-style anchor slugs of a markdown file's headings."""
    slugs = set()
    for line in strip_fences(path.read_text()).splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            t = re.sub(r"`([^`]*)`", r"\1", m.group(1).strip())
            t = re.sub(r"[^\w\- ]", "", t.lower())
            slugs.add(t.replace(" ", "-"))
    return slugs


def check_link(md: Path, target: str) -> str | None:
    if target.startswith(("http://", "https://", "mailto:")):
        return None
    path_part, _, frag = target.partition("#")
    dest = md if not path_part else (md.parent / path_part).resolve()
    if not dest.exists():
        return f"link target does not exist: {target}"
    if frag and dest.suffix == ".md" and frag not in heading_slugs(dest):
        return f"anchor #{frag} not found in {path_part or md.name}"
    return None


def resolve_dotted(name: str) -> str | None:
    """Import the longest module prefix, then getattr the rest."""
    parts = name.split(".")
    mod, i = None, 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            break
        except ImportError:
            continue
    if mod is None:
        return f"cannot import any prefix of {name}"
    obj = mod
    for attr in parts[i:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{'.'.join(parts[:i])} has no attribute " \
                   f"{'.'.join(parts[i:])}"
    return None


def check_code_span(span: str) -> str | None:
    span = span.strip()
    if DOTTED_RE.match(span):
        return resolve_dotted(span)
    if PATH_RE.match(span) and not span.startswith("."):
        if not (ROOT / span).exists():
            return f"path does not exist: {span}"
    return None


def main() -> int:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        body = strip_fences(md.read_text())
        for target in LINK_RE.findall(body):
            err = check_link(md, target)
            if err:
                errors.append(f"{md.relative_to(ROOT)}: {err}")
        for span in CODE_RE.findall(body):
            err = check_code_span(span)
            if err:
                errors.append(f"{md.relative_to(ROOT)}: {err}")
    for e in errors:
        print(f"[check-docs] FAIL: {e}", flush=True)
    if not errors:
        n = sum(1 for _ in DOC_FILES)
        print(f"[check-docs] OK: {n} files, links and code references "
              f"resolve", flush=True)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
