"""Locked-metrics mutation checker (the CI docs job).

Every serving counter (:class:`repro.serve.metrics.ServiceMetrics` /
``RouterMetrics``) is a ``_LockedMetrics`` dataclass: mutations must go
through ``metrics.inc(field=n)``, which takes the metrics lock — a bare
``metrics.requests += 1`` on a shared instance is a lost-update data
race that only shows up as drifting counters under concurrency.

The ct-cache counters (:class:`repro.core.cache.CtCache` hit/miss/
eviction/``delta_updated`` tallies) are locked the same way: the cache
is shared across serving threads, so mutations must go through the
cache's own locked helpers (``count_delta_updates()`` etc.), never a
bare ``cache.delta_updated += 1`` from outside.

This check walks ``src/repro`` and fails on any bare augmented
assignment to an attribute of a ``metrics``- or ``cache``-named
receiver::

    self.metrics.requests += 1        # FAIL: racy lost update
    m.coalesced -= 1                  # FAIL: bare mutation
    cache.delta_updated += 1          # FAIL: unlocked cache counter
    self.metrics.inc(requests=1)      # OK:  locked increment
    cache.count_delta_updates()       # OK:  locked helper
    stats.ct_rows += tab.nnz_rows()   # OK:  CostStats is not locked

The receiver rule is name-based (``metrics`` / ``*_metrics`` / ``m``
bound to a metrics object can't be distinguished statically, so the
check targets the conventional names actually used in the tree:
``metrics``/``cache`` and anything ending in them).  ``repro/serve/
metrics.py`` is exempt from the metrics rule and ``repro/core/cache.py``
from the cache rule — the locks live there.

Exits 1 when any mutation is found.

Run:  python scripts/check_locked_metrics.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src" / "repro"

# `<anything>metrics.<field> +=/-=` — the receiver must be a metrics
# object by naming convention; `.inc(` calls never match (no `=`).
MUTATION_RE = re.compile(
    r"\b[A-Za-z_][A-Za-z0-9_.]*metrics\.[A-Za-z_][A-Za-z0-9_]*\s*[+-]=")

# `<anything>cache.<field> +=/-=` — same convention for the shared
# ct-cache's counters; `.count_delta_updates(` calls never match.
CACHE_MUTATION_RE = re.compile(
    r"\b[A-Za-z_][A-Za-z0-9_.]*cache\.[A-Za-z_][A-Za-z0-9_]*\s*[+-]=")

# the lock implementations themselves (and only they) may touch fields
# directly
RULES = (
    (MUTATION_RE, {SRC / "serve" / "metrics.py"},
     "metrics mutation", "metrics.inc(field=n)"),
    (CACHE_MUTATION_RE, {SRC / "core" / "cache.py"},
     "cache-counter mutation", "the cache's locked helpers "
     "(e.g. cache.count_delta_updates())"),
)


def check_file(path: Path) -> list:
    errors = []
    rules = [(rx, kind, fix) for rx, exempt, kind, fix in RULES
             if path not in exempt]
    if not rules:
        return errors
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        code = line.split("#", 1)[0]
        for rx, kind, fix in rules:
            m = rx.search(code)
            if m:
                errors.append(f"{path.relative_to(ROOT)}:{lineno}: bare "
                              f"{kind} {m.group(0)!r} — use {fix} "
                              f"(locked)")
    return errors


def main() -> int:
    errors = []
    for path in sorted(SRC.rglob("*.py")):
        errors.extend(check_file(path))
    for err in errors:
        print(err, file=sys.stderr)
    if errors:
        print(f"\n{len(errors)} unlocked counter mutation(s)",
              file=sys.stderr)
        return 1
    print(f"locked-metrics check OK "
          f"({sum(1 for _ in SRC.rglob('*.py'))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
