"""Locked-metrics mutation checker (the CI docs job).

Every serving counter (:class:`repro.serve.metrics.ServiceMetrics` /
``RouterMetrics``) is a ``_LockedMetrics`` dataclass: mutations must go
through ``metrics.inc(field=n)``, which takes the metrics lock — a bare
``metrics.requests += 1`` on a shared instance is a lost-update data
race that only shows up as drifting counters under concurrency.

This check walks ``src/repro`` and fails on any bare augmented
assignment to an attribute of a ``metrics``-named receiver::

    self.metrics.requests += 1        # FAIL: racy lost update
    m.coalesced -= 1                  # FAIL: bare mutation
    self.metrics.inc(requests=1)      # OK:  locked increment
    stats.ct_rows += tab.nnz_rows()   # OK:  CostStats is not locked

The receiver rule is name-based (``metrics`` / ``*_metrics`` / ``m``
bound to a metrics object can't be distinguished statically, so the
check targets the conventional names actually used in the tree:
``metrics`` and anything ending in ``metrics``).  ``repro/serve/
metrics.py`` itself is exempt — the lock lives there.

Exits 1 when any mutation is found.

Run:  python scripts/check_locked_metrics.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src" / "repro"

# `<anything>metrics.<field> +=/-=` — the receiver must be a metrics
# object by naming convention; `.inc(` calls never match (no `=`).
MUTATION_RE = re.compile(
    r"\b[A-Za-z_][A-Za-z0-9_.]*metrics\.[A-Za-z_][A-Za-z0-9_]*\s*[+-]=")

# the lock implementation itself (and only it) may touch fields directly
EXEMPT = {SRC / "serve" / "metrics.py"}


def check_file(path: Path) -> list:
    errors = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        code = line.split("#", 1)[0]
        m = MUTATION_RE.search(code)
        if m:
            errors.append(f"{path.relative_to(ROOT)}:{lineno}: bare "
                          f"metrics mutation {m.group(0)!r} — use "
                          f"metrics.inc(field=n) (locked)")
    return errors


def main() -> int:
    errors = []
    for path in sorted(SRC.rglob("*.py")):
        if path in EXEMPT:
            continue
        errors.extend(check_file(path))
    for err in errors:
        print(err, file=sys.stderr)
    if errors:
        print(f"\n{len(errors)} unlocked metrics mutation(s)",
              file=sys.stderr)
        return 1
    print(f"locked-metrics check OK "
          f"({sum(1 for _ in SRC.rglob('*.py'))} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
