"""Render the generated tables of EXPERIMENTS.md from results/*.

Replaces the blocks between <!-- BEGIN:<name> --> / <!-- END:<name> -->
markers with freshly generated markdown.  Run after a dry-run sweep or
benchmark run:  PYTHONPATH=src python scripts/render_experiments.py
"""

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load(dirname):
    recs = []
    for f in sorted((ROOT / dirname).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("ok") and not r.get("tag"):
            recs.append(r)
    return recs


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 0.001:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table():
    recs = load("results/dryrun")
    rows = ["| arch | shape | mesh | chips | temp GB/chip | HLO TF/chip | "
            "coll GB/chip | status |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mem = (r["memory"]["temp_bytes"] or 0) / 1e9
        tf = r["hlo_totals"]["flops"] / 1e12
        cb = r["hlo_totals"]["coll_link_bytes"] / 1e9
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r['chips']} | {mem:.1f} | {tf:.1f} | {cb:.1f} | OK |")
    return "\n".join(rows)


def roofline_table():
    recs = [r for r in load("results/dryrun") if r["mesh"] == "pod16x16"]
    rows = ["| arch | shape | compute | memory | collective | bottleneck | "
            "roofline frac | 6ND/HLO | one-line next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    LEVER = {
        "memory": "cut fusion-boundary traffic (Pallas flash/SSD kernels keep blocks in VMEM)",
        "collective": "reshape collective schedule (EP/SP shard_map, smaller psum payloads)",
        "compute": "raise MXU utilisation (larger blocks, fewer remat passes)",
    }
    for r in recs:
        t = r["roofline"]
        c, m, cl = t["t_compute_s"], t["t_memory_s"], t["t_collective_s"]
        bound = max(c, m, cl)
        frac = c / bound if bound else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(c)} | {fmt_s(m)} | "
            f"{fmt_s(cl)} | {t['bottleneck']} | {frac:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {LEVER[t['bottleneck']]} |")
    return "\n".join(rows)


def counting_tables():
    art = json.loads((ROOT / "results/bench/counting.json").read_text())
    walls = {(r["dataset"], r["strategy"]): r["wall_s"] for r in art["runs"]}
    out = ["**Fig. 3 analogue — ct-construction time decomposition "
           "(seconds; fixed 400-family/point workload).**  `wall` includes "
           "family scoring; PRECOUNT's per-family projections land there "
           "(outside the paper's 3-component split), which is exactly its "
           "search-time cost in the dense-tensor adaptation:", "",
           "| dataset | strategy | metadata | positive ct | negative ct | "
           "3-part total | wall | completed |", "|---|---|---|---|---|---|---|---|"]
    for r in art["fig3_runtime"]:
        w = walls.get((r["dataset"], r["strategy"]), "-")
        out.append(f"| {r['dataset']} | {r['strategy']} | {r['metadata_s']} |"
                   f" {r['positive_s']} | {r['negative_s']} | {r['total_s']} |"
                   f" {w} |"
                   f" {'yes' if r['completed'] else '**TIMEOUT**'} |")
    out += ["", "**Fig. 4 analogue — peak resident ct-cache (MB):**", "",
            "| dataset | PRECOUNT | ONDEMAND | HYBRID |", "|---|---|---|---|"]
    mem = {}
    for r in art["fig4_memory"]:
        mem.setdefault(r["dataset"], {})[r["strategy"]] = r["peak_mb"]
    for ds, m in mem.items():
        out.append(f"| {ds} | {m.get('PRECOUNT','-')} | "
                   f"{m.get('ONDEMAND','-')} | {m.get('HYBRID','-')} |")
    out += ["", "**Table 5 analogue — ct rows, family-level vs global:**", "",
            "| dataset | ct(family) rows (HYBRID) | ct(database) rows "
            "(PRECOUNT) |", "|---|---|---|"]
    for r in art["table5_sizes"]:
        out.append(f"| {r['dataset']} | {r.get('ct_family_rows','-')} | "
                   f"{r.get('ct_database_rows','-')} |")
    if "spotlight_full_scale" in art:
        out += ["", "**Full-scale spotlight (paper's headline — millions of "
                "facts, HYBRID):**", ""]
        for r in art["spotlight_full_scale"]:
            out.append(f"* {r['dataset']}: {r['rows']:,} rows, "
                       f"{r['families']} families scored in {r['wall_s']}s "
                       f"(positive {r['time_positive']}s / Möbius "
                       f"{r['time_negative']}s)")
    if "service_flood" in art:
        out += ["", "**Serve layer — same-signature query flood, per-query "
                "dispatch vs signature-bucketed stacked execution "
                "(CountingService):**", "",
                "| config | executor | mode | queries/s | speedup |",
                "|---|---|---|---|---|"]
        for r in art["service_flood"]:
            sp = r.get("speedup_vs_per_query")
            out.append(f"| {r['config']} | {r['executor']} | {r['mode']} | "
                       f"{r['qps']} | {f'{sp}x' if sp else '-'} |")
    return "\n".join(out)


def main():
    p = ROOT / "EXPERIMENTS.md"
    text = p.read_text()
    for name, gen in (("DRYRUN", dryrun_table), ("ROOFLINE", roofline_table),
                      ("COUNTING", counting_tables)):
        begin, end = f"<!-- BEGIN:{name} -->", f"<!-- END:{name} -->"
        if begin in text:
            block = f"{begin}\n{gen()}\n{end}"
            text = re.sub(re.escape(begin) + ".*?" + re.escape(end), block,
                          text, flags=re.S)
    p.write_text(text)
    print("EXPERIMENTS.md tables rendered")


if __name__ == "__main__":
    main()
