"""Multi-tenant isolation property tests.

* Cache: a flooding tenant can spend the shared budget's slack but can
  NEVER evict a neighbour below its reserved floor.
* Admission: a flood from tenant A is bounded (queue policy) or shed
  (``TenantAdmissionError``) at A's own bound; B's queue is untouched.
* Counts: B's positive counts, complete CTs (all four strategies), and
  discovery output are bit-identical with and without A's flood and
  writes — the noisy-neighbour test.
* Dispatch: cross-tenant batched ``count_many`` equals per-tenant serial
  execution bit-for-bit, and the fused multi-db staging path actually
  engages.
* Stats: per-tenant and aggregate snapshots cover every
  ``ServiceMetrics`` field (deep merge, not top-level-numeric-only).
"""

import dataclasses
import time

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        build_lattice, make_strategy, synth_db)
from repro.core.strategies import STRATEGIES
from repro.serve import (ServiceMetrics, TenantAdmissionError,
                         TenantRegistry, merge_stats_dicts)

att = Attribute


def fleet_schema(n_rels: int = 5):
    """Several same-shape relationships: every tenant's flood is
    stack-compatible with every other's."""
    ents = (EntityType("A", 10, (att("a0", 3), att("a1", 2))),
            EntityType("B", 8, (att("b0", 3),)))
    rels = tuple(Relationship(f"R{i}", "A", "B", (att(f"e{i}", 3),))
                 for i in range(n_rels))
    return Schema(ents, rels)


def fleet_db(schema, seed, edges: int = 24):
    return synth_db(schema, {r.name: edges for r in schema.relationships},
                    seed=seed)


def points(schema, max_len: int = 2):
    return [p for p in build_lattice(schema, max_len) if p.atoms]


def fresh_edges(db, rel, n: int = 2):
    """``n`` (src, dst, attrs) edges NOT yet present in ``db``'s rel."""
    tab = db.relations[rel]
    have = tab.pair_set()
    pairs = [(s, d)
             for s in range(db.entities[tab.type.src].size)
             for d in range(db.entities[tab.type.dst].size)
             if (s, d) not in have][:n]
    assert len(pairs) == n, "relation unexpectedly complete"
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    attrs = {a.name: np.arange(n) % a.card for a in tab.type.attrs}
    return src, dst, attrs


def make_registry(schema, tenants, **tenant_kw):
    """One registry, one db per (tenant_id, seed) pair, shared schema."""
    reg = TenantRegistry(executor="dense")
    for tid, seed in tenants:
        reg.add_tenant(tid, fleet_db(schema, seed), **tenant_kw.get(tid, {}))
    return reg


# ------------------------------------------------------- fused dispatch --

def test_cross_tenant_batched_equals_per_tenant_serial():
    schema = fleet_schema()
    pts = points(schema)
    reg = make_registry(schema, [("a", 0), ("b", 1), ("c", 2)])
    queries = [(tid, p, None) for tid in ("a", "b", "c") for p in pts]
    tabs = reg.count_many(queries)
    # the fused multi-db staging path must actually have engaged
    staged = [k for k in reg.executor._batch_cache
              if isinstance(k, tuple) and k and k[0] == "multi_inputs"]
    assert staged, "cross-tenant dispatch never stacked"
    # per-tenant serial reference on cold registries
    for i, tid in enumerate(("a", "b", "c")):
        ref_reg = make_registry(schema, [(tid, {"a": 0, "b": 1, "c": 2}[tid])])
        svc = ref_reg.tenant(tid).service
        for j, p in enumerate(pts):
            ref = svc.count(p)
            got = tabs[i * len(pts) + j]
            assert got.vars == ref.vars
            assert np.array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))


@pytest.mark.parametrize("strat", sorted(STRATEGIES))
def test_complete_parity_vs_strategy_oracle_under_flood(strat):
    """B's complete CTs through the registry are bit-identical to the
    bare strategy oracle, even while tenant A floods the shared pool."""
    schema = fleet_schema(3)
    pts = points(schema)
    db_b = fleet_db(schema, seed=7)
    oracle = make_strategy(strat)
    oracle.prepare(db_b, pts)
    reg = TenantRegistry(executor="dense")
    reg.add_tenant("a", fleet_db(schema, seed=3))
    reg.add_tenant("b", db_b)
    # noisy neighbour: A floods through the fused path first
    reg.count_many([("a", p, None) for p in pts] * 2)
    for p in pts:
        keep = p.all_ct_vars(schema, include_rind=True)
        got = reg.count_complete("b", p, keep)
        ref = oracle.family_ct(p, keep)
        assert got.vars == ref.vars
        assert np.array_equal(np.asarray(got.counts), np.asarray(ref.counts))


# ------------------------------------------------------- cache isolation --

def test_flood_cannot_evict_neighbour_below_reserved_floor():
    schema = fleet_schema()
    pts = points(schema)
    reg = make_registry(schema, [("a", 0), ("b", 1)])
    # warm B fully, then reserve everything it holds
    for p in pts:
        reg.count("b", p)
    b_warm = reg.cache.tenants_info()["b"]["nbytes"]
    assert b_warm > 0
    reg.set_tenant_budget("b", reserved_bytes=b_warm)
    # now choke the global budget so A's flood MUST evict to fit
    reg.cache.budget_bytes = b_warm + b_warm // 2
    for _ in range(3):
        reg.count_many([("a", p, None) for p in pts])
        reg.tenant("a").service.engine.cache.invalidate()  # re-miss next round
    info = reg.cache.tenants_info()
    assert reg.cache.evictions > 0, "flood produced no cache pressure"
    assert info["b"]["nbytes"] >= b_warm, \
        f'B evicted below its floor: {info["b"]["nbytes"]} < {b_warm}'
    # and B is still served from its warm cache
    hits_before = reg.tenant("b").service.metrics.snapshot()["cache_hits"]
    reg.count("b", pts[0])
    assert (reg.tenant("b").service.metrics.snapshot()["cache_hits"]
            == hits_before + 1)


def test_tenant_cap_evicts_own_lru_not_neighbours():
    schema = fleet_schema()
    pts = points(schema)
    reg = make_registry(schema, [("a", 0), ("b", 1)])
    for p in pts:
        reg.count("b", p)
    b_bytes = reg.cache.tenants_info()["b"]["nbytes"]
    # cap A well below what its flood produces
    reg.set_tenant_budget("a", cap_bytes=max(64, b_bytes // 4))
    reg.count_many([("a", p, None) for p in pts])
    info = reg.cache.tenants_info()
    assert info["a"]["nbytes"] <= max(64, b_bytes // 4) or \
        info["a"]["entries"] <= 1          # one oversize entry may remain
    assert info["b"]["nbytes"] == b_bytes  # neighbour untouched


# ---------------------------------------------------------- admission --

def test_admission_shed_bounds_flooder_and_spares_neighbour():
    schema = fleet_schema()
    pts = points(schema)
    assert len(pts) > 4
    reg = make_registry(schema, [("a", 0), ("b", 1)],
                        a={"admission_max": 3, "admission_policy": "shed"})
    svc_a = reg.tenant("a").service
    svc_b = reg.tenant("b").service
    tickets = []
    with svc_a.defer_drains(), svc_b.defer_drains():
        for p in pts[:3]:
            tickets.append(svc_a.submit(p))
        with pytest.raises(TenantAdmissionError):
            svc_a.submit(pts[3])
        # B is unaffected by A hitting its bound
        for p in pts:
            tickets.append(svc_b.submit(p))
    reg.flush_all()
    for t in tickets:
        assert t.result() is not None
    sa = svc_a.stats()
    assert sa["shed"] >= 1 and sa["admitted"] == 3
    assert svc_b.stats()["shed"] == 0


def test_admission_queue_policy_holds_depth_at_bound():
    schema = fleet_schema()
    pts = points(schema)
    reg = make_registry(schema, [("a", 0)],
                        a={"admission_max": 2, "admission_policy": "queue"})
    svc = reg.tenant("a").service
    tickets = []
    with svc.defer_drains():               # admission still overrides this
        for p in pts:
            tickets.append(svc.submit(p))
            assert svc.pending() <= 2
    svc.flush()
    assert svc.stats()["throttled"] > 0
    # results still correct
    ref = make_registry(schema, [("a", 0)]).tenant("a").service
    for t, p in zip(tickets, pts):
        assert np.array_equal(np.asarray(t.result().counts),
                              np.asarray(ref.count(p).counts))


# --------------------------------------------------------- rate limiting --

def test_token_bucket_refill_with_injected_clock():
    from repro.serve.service import _TokenBucket
    t = [0.0]
    b = _TokenBucket(2, 1.0, clock=lambda: t[0])
    assert b.acquire() == 0.0
    assert b.acquire() == 0.0
    wait = b.acquire()                     # drained: 0.5s until one token
    assert wait == pytest.approx(0.5)
    t[0] += wait
    assert b.acquire() == 0.0              # refilled exactly on schedule


def test_rate_limit_sheds_flooder_and_spares_neighbour():
    """Token-bucket fairness: tenant A over its rate is shed at ITS gate
    while B's identical flood flows, and re-submitting an in-flight or
    cached point costs A no token (only newly admitted work is rated)."""
    schema = fleet_schema()
    pts = points(schema)
    assert len(pts) > 4
    reg = make_registry(schema, [("a", 0), ("b", 1)],
                        a={"rate_limit": (3, 3600.0),
                           "admission_policy": "shed"})
    svc_a = reg.tenant("a").service
    svc_b = reg.tenant("b").service
    tickets = []
    with svc_a.defer_drains(), svc_b.defer_drains():
        for p in pts[:3]:
            tickets.append(svc_a.submit(p))
        with pytest.raises(TenantAdmissionError):
            svc_a.submit(pts[3])
        # coalescing with in-flight work is free: no token burned, no shed
        tickets.append(svc_a.submit(pts[0]))
        # B is unaffected by A exhausting its bucket
        for p in pts:
            tickets.append(svc_b.submit(p))
    reg.flush_all()
    for t in tickets:
        assert t.result() is not None
    # cache hits after the flush are free too
    assert svc_a.count(pts[1]) is not None
    sa, sb = svc_a.stats(), svc_b.stats()
    assert sa["rate_limited"] >= 1 and sa["shed"] >= 1
    assert sa["admitted"] == 3
    assert sb["rate_limited"] == 0 and sb["shed"] == 0


def test_rate_limit_queue_policy_sleeps_then_serves():
    schema = fleet_schema()
    pts = points(schema)
    reg = make_registry(schema, [("a", 0)],
                        a={"rate_limit": (2, 0.25),
                           "admission_policy": "queue"})
    svc = reg.tenant("a").service
    t0 = time.monotonic()
    tickets = [svc.submit(p) for p in pts[:4]]
    waited = time.monotonic() - t0
    svc.flush()
    ref = make_registry(schema, [("a", 0)]).tenant("a").service
    for t, p in zip(tickets, pts):
        assert np.array_equal(np.asarray(t.result().counts),
                              np.asarray(ref.count(p).counts))
    assert svc.stats()["rate_limited"] >= 2   # over-rate submits slept
    assert svc.stats()["shed"] == 0           # ... instead of shedding
    assert waited >= 0.1


# ------------------------------------------------- noisy-neighbour counts --

def test_neighbour_counts_bit_identical_under_flood_and_writes():
    schema = fleet_schema(3)
    pts = points(schema)
    quiet = make_registry(schema, [("b", 7)])
    ref = [quiet.tenant("b").service.count(p) for p in pts]

    noisy = make_registry(schema, [("a", 3), ("b", 7)])
    noisy.count_many([("a", p, None) for p in pts])           # flood
    # A writes — must not move B's versions or invalidate B's cache
    src, dst, attrs = fresh_edges(noisy.tenant("a").db, "R0")
    noisy.apply_delta("a", "R0", src, dst, attrs)
    got = [noisy.count("b", p) for p in pts]
    for g, r in zip(got, ref):
        assert g.vars == r.vars
        assert np.array_equal(np.asarray(g.counts), np.asarray(r.counts))
    # B's cache entries survived A's write (still warm on the repeat)
    hits0 = noisy.tenant("b").service.metrics.snapshot()["cache_hits"]
    for p in pts:
        noisy.count("b", p)
    hits1 = noisy.tenant("b").service.metrics.snapshot()["cache_hits"]
    assert hits1 - hits0 == len(pts)


def test_discovery_shared_memo_is_tenant_disjoint():
    schema = fleet_schema(3)
    reg = make_registry(schema, [("a", 3), ("b", 7)])
    res_b = reg.discovery("b").discover()
    quiet = make_registry(schema, [("b", 7)])
    res_quiet = quiet.discovery("b").discover()
    assert res_b.score == pytest.approx(res_quiet.score, abs=0)
    reg.discovery("a").discover()

    def b_keys():
        return {k for k in reg._score_memo
                if k[0][:2] == ("tenant", "b")}

    keys_before = b_keys()
    assert keys_before, "B's scores not memoized under its tenant token"
    # A's write moves ONLY A's token; B's memo entries survive verbatim
    src, dst, attrs = fresh_edges(reg.tenant("a").db, "R0")
    reg.apply_delta("a", "R0", src, dst, attrs)
    reg.discovery("a").discover()
    assert b_keys() == keys_before
    res_b2 = reg.discovery("b").discover()
    assert res_b2.score == pytest.approx(res_b.score, abs=0)


# ------------------------------------------------------------- stats --

def test_registry_stats_cover_every_service_metrics_field():
    """Satellite bugfix proof: per-tenant AND aggregate snapshots are
    deep-merged — every ServiceMetrics field appears in both (the old
    top-level-numeric aggregation dropped nested dicts)."""
    schema = fleet_schema(3)
    pts = points(schema)
    reg = make_registry(schema, [("a", 0), ("b", 1)])
    reg.count_many([(tid, p, None) for tid in ("a", "b") for p in pts])
    st = reg.stats()
    for tid in ("a", "b"):
        for f in dataclasses.fields(ServiceMetrics):
            if not f.name.startswith("_"):
                assert f.name in st["tenants"][tid], (tid, f.name)
                assert f.name in st["aggregate"], f.name
    # nested dicts merged, not dropped
    assert "cache" in st["aggregate"]
    assert st["aggregate"]["cache"]["hits"] == sum(
        st["tenants"][t]["cache"]["hits"] for t in ("a", "b"))
    assert st["aggregate"]["enqueued"] == sum(
        st["tenants"][t]["enqueued"] for t in ("a", "b"))
    # shared store rollup carries per-tenant residency
    assert set(st["cache"]["tenants"]) >= {"a", "b"}


def test_merge_stats_dicts_semantics():
    a = {"n": 1, "nested": {"x": 2.5, "deep": {"k": 1}}, "name": "a",
         "flag": True}
    b = {"n": 2, "nested": {"x": 1.5, "deep": {"k": 3}, "only_b": 1},
         "name": "b", "flag": False}
    out = merge_stats_dicts([a, b])
    assert out["n"] == 3
    assert out["nested"]["x"] == 4.0
    assert out["nested"]["deep"]["k"] == 4
    assert out["nested"]["only_b"] == 1
    assert out["name"] == "a"              # non-numeric: first wins
    assert out["flag"] is True             # bools are not counters
    assert merge_stats_dicts([]) == {}


def test_default_tenant_shim_unchanged():
    """A bare service is the degenerate single-tenant fleet: tenant
    stamped "default", no admission gate, no tenant cache states."""
    from repro.core import CountingEngine
    from repro.serve import CountingService
    schema = fleet_schema(2)
    svc = CountingService(CountingEngine(fleet_db(schema, 0)))
    st = svc.stats()
    assert st["tenant"] == "default"
    assert st["shed"] == 0 and st["throttled"] == 0
    assert svc.count(points(schema)[0]) is not None
