"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus prefill->decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models.config import ShapeConfig
from repro.models.model import build_model
from repro.launch.specs import train_batch, prefill_batch, decode_batch

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = train_batch(cfg, SMOKE_SHAPE, concrete=True)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0
    logits, aux = jax.jit(model.forward)(params, batch)
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = train_batch(cfg, SMOKE_SHAPE, concrete=True)
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert not bool(jnp.any(jnp.isnan(g.astype(jnp.float32))))
    # at least some nonzero gradient signal
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step at position s must reproduce forward()'s logits at s
    (teacher forcing), for every architecture family."""
    cfg = get_reduced(arch)
    if cfg.is_moe:
        # lossless dispatch: capacity drops are train-time semantics and would
        # (correctly) make full-seq and stepwise paths differ
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    shp = ShapeConfig("c", s, b, "train")
    batch = train_batch(cfg, shp, concrete=True)
    logits_all, _ = jax.jit(model.forward)(params, batch)

    pre = prefill_batch(cfg, ShapeConfig("c", s - 1, b, "prefill"), concrete=True)
    # same inputs, truncated by one position
    for k in ("tokens", "embeds"):
        if k in batch:
            pre[k] = batch[k][:, : s - 1] if k == "tokens" else batch[k][:, : s - 1]
    if "positions" in batch:
        pre["positions"] = batch["positions"][:, :, : s - 1]
    if "frames" in batch:
        pre["frames"] = batch["frames"]
    last_logits, cache = jax.jit(model.prefill)(params, pre)

    # prefill's last-position logits == forward at s-2
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits_all[:, s - 2], np.float32), rtol=2e-2, atol=2e-2)

    # pad cache to length s and decode token s-1
    def pad_seq(a, target, axis):
        padw = [(0, 0)] * a.ndim
        padw[axis] = (0, target - a.shape[axis])
        return jnp.pad(a, padw)

    padded = {}
    for k2, v2 in cache.items():
        if k2 in ("k", "v"):
            padded[k2] = pad_seq(v2, s, 2)
        else:
            padded[k2] = v2
    dec = {"token": batch.get("tokens", jnp.zeros((b, s), jnp.int32))[:, s - 1: s],
           "pos": jnp.asarray(s - 1, jnp.int32)}
    if cfg.embeds_input:
        dec["embed1"] = batch["embeds"][:, s - 1: s]
    logits1, _ = jax.jit(model.decode_step)(params, padded, dec)
    np.testing.assert_allclose(
        np.asarray(logits1, np.float32),
        np.asarray(logits_all[:, s - 1], np.float32), rtol=5e-2, atol=5e-2)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    from repro.configs import get_config
    expect = {"granite-8b": 8e9, "nemotron-4-340b": 340e9,
              "mistral-nemo-12b": 12e9, "qwen2.5-3b": 3e9,
              "qwen3-moe-30b-a3b": 30e9, "arctic-480b": 480e9,
              "qwen2-vl-72b": 72e9, "rwkv6-1.6b": 1.6e9,
              "hymba-1.5b": 1.5e9, "whisper-base": 70e6}
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.8 * target, f"{arch}: {n:.2e} vs {target:.2e}"
