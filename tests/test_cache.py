"""CtCache: byte budget, LRU order, and CostStats accounting.

The Fig. 4 memory proxy (``peak_bytes``) depends on ``cache_bytes`` being
decremented on eviction/drop — these tests pin that contract down.
"""

import numpy as np

from repro.core import CostStats, CtCache
from repro.core.ct import CtTable


def _blob(n_bytes: int) -> np.ndarray:
    return np.zeros(n_bytes // 4, dtype=np.float32)


def test_put_get_and_hit_miss_counts():
    c = CtCache()
    assert c.get("x") is None
    v = _blob(64)
    c.put("x", v)
    assert c.get("x") is v
    assert c.hits == 1 and c.misses == 1
    assert c.nbytes == 64


def test_lru_eviction_under_budget():
    stats = CostStats()
    c = CtCache(budget_bytes=256, stats=stats)
    c.put("a", _blob(128))
    c.put("b", _blob(128))
    assert c.nbytes == 256 and stats.cache_bytes == 256
    c.get("a")                        # refresh a -> b becomes LRU
    c.put("c", _blob(128))            # evicts b
    assert "b" not in c and "a" in c and "c" in c
    assert c.evictions == 1
    assert c.nbytes == 256
    # the satellite fix: cache_bytes decremented on eviction
    assert stats.cache_bytes == 256
    assert stats.peak_bytes == 384    # transiently held a+b+c


def test_oversized_entry_admit_then_drop():
    stats = CostStats()
    c = CtCache(budget_bytes=100, stats=stats)
    c.put("huge", _blob(400))
    assert "huge" not in c and c.dropped == 1
    assert c.nbytes == 0 and stats.cache_bytes == 0
    assert stats.peak_bytes == 400    # residency recorded before the drop


def test_reput_same_key_does_not_double_count():
    stats = CostStats()
    c = CtCache(stats=stats)
    c.put("k", _blob(100))
    c.put("k", _blob(200))
    assert c.nbytes == 200 and stats.cache_bytes == 200
    assert len(c) == 1


def test_ct_table_and_tuple_values_are_charged():
    import jax.numpy as jnp
    c = CtCache()
    t = CtTable((), jnp.asarray(1.0))
    c.put("t", t)
    assert c.nbytes == t.nbytes
    m = jnp.zeros((4, 4))
    c.put("m", (m, ("vars",)))
    assert c.nbytes == t.nbytes + m.nbytes


def test_evict_all_returns_bytes():
    stats = CostStats()
    c = CtCache(stats=stats)
    c.put("a", _blob(64))
    c.put("b", _blob(64))
    c.evict_all()
    assert len(c) == 0 and c.nbytes == 0 and stats.cache_bytes == 0
    assert stats.peak_bytes == 128


def test_info_shape():
    c = CtCache(budget_bytes=10)
    info = c.info()
    assert set(info) >= {"entries", "nbytes", "budget_bytes", "hits",
                         "misses", "evictions", "dropped"}
