"""Per-kernel shape/dtype sweeps vs. the ref.py oracles (interpret mode)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.mobius_kernel import mobius_matrix


# ---------------------------------------------------------------- mobius ---
@pytest.mark.parametrize("k", [1, 2, 3, 4, 6])
@pytest.mark.parametrize("d", [1, 7, 128, 300])
def test_mobius_kernel_matches_ref(k, d):
    rng = np.random.default_rng(k * 100 + d)
    x = jnp.asarray(rng.integers(0, 50, size=(1 << k, d)).astype(np.float32))
    got = ops.mobius(x)
    want = ref.mobius_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_mobius_matrix_is_involution_signed():
    # zeta (superset-sum) matrix is the inverse of the Möbius matrix
    k = 4
    t = mobius_matrix(k)
    zeta = np.abs(t)  # zeta[A,S] = 1 iff S >= A
    np.testing.assert_allclose(t @ zeta, np.eye(1 << k), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 5), seed=st.integers(0, 10_000))
def test_mobius_kernel_property(k, seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 64))
    x = jnp.asarray(rng.uniform(0, 100, size=(1 << k, d)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ops.mobius(x)),
                               np.asarray(ref.mobius_ref(x)), atol=1e-3)


# ------------------------------------------------------------- histogram ---
@pytest.mark.parametrize("n,d,p", [(10, 3, 4), (513, 16, 7), (1000, 129, 300),
                                   (2048, 256, 256)])
def test_hist_kernel_matches_ref(n, d, p):
    rng = np.random.default_rng(n + d + p)
    codes = jnp.asarray(rng.integers(0, p, size=n, dtype=np.int32))
    vals = jnp.asarray(rng.uniform(0, 2, size=(n, d)).astype(np.float32))
    got = ops.segment_hist(codes, vals, p)
    want = ref.segment_hist_ref(codes, vals, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_hist_kernel_drops_negative_codes():
    codes = jnp.asarray(np.array([0, -1, 2, -1], np.int32))
    vals = jnp.ones((4, 5), jnp.float32)
    got = ops.segment_hist(codes, vals, 3)
    want = np.zeros((3, 5), np.float32)
    want[0] = 1.0
    want[2] = 1.0
    np.testing.assert_allclose(np.asarray(got), want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hist_kernel_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 700))
    d = int(rng.integers(1, 40))
    p = int(rng.integers(1, 50))
    codes = jnp.asarray(rng.integers(0, p, size=n, dtype=np.int32))
    vals = jnp.asarray(rng.uniform(-1, 1, size=(n, d)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(ops.segment_hist(codes, vals, p)),
                               np.asarray(ref.segment_hist_ref(codes, vals, p)),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------------ bdeu ---
@pytest.mark.parametrize("q,r", [(1, 2), (3, 4), (100, 3), (600, 7), (1024, 33)])
@pytest.mark.parametrize("ess", [1.0, 10.0])
def test_bdeu_kernel_matches_ref(q, r, ess):
    rng = np.random.default_rng(q * r)
    nijk = jnp.asarray(rng.integers(0, 30, size=(q, r)).astype(np.float32))
    got = ops.bdeu(nijk, ess=ess)
    want = ref.bdeu_ref(nijk, ess, q, r)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-2)


def test_bdeu_kernel_matches_core_scorer():
    from repro.core.bdeu import bdeu_score_2d
    rng = np.random.default_rng(0)
    nijk = jnp.asarray(rng.integers(0, 20, size=(36, 4)).astype(np.float32))
    np.testing.assert_allclose(float(ops.bdeu(nijk, ess=1.0)),
                               float(bdeu_score_2d(nijk, ess=1.0)),
                               rtol=1e-4, atol=1e-2)


# --------------------------------------------------- kernel-in-engine glue ---
def test_mobius_kernel_pluggable_into_complete_ct():
    from repro.core import complete_ct, point_from_rels, CostStats
    from repro.core.strategies import _OnDemandProvider
    from repro.core.oracle import oracle_ct
    from tests.test_counting_core import tiny_db
    db = tiny_db(5)
    point = point_from_rels(db.schema, ["Reg", "RA"])
    from repro.core.variables import Var
    from repro.core import attr_var, rind_var
    keep = (attr_var(Var("s"), "iq", 2), rind_var("Reg"), rind_var("RA"))
    got = complete_ct(point, keep, _OnDemandProvider(db, CostStats()),
                      use_butterfly=True, mobius_fn=ops.mobius_nd)
    want = oracle_ct(db, point, keep)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-3)
