"""Correctness of the counting engine vs. the brute-force grounding oracle."""

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        build_lattice, complete_ct, positive_ct, synth_db,
                        point_from_rels, attr_var, edge_var, rind_var,
                        CostStats, superset_mobius)
from repro.core.oracle import oracle_ct
from repro.core.strategies import _OnDemandProvider

import jax.numpy as jnp


def tiny_db(seed=0):
    att = lambda n, c=2: Attribute(n, c)
    schema = Schema(
        entities=(
            EntityType("s", 5, (att("iq", 2), att("rank", 3))),
            EntityType("c", 4, (att("diff", 2),)),
            EntityType("p", 3, (att("pop", 2),)),
        ),
        relationships=(
            Relationship("Reg", "s", "c", (att("grade", 2),)),
            Relationship("RA", "p", "s", (att("sal", 2),)),
        ),
    )
    return synth_db(schema, {"Reg": 8, "RA": 5}, seed=seed)


def self_rel_db(seed=1):
    att = lambda n, c=2: Attribute(n, c)
    schema = Schema(
        entities=(EntityType("u", 5, (att("g", 2),)),),
        relationships=(Relationship("Fr", "u", "u", ()),),
    )
    return synth_db(schema, {"Fr": 7}, seed=seed)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_positive_ct_single_rel(seed):
    db = tiny_db(seed)
    point = point_from_rels(db.schema, ["Reg"])
    keep = point.all_ct_vars(db.schema, include_rind=False)
    got = positive_ct(db, point, keep)
    want = oracle_ct(db, point, keep, require_positive=True)
    np.testing.assert_allclose(np.asarray(got.counts), want, rtol=0, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 1])
def test_positive_ct_chain(seed):
    db = tiny_db(seed)
    point = point_from_rels(db.schema, ["Reg", "RA"])
    keep = point.all_ct_vars(db.schema, include_rind=False)
    got = positive_ct(db, point, keep)
    want = oracle_ct(db, point, keep, require_positive=True)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-4)


def test_positive_ct_subset_attrs():
    db = tiny_db(0)
    point = point_from_rels(db.schema, ["Reg", "RA"])
    all_vars = point.all_ct_vars(db.schema, include_rind=False)
    keep = (all_vars[0], all_vars[3], all_vars[-1])
    got = positive_ct(db, point, keep)
    want = oracle_ct(db, point, keep, require_positive=True)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_complete_ct_single_rel(seed):
    db = tiny_db(seed)
    point = point_from_rels(db.schema, ["Reg"])
    keep = point.all_ct_vars(db.schema, include_rind=True)
    prov = _OnDemandProvider(db, CostStats())
    got = complete_ct(point, keep, prov)
    want = oracle_ct(db, point, keep)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-3)
    # total must equal the full grounding space
    assert got.total() == pytest.approx(5 * 4)


def test_complete_ct_chain_full():
    db = tiny_db(0)
    point = point_from_rels(db.schema, ["Reg", "RA"])
    keep = point.all_ct_vars(db.schema, include_rind=True)
    prov = _OnDemandProvider(db, CostStats())
    got = complete_ct(point, keep, prov)
    want = oracle_ct(db, point, keep)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-3)
    assert got.total() == pytest.approx(5 * 4 * 3)


def test_complete_ct_family_subsets():
    """Family-style keeps: mixtures of attrs / edge attrs / indicators."""
    db = tiny_db(2)
    sch = db.schema
    point = point_from_rels(sch, ["Reg", "RA"])
    from repro.core.variables import Var
    s, c, p = Var("s"), Var("c"), Var("p")
    cases = [
        (attr_var(s, "iq", 2), rind_var("Reg")),
        (attr_var(c, "diff", 2), rind_var("Reg"), rind_var("RA")),
        (edge_var("Reg", "grade", 2), attr_var(s, "iq", 2)),
        (edge_var("Reg", "grade", 2), rind_var("RA"), attr_var(p, "pop", 2)),
        (edge_var("RA", "sal", 2), edge_var("Reg", "grade", 2)),
    ]
    for keep in cases:
        prov = _OnDemandProvider(db, CostStats())
        got = complete_ct(point, keep, prov)
        want = oracle_ct(db, point, keep)
        np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-3,
                                   err_msg=str([str(v) for v in keep]))


def test_complete_ct_self_relationship():
    db = self_rel_db()
    point = point_from_rels(db.schema, ["Fr"])
    keep = point.all_ct_vars(db.schema, include_rind=True)
    prov = _OnDemandProvider(db, CostStats())
    got = complete_ct(point, keep, prov)
    want = oracle_ct(db, point, keep)
    np.testing.assert_allclose(np.asarray(got.counts), want, atol=1e-3)


def test_butterfly_equals_blockwise():
    db = tiny_db(1)
    point = point_from_rels(db.schema, ["Reg", "RA"])
    from repro.core.variables import Var
    keep = (attr_var(Var("s"), "iq", 2), rind_var("Reg"), rind_var("RA"))
    a = complete_ct(point, keep, _OnDemandProvider(db, CostStats()),
                    use_butterfly=True)
    b = complete_ct(point, keep, _OnDemandProvider(db, CostStats()),
                    use_butterfly=False)
    np.testing.assert_allclose(np.asarray(a.counts), np.asarray(b.counts),
                               atol=1e-3)


def test_superset_mobius_identity():
    # k=1: [*, T] -> [F, T] with F = * - T
    x = jnp.asarray([[10.0, 3.0], [8.0, 8.0]]).T  # axis0: {*,T}
    y = superset_mobius(x, 1)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0] - x[1]))
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(x[1]))


def test_lattice_builds():
    db = tiny_db(0)
    lat = build_lattice(db.schema, 2)
    names = [p.rels for p in lat]
    assert frozenset({"Reg"}) in names and frozenset({"RA"}) in names
    assert frozenset({"Reg", "RA"}) in names  # share the student type
