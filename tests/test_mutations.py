"""Versioned mutable store + delta count maintenance, end to end.

The acceptance properties of the mutations refactor:

* **Interleaving**: after any random interleaving of fact inserts/deletes
  and count queries, every strategy × executor (including the registered
  ``sparse_sharded`` backend and the sharded-database router path)
  returns counts identical to a brute-force oracle evaluated on the
  database state *at query time* — and therefore on the final state.
* **Fine-grained invalidation**: a write to one relationship retains the
  cache entries of every untouched relationship (hit-rate asserted — the
  retained entries serve follow-up queries without recomputation).
* **Delta path**: small deltas refresh positive artefacts in place (exact
  by multilinearity); deltas above the cost threshold drop them instead
  (the post-count fallback).
* **Online rebalancing**: ``CountingRouter.rebalance`` under a concurrent
  query flood loses no queries, and every answer merges to the single-DB
  value before AND after the swap.
* **Asyncio surface**: an ``asyncio.gather`` flood of ``acount`` /
  ``acomplete`` awaiters equals the oracle, batched by the dispatcher.
"""

import asyncio
import itertools
import threading

import numpy as np
import pytest

from repro.core import (CostStats, CountingEngine, build_lattice,
                        complete_ct, make_strategy, shard_database)
from repro.core.engine import OnDemandPositives, key_deps
from repro.core.executors import EXECUTORS
from repro.core.oracle import oracle_ct
from repro.core.strategies import STRATEGIES
from repro.core.variables import LatticePoint
from repro.serve import CountingRouter, CountingService
from tests.test_serve import mixed_db

ALL_COMBOS = list(itertools.product(sorted(STRATEGIES), sorted(EXECUTORS)))


def fresh_pairs(db, rel, k, rng):
    """``k`` random (src, dst) pairs not currently present in ``rel``."""
    tab = db.relations[rel]
    ns = db.entities[tab.type.src].size
    nd = db.entities[tab.type.dst].size
    have = tab.pair_set()
    out = []
    while len(out) < k:
        s, d = int(rng.integers(ns)), int(rng.integers(nd))
        if (s, d) not in have:
            have.add((s, d))
            out.append((s, d))
    return (np.array([p[0] for p in out], np.int32),
            np.array([p[1] for p in out], np.int32))


def random_insert(db, rel, k, rng):
    src, dst = fresh_pairs(db, rel, k, rng)
    attrs = {a.name: rng.integers(0, a.card, size=k).astype(np.int32)
             for a in db.relations[rel].type.attrs}
    return db.insert_facts(rel, src, dst, attrs)


def random_delete(db, rel, k, rng):
    tab = db.relations[rel]
    k = min(k, tab.num_edges)
    if k == 0:
        return None
    pick = rng.choice(tab.num_edges, size=k, replace=False)
    return db.delete_facts(rel, tab.src[pick].copy(), tab.dst[pick].copy())


def random_attr_write(db, etype, k, rng):
    """Overwrite ``k`` random rows of one random attribute of ``etype``."""
    tab = db.entities[etype]
    specs = [a for a in tab.type.attrs]
    if not specs or tab.size == 0:
        return None
    a = specs[int(rng.integers(len(specs)))]
    k = min(k, tab.size)
    rows = rng.choice(tab.size, size=k, replace=False).astype(np.int32)
    vals = rng.integers(0, a.card, size=k).astype(tab.attrs[a.name].dtype)
    return db.update_attrs(etype, rows, {a.name: vals})


# ------------------------------------------------------ versioned store ----

def test_insert_delete_roundtrip_and_versions():
    db = mixed_db()
    rng = np.random.default_rng(0)
    assert db.version == 0
    d = random_insert(db, "R0", 3, rng)
    assert d.op == "insert" and d.num_edges == 3
    assert (d.old_version, d.new_version) == (0, 1) and db.version == 1
    db.validate()
    d2 = db.delete_facts("R0", d.src, d.dst)
    assert d2.op == "delete" and d2.sign == -1
    assert d2.num_edges == 3 and db.version == 2
    # deleted edges carry the attribute values they had
    np.testing.assert_array_equal(d2.attrs["e0"], d.attrs["e0"])
    db.validate()
    # empty batches are no-ops, not version bumps
    assert db.insert_facts("R0", [], [], {"e0": []}) is None
    assert db.version == 2


def test_bad_writes_rejected():
    db = mixed_db()
    tab = db.relations["R0"]
    s0, d0 = int(tab.src[0]), int(tab.dst[0])
    with pytest.raises(ValueError):          # duplicate pair
        db.insert_facts("R0", [s0], [d0], {"e0": [0]})
    with pytest.raises(ValueError):          # missing attr column
        db.insert_facts("R0", [0], [0], None)
    with pytest.raises(ValueError):          # attr out of range
        db.insert_facts("R0", [8], [6], {"e0": [99]})
    with pytest.raises(ValueError):          # index out of range
        db.insert_facts("R0", [1000], [0], {"e0": [0]})
    with pytest.raises(ValueError):          # deleting a missing edge
        db.delete_facts("R1", [1000], [1000])
    assert db.version == 0                   # nothing was applied


def test_delta_view_is_linear():
    """positive(db after) - positive(db before) == positive(delta view):
    the multilinearity the delta path relies on."""
    db = mixed_db()
    rng = np.random.default_rng(1)
    eng = CountingEngine(db, "sparse", CostStats())
    points = [p for p in build_lattice(db.schema, 2) if "R0" in p.rels]
    for p in points:
        before = np.asarray(eng.contract(p, None).counts)
        delta = random_insert(db, "R0", 4, rng)
        after = np.asarray(eng.contract(p, None).counts)
        dtab = eng.executor.positive(delta.as_db(db), eng.plan(p, None))
        np.testing.assert_allclose(after - before, np.asarray(dtab.counts),
                                   atol=1e-3, err_msg=str(p))


# --------------------------------------- interleaving property (tentpole) ----

@pytest.mark.parametrize("sname,ex", ALL_COMBOS)
def test_interleaved_mutations_match_oracle(sname, ex):
    """Random interleavings of inserts/deletes/attribute writes and
    family queries stay oracle-exact for every strategy × executor
    (``sparse_sharded`` runs on the in-process 1-device mesh, exercising
    its delta/local paths)."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    rels = sorted(db.relations)
    etypes = sorted(db.entities)
    points = lattice[:2] + lattice[-2:]
    rng = np.random.default_rng(hash((sname, ex)) % (2 ** 32))
    st = make_strategy(sname, executor=ex)
    st.prepare(db, lattice)

    def check_all():
        for p in points:
            pool = list(p.all_ct_vars(db.schema, include_rind=True))
            pick = rng.choice(len(pool),
                              size=int(rng.integers(1, len(pool) + 1)),
                              replace=False)
            keep = tuple(pool[i] for i in sorted(pick))
            got = st.family_ct(p, keep)
            want = oracle_ct(db, p, keep)
            np.testing.assert_allclose(
                np.asarray(got.counts), want, atol=1e-3,
                err_msg=f"{sname}/{ex} v={db.version} {p} "
                        f"keep={[str(v) for v in keep]}")

    check_all()                                  # warm the caches
    for step in range(7):
        roll = rng.random()
        if roll < 0.25:
            etype = etypes[int(rng.integers(len(etypes)))]
            delta = random_attr_write(db, etype, int(rng.integers(1, 4)),
                                      rng)
        elif roll < 0.6 \
                and db.relations[(rel := rels[int(rng.integers(len(rels)))])
                                 ].num_edges > 3:
            delta = random_delete(db, rel, int(rng.integers(1, 4)), rng)
        else:
            rel = rels[int(rng.integers(len(rels)))]
            delta = random_insert(db, rel, int(rng.integers(1, 4)), rng)
        if delta is not None:
            st.apply_delta(delta)
        if step % 2 == 0:
            check_all()
    check_all()                                  # final state


def test_stale_delta_application_rejected():
    db = mixed_db()
    rng = np.random.default_rng(2)
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, build_lattice(db.schema, 1))
    d1 = random_insert(db, "R0", 2, rng)
    random_insert(db, "R0", 2, rng)              # second, unreconciled write
    with pytest.raises(ValueError):
        st.apply_delta(d1)                       # out of order: cross terms


def test_stale_attr_delta_application_rejected():
    db = mixed_db()
    rng = np.random.default_rng(21)
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, build_lattice(db.schema, 1))
    d1 = random_attr_write(db, "A", 2, rng)
    random_attr_write(db, "A", 2, rng)           # second, unreconciled write
    with pytest.raises(ValueError):
        st.apply_delta(d1)                       # out of order


@pytest.mark.parametrize("sname", sorted(STRATEGIES))
def test_small_delta_retains_or_updates_fam_and_complete(sname):
    """The tentpole acceptance property: after a small fact delta, every
    resident ``"fam"``/``"complete"`` entry is retained (zero-delta
    relation) or updated IN PLACE through the butterfly delta — never
    invalidated — and each is bit-exact against a flush-and-recount on a
    fresh engine over the mutated store."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    rng = np.random.default_rng(hash(sname) % (2 ** 32))
    st = make_strategy(sname, executor="sparse")
    st.prepare(db, lattice)
    for p in lattice:                            # warm the family memos
        st.family_ct(p, tuple(p.all_ct_vars(db.schema, include_rind=True)))
    cache = st.engine.cache
    fam_keys = [k for k in cache.keys_snapshot()
                if k[0] in ("fam", "complete")]
    assert fam_keys
    report = st.apply_delta(random_insert(db, "R0", 2, rng))
    assert report.invalidated == 0, report
    assert report.updated > 0
    # every previously resident family entry is still resident ...
    survivors = set(cache.keys_snapshot())
    assert set(fam_keys) <= survivors
    # ... and bit-exact vs a flush-and-recount on the mutated store
    fresh = make_strategy(sname, executor="sparse")
    fresh.prepare(db, lattice)
    for key in fam_keys:
        point = LatticePoint(key[1])
        keep = tuple(key[2])
        want = fresh.family_ct(point, keep) if key[0] == "fam" \
            else fresh._complete_full(point)
        got = cache.peek(key)
        assert got is not None
        np.testing.assert_allclose(np.asarray(got.counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=f"{sname} {key[0]} {point}")


def test_attr_write_invalidates_only_dependent_entries():
    """An attribute write sweeps exactly the entries whose dependency
    stamps intersect the written ``(etype, attr)`` tags; everything else
    stays resident and oracle-exact afterwards."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, lattice)
    for p in lattice:
        st.family_ct(p, tuple(p.all_ct_vars(db.schema, include_rind=True)))
    cache = st.engine.cache
    before = set(cache.keys_snapshot())
    rows = np.array([0, 1], np.int32)
    a_attr = db.entities["A"].type.attrs[0]
    vals = ((db.entities["A"].attrs[a_attr.name][rows] + 1)
            % a_attr.card).astype(db.entities["A"].attrs[a_attr.name].dtype)
    delta = db.update_attrs("A", rows, {a_attr.name: vals})
    tags = delta.dep_tags()
    report = st.apply_delta(delta)
    assert report.op == "update_attrs"
    after = set(cache.keys_snapshot())
    for key in before:
        deps = key_deps(key)
        if deps is not None and not (deps & tags):
            assert key in after, key             # disjoint deps: retained
        else:
            assert key not in after, key         # dependent: invalidated
    assert report.retained == sum(
        1 for k in before
        if (key_deps(k) is not None and not (key_deps(k)
                                             & tags)))
    for p in lattice:                            # recomputes are exact
        keep = tuple(p.all_ct_vars(db.schema, include_rind=True))
        np.testing.assert_allclose(
            np.asarray(st.family_ct(p, keep).counts),
            oracle_ct(db, p, keep), atol=1e-3, err_msg=str(p))


# ----------------------------------------- fine-grained invalidation ----

def test_untouched_relations_keep_their_cache_entries():
    """A write to R0 must retain every R1/R2 artefact: the follow-up
    queries hit the cache (no new joins), and only R0-dependent entries
    were touched."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    rng = np.random.default_rng(3)
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, lattice)
    untouched = [p for p in lattice if "R0" not in p.rels]
    keeps = {p: tuple(p.all_ct_vars(db.schema, include_rind=True))
             for p in untouched}
    for p in untouched:
        st.family_ct(p, keeps[p])                # warm
    report = st.apply_delta(random_insert(db, "R0", 2, rng))
    assert report.retained > 0
    joins_before = st.stats.joins                # delta-path joins excluded:
    hits_before = st.engine.cache.hits           # only follow-ups measured
    for p in untouched:                          # all served from cache
        got = st.family_ct(p, keeps[p])
        np.testing.assert_allclose(np.asarray(got.counts),
                                   oracle_ct(db, p, keeps[p]), atol=1e-3)
    assert st.stats.joins == joins_before        # zero data access
    assert st.engine.cache.hits > hits_before    # hit-rate: cache served


def test_entries_are_version_and_deps_stamped():
    db = mixed_db()
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, build_lattice(db.schema, 1))
    cache = st.engine.cache
    keys = cache.keys_snapshot()
    assert keys
    for key in keys:
        deps, version = cache.entry_meta(key)
        assert deps == key_deps(key)
        assert version == 0
        if key[0] == "hist":
            # real deps now: the kept attribute columns, never a relation
            # (histograms stay immune to fact deltas)
            assert not any(isinstance(d, str) for d in deps)
            assert all(d[0] == "attr" for d in deps)
        elif key[0] == "full":
            # relation names + the ("attr*", etype) wildcard per pattern
            # variable (full resolution reads every column of each type)
            rels = {d for d in deps if isinstance(d, str)}
            assert rels and rels <= set(db.relations)
            assert all(t[0] == "attr*" for t in deps - rels)
    rng = np.random.default_rng(4)
    st.apply_delta(random_insert(db, "R0", 1, rng))
    updated = [k for k in cache.keys_snapshot()
               if cache.entry_meta(k) and "R0" in (cache.entry_meta(k)[0]
                                                   or ())]
    for k in updated:                            # refreshed under v1
        assert cache.entry_meta(k)[1] == 1


def test_delta_threshold_falls_back_to_invalidation():
    """A delta above max_update_fraction drops the dependent positive
    artefacts instead of updating them — and the next query recomputes
    correctly either way."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 1)
    rng = np.random.default_rng(5)
    st = make_strategy("HYBRID", executor="sparse")
    st.prepare(db, lattice)
    small = st.apply_delta(random_insert(db, "R0", 1, rng))
    assert small.updated > 0 and small.invalidated == 0
    big = st.apply_delta(random_insert(db, "R0", 12, rng),
                         max_update_fraction=0.05)
    assert big.updated == 0 and big.invalidated > 0
    for p in lattice:
        keep = p.all_ct_vars(db.schema, include_rind=True)
        np.testing.assert_allclose(
            np.asarray(st.family_ct(p, keep).counts),
            oracle_ct(db, p, keep), atol=1e-3)


# ------------------------------------------------------- service fence ----

def test_service_apply_delta_fences_and_serves_fresh():
    """Mutations through the service are atomic w.r.t. the query stream:
    concurrent clients always observe a consistent pre- or post-delta
    answer, never a torn one."""
    db = mixed_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=4)
    lattice = build_lattice(db.schema, 2)
    states = {}                                  # version -> oracle answers
    rng = np.random.default_rng(6)

    def snapshot_oracle():
        states[db.version] = {
            p: oracle_ct(db, p, p.all_ct_vars(db.schema,
                                              include_rind=False),
                         require_positive=True)
            for p in lattice}

    snapshot_oracle()
    errors = []
    observations = []                            # validated at the end,
    stop = threading.Event()                     # once every state is known

    def client(seed):
        r = np.random.default_rng(seed)
        while not stop.is_set():
            p = lattice[int(r.integers(len(lattice)))]
            try:
                observations.append((p, np.asarray(svc.count(p).counts)))
            except Exception as e:               # pragma: no cover
                errors.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(3)]
    for t in threads:
        t.start()
    for _ in range(4):
        rel = ("R0", "R1", "R2")[int(rng.integers(3))]
        src, dst = fresh_pairs(db, rel, 2, rng)
        attrs = {a.name: rng.integers(0, a.card, size=2).astype(np.int32)
                 for a in db.relations[rel].type.attrs}
        svc.apply_delta(mutate=lambda: db.insert_facts(rel, src, dst,
                                                       attrs))
        snapshot_oracle()
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:2]
    assert observations
    for p, got in observations:                  # consistent pre- or
        assert any(np.allclose(got, s[p], atol=1e-3)   # post-delta answer,
                   for s in states.values()), (p, got)  # never torn
    assert svc.stats()["deltas"] == 4
    # after the last fence, every fresh query is post-delta exact
    for p in lattice:
        got = np.asarray(svc.count(p).counts)
        np.testing.assert_allclose(got, states[db.version][p], atol=1e-3)


# ------------------------------------------------------- asyncio surface ----

def test_async_flood_matches_oracle():
    db = mixed_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=16, max_wait_s=0.003,
                          dispatcher=True)
    lattice = build_lattice(db.schema, 2)
    want = {p: oracle_ct(db, p, p.all_ct_vars(db.schema,
                                              include_rind=False),
                         require_positive=True)
            for p in lattice}
    cwant = {p: complete_ct(p, p.all_ct_vars(db.schema, include_rind=True),
                            OnDemandPositives(
                                CountingEngine(db, "sparse", CostStats())))
             for p in lattice}

    async def flood():
        pos = [svc.acount(p) for p in lattice * 8]
        com = [svc.acomplete(p) for p in lattice]
        return await asyncio.gather(*(pos + com))

    try:
        tabs = asyncio.run(flood())
    finally:
        svc.shutdown()
    n_pos = len(lattice) * 8
    for p, t in zip(lattice * 8, tabs[:n_pos]):
        np.testing.assert_allclose(np.asarray(t.counts), want[p], atol=1e-3)
    for p, t in zip(lattice, tabs[n_pos:]):
        np.testing.assert_allclose(np.asarray(t.counts),
                                   np.asarray(cwant[p].counts), atol=1e-3)
    snap = svc.stats()
    assert snap["requests"] == n_pos + len(lattice)
    # the dispatcher batched the flood: far fewer dispatches than queries
    assert snap["enqueued"] < snap["requests"]


def test_async_without_dispatcher_falls_back():
    db = mixed_db()
    svc = CountingService(CountingEngine(db, "sparse", CostStats()))
    p = build_lattice(db.schema, 1)[0]

    async def one():
        return await svc.acount(p)

    tab = asyncio.run(one())
    np.testing.assert_allclose(
        np.asarray(tab.counts),
        oracle_ct(db, p, p.all_ct_vars(db.schema, include_rind=False),
                  require_positive=True),
        atol=1e-3)


# --------------------------------------------------- router: writes ----

def _routable_points(sdb, lattice):
    out = []
    for p in lattice:
        try:
            sdb.route(p)
            out.append(p)
        except Exception:
            pass
    return out


def test_router_interleaved_mutations_match_single_db():
    """The router path of the interleaving property: writes through
    CountingRouter.apply_delta keep merged answers == a single-DB engine
    on an identically mutated copy, for inserts AND deletes on
    partitioned and replicated relationships."""
    db = mixed_db()
    ref_db = mixed_db()
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse")
    lattice = build_lattice(db.schema, 2)
    points = _routable_points(sdb, lattice)
    rng = np.random.default_rng(8)
    ref = CountingEngine(ref_db, "sparse", CostStats())
    for step in range(5):
        rel = sorted(db.relations)[int(rng.integers(3))]
        if rng.random() < 0.4 and ref_db.relations[rel].num_edges > 3:
            tab = ref_db.relations[rel]
            pick = rng.choice(tab.num_edges, size=2, replace=False)
            src, dst = tab.src[pick].copy(), tab.dst[pick].copy()
            router.delete_facts(rel, src, dst)
            ref_db.delete_facts(rel, src, dst)
        else:
            src, dst = fresh_pairs(ref_db, rel, 2, rng)
            attrs = {a.name: rng.integers(0, a.card, size=2)
                     .astype(np.int32)
                     for a in ref_db.relations[rel].type.attrs}
            router.insert_facts(rel, src, dst, attrs)
            ref_db.insert_facts(rel, src, dst, attrs)
        for p in points:
            got = router.count(p)
            want = ref.contract(p, None)
            np.testing.assert_allclose(
                np.asarray(got.counts), np.asarray(want.counts), atol=1e-3,
                err_msg=f"step={step} rel={rel} {p}")
    assert router.stats()["router"]["deltas"] == 5


def test_router_complete_ct_matches_single_db():
    """Complete-CT routing: positive fan-out + front-end Möbius equals
    single-database complete_ct, for full and partial keeps."""
    db = mixed_db()
    sdb = shard_database(db, 3)
    router = CountingRouter(sdb, executor="sparse")
    lattice = build_lattice(db.schema, 2)
    points = _routable_points(sdb, lattice)
    ref_eng = CountingEngine(mixed_db(), "sparse", CostStats())
    policy = OnDemandPositives(ref_eng)
    rng = np.random.default_rng(10)
    queries = []
    for p in points:
        pool = list(p.all_ct_vars(db.schema, include_rind=True))
        queries.append((p, None))
        pick = rng.choice(len(pool), size=max(1, len(pool) // 2),
                          replace=False)
        queries.append((p, tuple(pool[i] for i in sorted(pick))))
    tabs = router.complete_many(queries)
    for (p, keep), got in zip(queries, tabs):
        if keep is None:
            keep = p.all_ct_vars(db.schema, include_rind=True)
        want = complete_ct(p, tuple(keep), policy)
        assert got.vars == want.vars
        np.testing.assert_allclose(np.asarray(got.counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=str(p))
    assert router.stats()["router"]["complete_requests"] == len(queries)
    # repeats are served from the router's complete-table cache
    before = router.stats()["aggregate"]["requests"]
    router.count_complete(points[0])
    assert router.stats()["aggregate"]["requests"] == before


def test_router_concurrent_writes_never_tear_merges():
    """Fan-out merges linearize around router writes: under concurrent
    client threads, every merged answer equals SOME version's single-DB
    oracle — never a mix of shard states from both sides of a delta."""
    db = mixed_db()
    ref_db = mixed_db()     # mutated in lock-step: partitioned-relation
    sdb = shard_database(db, 2)   # writes land only in the shard tables
    router = CountingRouter(sdb, executor="sparse", max_batch_size=4)
    lattice = build_lattice(db.schema, 2)
    points = _routable_points(sdb, lattice)
    rng = np.random.default_rng(13)
    states = {}

    def snapshot_oracle():
        states[len(states)] = {
            p: oracle_ct(ref_db, p, p.all_ct_vars(db.schema,
                                                  include_rind=False),
                         require_positive=True) for p in points}

    snapshot_oracle()
    errors, observations = [], []
    stop = threading.Event()

    def client(seed):
        r = np.random.default_rng(seed)
        while not stop.is_set():
            p = points[int(r.integers(len(points)))]
            try:
                observations.append((p,
                                     np.asarray(router.count(p).counts)))
            except Exception as e:               # pragma: no cover
                errors.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(3)]
    for t in threads:
        t.start()
    for _ in range(4):
        rel = sorted(ref_db.relations)[int(rng.integers(3))]
        src, dst = fresh_pairs(ref_db, rel, 2, rng)
        attrs = {a.name: rng.integers(0, a.card, size=2).astype(np.int32)
                 for a in ref_db.relations[rel].type.attrs}
        router.apply_delta(rel, src, dst, attrs)
        ref_db.insert_facts(rel, src, dst, attrs)
        snapshot_oracle()
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:2]
    assert observations
    for p, got in observations:
        assert any(np.allclose(got, s[p], atol=1e-3)
                   for s in states.values()), (p, got)


# --------------------------------------------- router: online rebalancing ----

def test_rebalance_under_concurrent_flood_loses_nothing():
    """Acceptance: rebalance() during a query flood — every query
    resolves (none lost, none erroring) and every answer equals the
    single-DB value; afterwards the new shard set still partitions the
    data exactly."""
    db = mixed_db()
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=4)
    lattice = build_lattice(db.schema, 2)
    points = _routable_points(sdb, lattice)
    eng = CountingEngine(mixed_db(), "sparse", CostStats())
    ref = {p: np.asarray(eng.contract(p, None).counts) for p in points}
    errors = []
    done = []

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(15):
            p = points[int(rng.integers(len(points)))]
            try:
                tab = router.count(p)
                np.testing.assert_allclose(np.asarray(tab.counts), ref[p],
                                           atol=1e-3)
                done.append(1)
            except Exception as e:
                errors.append(e)

    threads = [threading.Thread(target=client, args=(s,))
               for s in range(4)]
    for t in threads:
        t.start()
    first = router.rebalance(0)
    second = router.rebalance(1)
    for t in threads:
        t.join()
    assert not errors, errors[:2]
    assert len(done) == 4 * 15                   # no query lost
    assert (first, second) == (2, 3)
    assert router.n_shards == 4
    assert router.stats()["router"]["rebalances"] == 2
    # partition invariants on the new generation
    new_sdb = router.sdb
    for name in new_sdb.partitioned:
        total = sum(s.relations[name].num_edges for s in new_sdb.shards)
        assert total == db.relations[name].num_edges
    for s in new_sdb.shards:
        s.validate()
    # merged answers unchanged by the re-partitioning
    for p in points:
        np.testing.assert_allclose(np.asarray(router.count(p).counts),
                                   ref[p], atol=1e-3)


def test_rebalance_auto_trigger_and_split_limits():
    db = mixed_db()
    sdb = shard_database(db, 2, n_buckets=4)
    # threshold low enough that the first insert trips a split
    router = CountingRouter(sdb, executor="sparse", rebalance_rows=1)
    rng = np.random.default_rng(11)
    src, dst = fresh_pairs(db, "R0", 3, rng)
    router.insert_facts("R0", src, dst,
                        {"e0": rng.integers(0, 2, size=3).astype(np.int32)})
    assert router.stats()["router"]["rebalances"] >= 1
    assert router.n_shards > 2
    # a shard down to one bucket refuses to split further
    sdb2 = shard_database(mixed_db(), 2, n_buckets=2)
    with pytest.raises(ValueError):
        sdb2.split_shard(0)
