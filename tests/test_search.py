"""Direct edge-case coverage for the hill-climbing structure search
(:mod:`repro.core.search`), which was previously only exercised through
the strategy-parity tests."""

import random

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        build_lattice, discover_model, make_strategy,
                        synth_db)
from repro.core.search import StructureSearch, family_score
from tests.test_counting_core import tiny_db


def _prepared_search(db, max_parents=3, **kw):
    st = make_strategy("ONDEMAND")
    st.prepare(db, build_lattice(db.schema, 2))
    return StructureSearch(db, st, max_parents=max_parents, **kw)


# -- max_parents=0 ------------------------------------------------------------

def test_max_parents_zero_learns_empty_graphs():
    db = tiny_db(0)
    st = make_strategy("ONDEMAND")
    models, _ = discover_model(db, st, max_chain_length=2, max_parents=0)
    assert models
    for m in models.values():
        assert all(len(ps) == 0 for ps in m.parents.values())
        assert m.edges() == []
        assert np.isfinite(m.score)


# -- single-variable lattice points ------------------------------------------

def test_single_variable_point_climbs_without_moves():
    """An attribute-free schema collapses each point to its rind variable
    alone: no legal moves exist, and the climb must still terminate with
    a finite-scored single-node model."""
    schema = Schema(
        entities=(EntityType("u", 4, ()),),
        relationships=(Relationship("Fr", "u", "u", ()),),
    )
    db = synth_db(schema, {"Fr": 5}, seed=0)
    st = make_strategy("ONDEMAND")
    models, _ = discover_model(db, st, max_chain_length=1)
    assert models
    for m in models.values():
        assert len(m.nodes) == 1
        assert m.edges() == []
        assert np.isfinite(m.score)


# -- cardinality-1 domains ---------------------------------------------------

def test_card_one_domain_is_inert():
    """A one-value attribute carries zero information; search must handle
    the degenerate axis (no NaNs from the single-cell N_ijk marginals)."""
    schema = Schema(
        entities=(
            EntityType("s", 5, (Attribute("iq", 2), Attribute("one", 1))),
            EntityType("c", 4, (Attribute("diff", 2),)),
        ),
        relationships=(Relationship("Reg", "s", "c", (Attribute("g", 2),)),),
    )
    db = synth_db(schema, {"Reg": 7}, seed=0)
    st = make_strategy("ONDEMAND")
    models, _ = discover_model(db, st, max_chain_length=1)
    for m in models.values():
        assert np.isfinite(m.score)
        ones = [n for n in m.nodes if "one" in str(n)]
        assert ones, "card-1 variable must still be a node"


# -- _creates_cycle property --------------------------------------------------

def _is_acyclic(parents):
    # Kahn's algorithm over the parent map
    indeg = {n: len(ps) for n, ps in parents.items()}
    children = {n: [] for n in parents}
    for c, ps in parents.items():
        for p in ps:
            children[p].append(c)
    frontier = [n for n, d in indeg.items() if d == 0]
    seen = 0
    while frontier:
        n = frontier.pop()
        seen += 1
        for c in children[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                frontier.append(c)
    return seen == len(parents)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_accepted_moves_keep_dag_acyclic(seed):
    """Property: any add admitted by ``_creates_cycle`` (and any delete)
    keeps the graph a DAG — checked against an independent Kahn's
    topological sort after every accepted mutation."""
    rng = random.Random(seed)
    nodes = list(range(8))
    parents = {n: set() for n in nodes}
    accepted_adds = 0
    for _ in range(400):
        src, dst = rng.sample(nodes, 2)
        if src in parents[dst]:
            parents[dst].remove(src)
        elif not StructureSearch._creates_cycle(parents, src, dst):
            parents[dst].add(src)
            accepted_adds += 1
        assert _is_acyclic(parents), f"cycle after {src}->{dst}"
    assert accepted_adds > 0


def test_creates_cycle_rejects_back_edge():
    # chain 0 -> 1 -> 2 (parents map: child -> {parents})
    parents = {0: set(), 1: {0}, 2: {1}}
    # closing an edge back up the chain would cycle: dst is an ancestor
    # of src, reachable by walking src's parent links
    assert StructureSearch._creates_cycle(parents, 1, 0)
    assert StructureSearch._creates_cycle(parents, 2, 0)
    assert StructureSearch._creates_cycle(parents, 2, 1)
    # a forward shortcut 0 -> 2 creates no cycle
    assert not StructureSearch._creates_cycle(parents, 0, 2)


# -- batched vs. unbatched scoring -------------------------------------------

def test_batched_and_unbatched_scoring_agree():
    db = tiny_db(1)
    lattice = build_lattice(db.schema, 2)
    runs = {}
    for batched in (True, False):
        st = make_strategy("ONDEMAND")
        st.prepare(db, lattice)
        search = StructureSearch(db, st, max_parents=2,
                                 batch_scoring=batched)
        models = search.run(lattice)
        runs[batched] = (search._score_cache, models)
    cache_b, models_b = runs[True]
    cache_u, models_u = runs[False]
    assert cache_b.keys() >= cache_u.keys()      # batching prefetches more
    for fam in cache_u:
        assert cache_b[fam] == pytest.approx(cache_u[fam], abs=1e-3)
    sig = lambda ms: {str(p): sorted((str(c), sorted(map(str, ps)))
                                     for c, ps in m.parents.items())
                      for p, m in ms.items()}
    assert sig(models_b) == sig(models_u)
    for p in models_b:
        assert models_b[p].score == pytest.approx(models_u[p].score,
                                                  abs=1e-3)
    assert runs[True][0] is not runs[False][0]


# -- pow2 padding isolation ---------------------------------------------------

def test_pow2_padding_rows_never_leak_into_scores():
    """``batch_scores`` pads each N_ijk stack to a power-of-two batch to
    stabilise the jit cache; the padded all-zero rows must never leak —
    every cached score must equal the unbatched single-family score."""
    db = tiny_db(2)
    lattice = build_lattice(db.schema, 2)
    st = make_strategy("ONDEMAND")
    st.prepare(db, lattice)
    search = StructureSearch(db, st, batch_scoring=True)
    point = lattice[-1]
    nodes = list(point.all_ct_vars(db.schema, include_rind=True))
    # 3 same-shape families -> padded to 4: the classic leak shape
    child = nodes[0]
    fams = [(child, frozenset([p])) for p in nodes[1:4]]
    search.batch_scores(point, iter(fams))
    assert search.batch_calls >= 1
    for fam_child, fam_parents in fams:
        keep = tuple(sorted(fam_parents)) + (fam_child,)
        tab = st.family_ct(point, keep)
        want = family_score(tab, fam_child, search.ess)
        got = search._score_cache[(fam_child, fam_parents)]
        assert got == pytest.approx(want, abs=1e-3)
    # and zero-score rows were sliced off, not cached under any family
    assert len(search._score_cache) == len(fams)
