"""Property test: every counting strategy × every executor backend is
*semantically identical* — same positive ct-tables and same Möbius-derived
complete (negative-including) ct-tables as a brute-force numpy counter —
on small random ``synth_db`` instances.

Also covers the refactor's acceptance bar: the sparse executor completes
``family_ct`` on ``paper_benchmark_db("IMDb", scale=0.1)`` for the HYBRID
strategy under a 2 GiB cache budget.
"""

import itertools

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        CostStats, CountingEngine, build_lattice,
                        make_strategy, paper_benchmark_db,
                        synth_db)
from repro.core.oracle import oracle_ct
from repro.core.strategies import STRATEGIES
from repro.core.executors import EXECUTORS

ALL_COMBOS = list(itertools.product(sorted(STRATEGIES), sorted(EXECUTORS)))


def random_db(seed: int):
    """Small random schema + data with every shape knob randomised:
    entity sizes, attribute counts/cards, edge-attr presence, self-rels."""
    rng = np.random.default_rng(seed)
    card = lambda: int(rng.integers(2, 4))
    a_attrs = tuple(Attribute(f"a{i}", card())
                    for i in range(int(rng.integers(1, 3))))
    b_attrs = (Attribute("b0", card()),)
    schema_a = EntityType("A", int(rng.integers(4, 7)), a_attrs)
    schema_b = EntityType("B", int(rng.integers(3, 6)), b_attrs)
    r1_attrs = (Attribute("e1", card()),) if rng.random() < 0.7 else ()
    rels = [Relationship("R1", "A", "B", r1_attrs)]
    if rng.random() < 0.5:
        rels.append(Relationship("R2", "B", "A", (Attribute("e2", card()),)))
    else:
        rels.append(Relationship("S", "A", "A", ()))
    schema = Schema((schema_a, schema_b), tuple(rels))
    edges = {r.name: int(rng.integers(3, 10)) for r in rels}
    return synth_db(schema, edges, seed=seed)


def random_keeps(rng, point, schema, n=3):
    """A few random axis subsets: attrs, edge attrs and indicators mixed."""
    pool = list(point.all_ct_vars(schema, include_rind=True))
    keeps = [tuple(pool)]
    for _ in range(n):
        k = rng.integers(1, len(pool) + 1)
        pick = rng.choice(len(pool), size=k, replace=False)
        keeps.append(tuple(pool[i] for i in sorted(pick)))
    return keeps


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_positive_ct_matches_oracle_both_executors(seed):
    db = random_db(seed)
    for point in build_lattice(db.schema, 2):
        keep = point.all_ct_vars(db.schema, include_rind=False)
        want = oracle_ct(db, point, keep, require_positive=True)
        for ex in sorted(EXECUTORS):
            eng = CountingEngine(db, ex, CostStats())
            got = eng.contract(point, keep)
            np.testing.assert_allclose(
                np.asarray(got.counts), want, atol=1e-3,
                err_msg=f"seed={seed} executor={ex} point={point}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_all_strategies_and_executors_match_oracle(seed):
    """Complete (Möbius-negative-including) family tables agree with the
    grounding oracle for every strategy × executor combination."""
    db = random_db(seed)
    rng = np.random.default_rng(seed + 100)
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]                       # largest connected point
    keeps = random_keeps(rng, point, db.schema)
    oracles = [oracle_ct(db, point, keep) for keep in keeps]
    for sname, ex in ALL_COMBOS:
        st = make_strategy(sname, executor=ex)
        st.prepare(db, lattice)
        for keep, want in zip(keeps, oracles):
            got = st.family_ct(point, keep)
            np.testing.assert_allclose(
                np.asarray(got.counts), want, atol=1e-3,
                err_msg=f"seed={seed} {sname}/{ex} "
                        f"keep={[str(v) for v in keep]}")


def test_executors_agree_under_tight_budget():
    """Eviction-forcing budget: results unchanged, accounting coherent."""
    db = random_db(7)
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    keep = point.all_ct_vars(db.schema, include_rind=True)
    ref = None
    for ex in sorted(EXECUTORS):
        st = make_strategy("HYBRID", executor=ex, cache_budget_bytes=4096)
        st.prepare(db, lattice)
        got = st.family_ct(point, keep)
        if ref is None:
            ref = np.asarray(got.counts)
        else:
            np.testing.assert_allclose(np.asarray(got.counts), ref, atol=1e-3)
        cache = st.engine.cache
        assert cache.nbytes <= 4096 or len(cache) <= 1
        assert st.stats.cache_bytes == cache.nbytes
        assert st.stats.peak_bytes >= st.stats.cache_bytes


def test_sparse_hybrid_imdb_scale_under_budget():
    """Acceptance: sparse executor completes family_ct on IMDb at scale 0.1
    for HYBRID under a 2 GiB cache budget."""
    db = paper_benchmark_db("IMDb", seed=0, scale=0.1)
    lattice = build_lattice(db.schema, 2)
    st = make_strategy("HYBRID", executor="sparse",
                       cache_budget_bytes=2 << 30)
    st.prepare(db, lattice)
    point = next(p for p in lattice if p.length == 2)
    sch = db.schema
    nodes = list(point.all_ct_vars(sch, include_rind=True))
    fams = [
        (nodes[0],),
        (nodes[0], nodes[1]),
        (nodes[-1], nodes[0]),                 # rind child axis
        (nodes[3], nodes[-2], nodes[0]),
    ]
    for keep in fams:
        tab = st.family_ct(point, keep)
        assert tab.total() > 0
    assert st.stats.peak_bytes < (2 << 30)
    assert st.stats.cache_bytes == st.engine.cache.nbytes
