"""The Pallas segment-sum kernel (sparse hop scatter-add) vs the jnp
oracle, the backend probe behind every kernel entry point, and the
executor-level kernel route.

Unlike :mod:`tests.test_kernels` (which needs hypothesis and skips when
it is absent), these run everywhere: the segment-sum kernel backs the
sparse executors' innermost hop, so its parity must be part of tier-1.
All kernel executions here use ``interpret=True`` — this container is
CPU-only, which is exactly what :func:`repro.kernels.ops
.default_interpret` resolves to.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


# ------------------------------------------------------------ segment-sum ---
@pytest.mark.parametrize("n,d,p", [(10, 1, 4), (513, 16, 300),
                                   (800, 7, 1000), (2048, 64, 2048)])
def test_segsum_rows_kernel_matches_ref(n, d, p):
    rng = np.random.default_rng(n + d + p)
    # +3: ids past the segment space, like the executor's edge-bucket pads
    seg = jnp.asarray(rng.integers(0, p + 3, size=n, dtype=np.int32))
    rows = jnp.asarray(rng.uniform(0, 2, size=(n, d)).astype(np.float32))
    got = ops.edge_segment_sum(seg, rows, p, interpret=True)
    want = ref.edge_segment_sum_ref(seg, rows, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("n,p", [(10, 4), (513, 300), (4096, 1024)])
def test_segsum_ones_kernel_matches_ref(n, p):
    rng = np.random.default_rng(n + p)
    seg = jnp.asarray(rng.integers(0, p + 3, size=n, dtype=np.int32))
    w = jnp.asarray(rng.uniform(0, 2, size=n).astype(np.float32))
    got = ops.ones_segment_sum(seg, w, p, interpret=True)
    want = ref.ones_segment_sum_ref(seg, w, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_segsum_kernel_drops_padding_and_weights():
    # executor invariant: pad edges scatter to seg == num_segments and
    # the sharded mesh pads carry weight 0 — neither may leak into counts
    seg = jnp.asarray(np.array([0, 3, 1, 3, 2], np.int32))   # 3 == P: pad
    w = jnp.asarray(np.array([1.0, 5.0, 1.0, 5.0, 0.0], np.float32))
    got = ops.ones_segment_sum(seg, w, 3, interpret=True)
    np.testing.assert_allclose(np.asarray(got), [1.0, 1.0, 0.0])


@pytest.mark.parametrize("seed", [0, 7, 42, 1234])
def test_segsum_kernel_random_shapes(seed):
    rng = np.random.default_rng(seed)
    for _ in range(5):
        n = int(rng.integers(1, 600))
        d = int(rng.integers(1, 48))
        p = int(rng.integers(1, 700))
        seg = jnp.asarray(rng.integers(0, p + 2, size=n, dtype=np.int32))
        rows = jnp.asarray(rng.uniform(0, 3, size=(n, d)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(ops.edge_segment_sum(seg, rows, p, interpret=True)),
            np.asarray(ref.edge_segment_sum_ref(seg, rows, p)),
            rtol=1e-5, atol=1e-3,
            err_msg=f"seed={seed} n={n} d={d} p={p}")


# ------------------------------------------------- backend probe / routing ---
def test_default_interpret_probe_and_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    # this container is CPU-only: the probe must choose the interpreter
    assert jax.default_backend() == "cpu"
    assert ops.default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.default_interpret() is False          # forced native
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
    assert ops.default_interpret() is True
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert ops.default_interpret() is True


def test_segsum_kernel_routing_predicate(monkeypatch):
    monkeypatch.delenv("REPRO_SEGSUM_PALLAS", raising=False)
    # CPU default: XLA scatter wins, kernel stays off
    assert ops.segsum_kernel_enabled(256) is False
    monkeypatch.setenv("REPRO_SEGSUM_PALLAS", "1")
    assert ops.segsum_kernel_enabled(256) is True
    # the O(edges x segments) one-hot sweep is always capped
    assert ops.segsum_kernel_enabled(
        ops.SEGSUM_KERNEL_MAX_SEGMENTS + 1) is False
    monkeypatch.setenv("REPRO_SEGSUM_PALLAS", "0")
    assert ops.segsum_kernel_enabled(256) is False


def test_sparse_executor_kernel_route_parity(monkeypatch):
    """Counts through the kernel-backed scatter-add (forced on, interpret
    mode) are bit-identical to the XLA segment-sum path."""
    from repro.core import CostStats, CountingEngine, build_lattice
    from tests.test_counting_core import tiny_db

    db = tiny_db(4)
    points = build_lattice(db.schema, 2)
    monkeypatch.delenv("REPRO_SEGSUM_PALLAS", raising=False)
    eng = CountingEngine(db, "sparse", CostStats())
    want = [np.asarray(eng.contract(p, None).counts) for p in points]
    monkeypatch.setenv("REPRO_SEGSUM_PALLAS", "1")
    eng_k = CountingEngine(db, "sparse", CostStats())
    for p, w in zip(points, want):
        np.testing.assert_array_equal(
            np.asarray(eng_k.contract(p, None).counts), w,
            err_msg=str(p))
