"""TUPLEID — the paper's future-work pre-count variant (tuple-ID
propagation) — must produce identical counts to the other strategies and
never touch edge tables during search."""

import numpy as np

from benchmarks.bench_counting import family_workload
from repro.core.database import paper_benchmark_db
from repro.core.strategies import make_strategy
from repro.core.variables import build_lattice


def test_tupleid_matches_hybrid():
    db = paper_benchmark_db("UW", scale=0.25, seed=3)
    lattice = build_lattice(db.schema, 2)
    h = make_strategy("HYBRID")
    t = make_strategy("TUPLEID")
    h.prepare(db, lattice)
    t.prepare(db, lattice)
    for point, keep in family_workload(db, lattice, per_point=24):
        th = h.family_ct(point, keep)
        tt = t.family_ct(point, keep).transpose_to(th.vars)
        np.testing.assert_allclose(np.asarray(tt.counts),
                                   np.asarray(th.counts),
                                   atol=1e-3, rtol=1e-5)


def test_tupleid_zero_joins_at_search_time():
    db = paper_benchmark_db("MovieLens", scale=0.05, seed=1)
    lattice = build_lattice(db.schema, 2)
    t = make_strategy("TUPLEID")
    t.prepare(db, lattice)
    joins_after_prepare = t.stats.joins
    for point, keep in family_workload(db, lattice, per_point=16):
        t.family_ct(point, keep)
    # tuple-ID propagation: the JOIN count must not grow during search
    assert t.stats.joins == joins_after_prepare
