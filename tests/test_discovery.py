"""Oracle-parity and chaos layer for served/distributed model discovery.

The correctness spine of the discovery service: whatever backend the
hill-climb counts through — bare strategy, batching service, sharded
router — and however the store mutates, the learned model must be
*edge-identical* (and score-identical within fp tolerance) to the local
``StructureSearch`` oracle run on an equivalent store."""

import threading
import time

import numpy as np
import pytest

from repro.core import build_lattice, discover_model, make_strategy
from repro.core.database import shard_database
from repro.core.engine import CountingEngine
from repro.discover import DiscoveryService, models_signature
from repro.serve.router import CountingRouter
from repro.serve.service import CountingService
from tests.test_counting_core import tiny_db
from tests.test_mutations import fresh_pairs

STRATEGIES = ["PRECOUNT", "ONDEMAND", "HYBRID", "TUPLEID"]
SCORE_TOL = 1e-3


def _oracle(db, strategy="ONDEMAND", **kw):
    models, _ = discover_model(db, make_strategy(strategy),
                               max_chain_length=2, **kw)
    return models_signature(models), sum(m.score for m in models.values())


# -- (a) served == local == sharded, all 4 strategies -------------------------

@pytest.fixture(scope="module")
def oracle():
    return _oracle(tiny_db(0))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_local_discovery_matches_oracle_per_strategy(strategy, oracle):
    """DiscoveryService over each bare strategy == plain discover_model."""
    db = tiny_db(0)
    svc = DiscoveryService(make_strategy(strategy), db=db)
    res = svc.discover()
    sig, score = oracle
    assert res.signature() == sig
    assert res.score == pytest.approx(score, abs=SCORE_TOL)
    assert res.restarts == 0
    assert res.families_scored > 0


def test_served_discovery_matches_oracle(oracle):
    db = tiny_db(0)
    csvc = CountingService(CountingEngine(db, "sparse"))
    res = csvc.discovery().discover()
    sig, score = oracle
    assert res.signature() == sig
    assert res.score == pytest.approx(score, abs=SCORE_TOL)
    # entry point memoizes one shared service and surfaces its stats
    assert csvc.discovery() is csvc.discovery()
    assert csvc.stats()["discovery"]["discoveries"] == 1


def test_sharded_router_discovery_matches_oracle(oracle):
    db = tiny_db(0)
    router = CountingRouter(shard_database(db, 2), executor="sparse")
    res = router.discovery().discover()
    sig, score = oracle
    assert res.signature() == sig
    assert res.score == pytest.approx(score, abs=SCORE_TOL)
    assert router.discovery() is router.discovery()
    assert router.stats()["discovery"]["discoveries"] == 1


def test_all_backends_agree_exactly():
    """The three backends' signatures must be mutually identical — the
    strongest form: one assert over all of them at once."""
    db = tiny_db(1)
    results = {}
    results["local"] = DiscoveryService(make_strategy("HYBRID"),
                                        db=db).discover()
    results["served"] = DiscoveryService(
        CountingService(CountingEngine(tiny_db(1), "sparse"))).discover()
    results["sharded"] = DiscoveryService(
        CountingRouter(shard_database(tiny_db(1), 2),
                       executor="sparse")).discover()
    sigs = {k: r.signature() for k, r in results.items()}
    assert sigs["local"] == sigs["served"] == sigs["sharded"]
    scores = [r.score for r in results.values()]
    assert max(scores) - min(scores) < SCORE_TOL


# -- (b) delta refresh: selective, counter-asserted, == full relearn ---------

def _mutate(db, strategy_or_none=None, seed=7):
    """Insert a few not-yet-present Reg edges; returns the FactDelta."""
    rng = np.random.default_rng(seed)
    src, dst = fresh_pairs(db, "Reg", 3, rng)
    delta = db.insert_facts("Reg", src, dst,
                            {"grade": rng.integers(0, 2, size=3)
                             .astype(np.int32)})
    if strategy_or_none is not None:
        strategy_or_none.apply_delta(delta)
    return delta


def test_refresh_matches_fresh_relearn_and_rescans_selectively():
    db = tiny_db(0)
    svc = DiscoveryService(make_strategy("ONDEMAND"), db=db)
    first = svc.discover()

    delta = _mutate(db, svc.provider.strategy)
    report = svc.refresh(delta)

    # the counter assertion: only dependency-intersecting families were
    # re-scored; RA-only families were carried forward untouched
    assert report.changed == frozenset({"Reg"})
    assert report.retained > 0
    assert report.rescored > 0
    assert report.rescored < report.total_families

    # and the refreshed model is bit-identical to learning from scratch
    # on the mutated database
    sig, score = _oracle(db)
    assert report.result.signature() == sig
    assert report.result.score == pytest.approx(score, abs=SCORE_TOL)
    # version token advanced past the pre-delta result's
    assert report.result.version != first.version


def test_refresh_through_served_backend():
    db = tiny_db(0)
    csvc = CountingService(CountingEngine(db, "sparse"))
    dsvc = csvc.discovery()
    dsvc.discover()
    # fenced write through the service; the delta names the relation
    rng = np.random.default_rng(11)
    src, dst = fresh_pairs(csvc.engine.db, "Reg", 2, rng)
    report = csvc.insert_facts("Reg", src, dst,
                               {"grade": rng.integers(0, 2, size=2)
                                .astype(np.int32)})
    rep = dsvc.refresh("Reg")
    assert rep.retained > 0
    assert rep.rescored < rep.total_families
    sig, score = _oracle(csvc.engine.db)
    assert rep.result.signature() == sig
    assert rep.result.score == pytest.approx(score, abs=SCORE_TOL)
    snap = csvc.stats()["discovery"]
    assert snap["refreshes"] == 1
    assert snap["families_retained"] == rep.retained
    assert snap["rescored_hist"]["count"] == 1
    assert report is not None


def test_refresh_on_untouched_relation_rescans_nothing_new():
    """A delta on RA must retain every Reg-only family score."""
    db = tiny_db(0)
    svc = DiscoveryService(make_strategy("ONDEMAND"), db=db)
    svc.discover()
    rng = np.random.default_rng(3)
    src, dst = fresh_pairs(db, "RA", 1, rng)
    delta = db.insert_facts("RA", src, dst,
                            {"sal": rng.integers(0, 2, size=1)
                             .astype(np.int32)})
    svc.provider.strategy.apply_delta(delta)
    rep = svc.refresh(delta)
    assert rep.changed == frozenset({"RA"})
    # every family whose deps are {Reg} alone survived the version bump
    assert rep.retained > 0
    assert rep.rescored < rep.total_families
    sig, score = _oracle(db)
    assert rep.result.signature() == sig


def test_warm_start_refresh_is_selective_and_valid():
    """warm_start=True trades exact relearn-parity for fewer rounds; it
    must still re-score selectively and produce a well-formed model."""
    db = tiny_db(0)
    svc = DiscoveryService(make_strategy("ONDEMAND"), db=db)
    svc.discover()
    delta = _mutate(db, svc.provider.strategy)
    rep = svc.refresh(delta, warm_start=True)
    assert rep.retained > 0
    assert rep.rescored < rep.total_families
    for m in rep.result.models.values():
        assert np.isfinite(m.score)


# -- (c) concurrent searches + write flood ------------------------------------

def test_concurrent_searches_share_cache_and_agree_under_write_flood():
    db = tiny_db(0)
    csvc = CountingService(CountingEngine(db, "sparse"))
    dsvc = csvc.discovery(max_restarts=500)
    dsvc.discover()                      # warm the CT cache + score memo

    stop_writes = threading.Event()
    mid_results, finals, errors = [], {}, []

    def writer():
        rng = np.random.default_rng(23)
        try:
            for i in range(5):
                src, dst = fresh_pairs(csvc.engine.db, "Reg", 1, rng)
                csvc.insert_facts("Reg", src, dst,
                                  {"grade": rng.integers(0, 2, size=1)
                                   .astype(np.int32)})
                time.sleep(0.05)
        except Exception as e:            # pragma: no cover - debug aid
            errors.append(e)
        finally:
            stop_writes.set()

    def searcher(name):
        try:
            while not stop_writes.is_set():
                mid_results.append(dsvc.discover())
            finals[name] = dsvc.discover()
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=writer)]
    threads += [threading.Thread(target=searcher, args=(f"s{i}",))
                for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    # both clients converged on the same final model of the same version
    a, b = finals["s0"], finals["s1"]
    assert a.version == b.version
    assert a.signature() == b.signature()
    assert a.score == pytest.approx(b.score, abs=SCORE_TOL)

    # ... which equals a from-scratch local relearn of the final store
    sig, score = _oracle(csvc.engine.db)
    assert a.signature() == sig
    assert a.score == pytest.approx(score, abs=SCORE_TOL)

    # every mid-flight result is internally consistent: any result minted
    # at the final version must be the final model (no torn mixes)
    for r in mid_results:
        if r.version == a.version:
            assert r.signature() == a.signature()

    # no torn counts: family tables read after quiesce are non-negative
    # integers (a torn pre/post-delta merge would leave fractional or
    # negative cells)
    lattice = build_lattice(csvc.engine.db.schema, 2)
    point = lattice[-1]
    keep = tuple(point.all_ct_vars(csvc.engine.db.schema,
                                   include_rind=True))[:3]
    tab = csvc.count_complete(point, keep)
    arr = np.asarray(tab.counts)
    assert (arr >= -1e-4).all()
    np.testing.assert_allclose(arr, np.round(arr), atol=1e-3)

    # the shared memo actually served both clients: warm discovers on a
    # quiesced store do no fresh scoring at all
    before = dsvc.metrics.snapshot()["families_scored"]
    again = dsvc.discover()
    assert again.signature() == a.signature()
    assert dsvc.metrics.snapshot()["families_scored"] == before
