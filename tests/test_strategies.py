"""The three caching strategies must be *semantically identical* — same
family ct-tables, same learned model — differing only in cost profile."""

import numpy as np
import pytest

from repro.core import (build_lattice, discover_model, make_strategy,
                        point_from_rels, attr_var, edge_var, rind_var)
from repro.core.variables import Var
from tests.test_counting_core import tiny_db


@pytest.fixture(scope="module")
def db():
    return tiny_db(3)


def all_family_keeps(db):
    sch = db.schema
    point = point_from_rels(sch, ["Reg", "RA"])
    s, c, p = Var("s"), Var("c"), Var("p")
    return point, [
        (attr_var(s, "iq", 2),),
        (attr_var(s, "iq", 2), rind_var("Reg")),
        (edge_var("Reg", "grade", 2), attr_var(c, "diff", 2), rind_var("RA")),
        (attr_var(p, "pop", 2), edge_var("RA", "sal", 2), attr_var(s, "rank", 3)),
    ]


def test_strategies_agree_on_family_cts(db):
    lattice = build_lattice(db.schema, 2)
    point, keeps = all_family_keeps(db)
    tabs = {}
    for name in ("PRECOUNT", "ONDEMAND", "HYBRID"):
        st = make_strategy(name)
        st.prepare(db, lattice)
        tabs[name] = [st.family_ct(point, k) for k in keeps]
    for i in range(len(keeps)):
        a = np.asarray(tabs["PRECOUNT"][i].counts)
        b = np.asarray(tabs["ONDEMAND"][i].counts)
        c = np.asarray(tabs["HYBRID"][i].counts)
        np.testing.assert_allclose(a, b, atol=1e-3)
        np.testing.assert_allclose(a, c, atol=1e-3)


def test_counts_are_nonnegative_integers(db):
    lattice = build_lattice(db.schema, 2)
    st = make_strategy("HYBRID")
    st.prepare(db, lattice)
    point, keeps = all_family_keeps(db)
    for k in keeps:
        t = st.family_ct(point, k)
        arr = np.asarray(t.counts)
        assert (arr >= -1e-4).all(), "Möbius join produced negative counts"
        np.testing.assert_allclose(arr, np.round(arr), atol=1e-3)


def test_discovery_same_model_all_strategies(db):
    results = {}
    for name in ("PRECOUNT", "ONDEMAND", "HYBRID"):
        st = make_strategy(name)
        models, st = discover_model(db, st, max_chain_length=2, max_parents=2)
        results[name] = {str(p): sorted((str(c), sorted(map(str, ps)))
                                        for c, ps in m.parents.items())
                         for p, m in models.items()}
        # scores finite
        assert all(np.isfinite(m.score) for m in models.values())
    assert results["PRECOUNT"] == results["ONDEMAND"] == results["HYBRID"]


def test_cost_profiles_match_paper_directionality(db):
    """ONDEMAND re-runs joins during search; PRECOUNT/HYBRID join only in
    prepare(). PRECOUNT caches the largest tables (Fig. 4)."""
    lattice = build_lattice(db.schema, 2)
    point, keeps = all_family_keeps(db)
    stats = {}
    for name in ("PRECOUNT", "ONDEMAND", "HYBRID"):
        st = make_strategy(name)
        st.prepare(db, lattice)
        joins_before = st.stats.joins
        for k in keeps:
            st.family_ct(point, k)
        stats[name] = (joins_before, st.stats.joins - joins_before,
                       st.stats.peak_bytes)
    # search-phase joins: ONDEMAND > 0; HYBRID and PRECOUNT == 0
    assert stats["ONDEMAND"][1] > 0
    assert stats["HYBRID"][1] == 0
    assert stats["PRECOUNT"][1] == 0
    # prepare-phase joins happen for PRECOUNT/HYBRID
    assert stats["PRECOUNT"][0] > 0 and stats["HYBRID"][0] > 0
    # memory: PRECOUNT >= HYBRID (it additionally stores complete tables)
    assert stats["PRECOUNT"][2] >= stats["HYBRID"][2]


def test_planted_dependency_recovered(db):
    """The generator plants edge-attr <- endpoint-attr dependencies; the
    learned model should contain at least one edge into an edge attribute."""
    st = make_strategy("HYBRID")
    models, _ = discover_model(db, st, max_chain_length=1, max_parents=2)
    found = False
    for m in models.values():
        for child, ps in m.parents.items():
            if child.kind == "edge" and len(ps) > 0:
                found = True
    assert found
