"""Counting-service tests.

* Property: ct-tables fetched through the batched serve path
  (``family_ct_many`` / ``CountingService``) are identical to per-query
  ``family_ct`` answers for all four strategies × both executors.
* Scheduler: a mixed-signature query flood under a tight cache budget
  still produces correct per-query results (eviction-safe batching).
* Executor layer: ``positive_batch`` equals ``positive`` bit-for-bit and
  stacks what it can; the service's knobs (max batch size, coalescing,
  cache short-circuit, backpressure) behave as documented, including
  under concurrent client threads.
"""

import itertools
import threading
import time

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        CostStats, CountingEngine, build_lattice,
                        complete_ct, make_strategy, synth_db)
from repro.core.engine import OnDemandPositives
from repro.core.executors import EXECUTORS, plan_stack_key
from repro.core.plan import compile_plan, group_by_signature
from repro.core.strategies import STRATEGIES
from repro.serve import CountingService, ServiceMetrics, ServiceShutdown

att = Attribute
ALL_COMBOS = list(itertools.product(sorted(STRATEGIES), sorted(EXECUTORS)))


def flood_db(n_rels: int = 5, edges: int = 24, seed: int = 0):
    """Several same-shape relationships -> stack-compatible plan floods."""
    ents = (EntityType("A", 10, (att("a0", 3), att("a1", 2))),
            EntityType("B", 8, (att("b0", 3),)))
    rels = tuple(Relationship(f"R{i}", "A", "B", (att(f"e{i}", 3),))
                 for i in range(n_rels))
    schema = Schema(ents, rels)
    return synth_db(schema, {f"R{i}": edges for i in range(n_rels)},
                    seed=seed)


def mixed_db(seed: int = 0):
    """Heterogeneous shapes -> a mixed-signature workload."""
    ents = (EntityType("A", 9, (att("a0", 3), att("a1", 2))),
            EntityType("B", 7, (att("b0", 4),)),
            EntityType("C", 6, (att("c0", 2),)))
    rels = (Relationship("R0", "A", "B", (att("e0", 2),)),
            Relationship("R1", "B", "C", ()),
            Relationship("R2", "A", "C", (att("e2", 3),)))
    schema = Schema(ents, rels)
    return synth_db(schema, {"R0": 14, "R1": 11, "R2": 9}, seed=seed)


# ---------------------------------------------------------------- executor --

@pytest.mark.parametrize("ex", sorted(EXECUTORS))
def test_positive_batch_identical_to_positive(ex):
    db = flood_db()
    plans = [compile_plan(db.schema, p) for p in build_lattice(db.schema, 1)]
    assert len({plan_stack_key(db, p) for p in plans}) == 1  # stackable
    eng = CountingEngine(db, ex, CostStats())
    want = [eng.executor.positive(db, p) for p in plans]
    got = eng.executor.positive_batch(db, plans, CostStats())
    for w, g in zip(want, got):
        assert w.vars == g.vars
        np.testing.assert_array_equal(np.asarray(w.counts),
                                      np.asarray(g.counts))


@pytest.mark.parametrize("ex", sorted(EXECUTORS))
def test_positive_batch_mixed_signatures(ex):
    db = mixed_db()
    points = build_lattice(db.schema, 2)
    plans = [compile_plan(db.schema, p) for p in points]
    assert len(group_by_signature(plans, key="shape")) > 1
    eng = CountingEngine(db, ex, CostStats())
    want = [eng.executor.positive(db, p) for p in plans]
    got = eng.executor.positive_batch(db, plans, CostStats())
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w.counts),
                                      np.asarray(g.counts))


def test_batch_join_accounting_matches_unbatched():
    db = flood_db()
    plans = [compile_plan(db.schema, p) for p in build_lattice(db.schema, 1)]
    eng = CountingEngine(db, "sparse", CostStats())
    st_ref = CostStats()
    for p in plans:
        eng.executor.positive(db, p, st_ref)
    st_batch = CostStats()
    eng.executor.positive_batch(db, plans, st_batch)
    assert st_batch.joins == st_ref.joins
    assert st_batch.rows_scanned == st_ref.rows_scanned


# -------------------------------------------------------- property: service --

@pytest.mark.parametrize("sname,ex", ALL_COMBOS)
def test_family_ct_many_equals_family_ct(sname, ex):
    """Batched answers equal per-query family_ct answers for all four
    strategies × both executors."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    pool = list(point.all_ct_vars(db.schema, include_rind=True))
    rng = np.random.default_rng(7)
    keeps = [tuple(pool)]
    for _ in range(5):
        k = rng.integers(1, len(pool) + 1)
        pick = rng.choice(len(pool), size=k, replace=False)
        keeps.append(tuple(pool[i] for i in sorted(pick)))

    ref = make_strategy(sname, executor=ex)
    ref.prepare(db, lattice)
    want = [ref.family_ct(point, keep) for keep in keeps]

    st = make_strategy(sname, executor=ex)
    st.prepare(db, lattice)
    got = st.family_ct_many(point, keeps)
    for keep, w, g in zip(keeps, want, got):
        assert w.vars == g.vars
        np.testing.assert_allclose(
            np.asarray(g.counts), np.asarray(w.counts), atol=1e-3,
            err_msg=f"{sname}/{ex} keep={[str(v) for v in keep]}")


@pytest.mark.parametrize("ex", sorted(EXECUTORS))
def test_mixed_signature_flood_under_tight_budget(ex):
    """Scheduler correctness: a mixed-signature flood against a cache too
    small to hold the working set still answers every query correctly."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    pool = list(point.all_ct_vars(db.schema, include_rind=True))
    rng = np.random.default_rng(3)
    keeps = []
    for _ in range(12):
        k = rng.integers(1, len(pool) + 1)
        pick = rng.choice(len(pool), size=k, replace=False)
        keeps.append(tuple(pool[i] for i in sorted(pick)))

    ref = make_strategy("ONDEMAND", executor=ex)
    ref.prepare(db, lattice)
    want = [np.asarray(ref.family_ct(point, k).counts) for k in keeps]

    st = make_strategy("ONDEMAND", executor=ex, cache_budget_bytes=4096)
    st.prepare(db, lattice)
    got = st.family_ct_many(point, keeps)
    for keep, w, g in zip(keeps, want, got):
        np.testing.assert_allclose(np.asarray(g.counts), w, atol=1e-3,
                                   err_msg=f"{ex} keep={[str(v) for v in keep]}")
    cache = st.engine.cache
    assert cache.nbytes <= 4096 or len(cache) <= 1
    assert st.stats.cache_bytes == cache.nbytes


# ------------------------------------------------------------- scheduler ----

def test_service_cache_short_circuit_and_coalescing():
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=16)
    points = build_lattice(db.schema, 1)
    t1 = svc.submit(points[0])
    t2 = svc.submit(points[0])          # identical in-flight -> coalesced
    svc.flush()
    np.testing.assert_array_equal(np.asarray(t1.result().counts),
                                  np.asarray(t2.result().counts))
    assert svc.metrics.coalesced == 1
    t3 = svc.submit(points[0])          # now resident -> short-circuit
    assert t3.done
    assert svc.metrics.cache_hits == 1


def test_service_sink_and_client_coalesce_still_caches():
    """A client coalescing onto an in-flight sink submission (policy
    prefetch) must still get the result cached under the client key."""
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=16)
    point = build_lattice(db.schema, 1)[0]
    absorbed = []
    svc.submit(point, None, sink=lambda p, k, tab: absorbed.append(tab))
    t = svc.submit(point, None)               # client rides the same entry
    svc.flush()
    assert len(absorbed) == 1                 # the sink got its copy
    keep = eng.plan(point, None).keep
    key = ("pos", eng.executor.name, point.atoms, keep)
    assert key in eng.cache                   # …and the client key is warm
    t2 = svc.submit(point, None)
    assert t2.done and svc.metrics.cache_hits == 1
    np.testing.assert_array_equal(np.asarray(t.result().counts),
                                  np.asarray(t2.result().counts))


def test_rows_counted_shared_between_service_and_policy():
    """ct_rows accounting is per distinct artefact even when the service
    and a policy compute the same key (engine-level rows_counted set)."""
    from repro.core.engine import OnDemandPositives
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng)
    point = build_lattice(db.schema, 1)[0]
    keep = eng.plan(point, None).keep
    svc.count(point, keep)
    rows_after_service = eng.stats.ct_rows
    assert rows_after_service > 0
    eng.cache.evict_all()                     # force the policy to recompute
    OnDemandPositives(eng).positive(point, keep)
    assert eng.stats.ct_rows == rows_after_service


def test_service_size_trigger_dispatches_bucket():
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=3)
    points = build_lattice(db.schema, 1)      # 5 same-signature queries
    tickets = [svc.submit(p) for p in points]
    assert svc.metrics.size_flushes >= 1      # fired at the 3rd submit
    assert svc.pending() < len(points)
    svc.flush()
    for p, t in zip(points, tickets):
        ref = eng.executor.positive(db, eng.plan(p, None))
        np.testing.assert_array_equal(np.asarray(t.result().counts),
                                      np.asarray(ref.counts))


def test_service_backpressure_bounds_queue():
    db = mixed_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=64, max_in_flight=2)
    for p in build_lattice(db.schema, 2):
        svc.submit(p)
    assert svc.pending() <= 2
    assert svc.metrics.backpressure_flushes >= 1
    svc.flush()


def test_service_concurrent_clients():
    """Several client threads flooding one service get correct answers."""
    db = mixed_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=4)
    points = build_lattice(db.schema, 2)
    ref = {p: np.asarray(CountingEngine(db, "sparse", CostStats())
                         .contract(p, None).counts) for p in points}
    errors = []

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(6):
            p = points[int(rng.integers(len(points)))]
            try:
                tab = svc.count(p)
                np.testing.assert_array_equal(np.asarray(tab.counts), ref[p])
            except Exception as e:          # surface in the main thread
                errors.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    snap = svc.stats()
    assert snap["requests"] == 24
    assert snap["cache"]["hits"] >= 1       # repeats served from the cache


@pytest.mark.parametrize("use_butterfly", [True, False])
def test_positive_queries_predicts_complete_ct_requests(use_butterfly):
    """The prefetch enumeration must stay in lockstep with what
    complete_ct actually requests from its provider — a misprediction
    doesn't break correctness (family_ct recomputes) but silently turns
    the batched prefetch into wasted double work, so drift fails here."""
    from repro.core import CtVar, complete_ct, positive_queries
    from repro.core.engine import OnDemandPositives

    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    pool = list(point.all_ct_vars(db.schema, include_rind=True))
    rng = np.random.default_rng(11)
    keeps = [tuple(pool), ()]
    for _ in range(6):
        k = rng.integers(1, len(pool) + 1)
        pick = rng.choice(len(pool), size=k, replace=False)
        keeps.append(tuple(pool[i] for i in sorted(pick)))

    for keep in keeps:
        eng = CountingEngine(db, "sparse", CostStats())
        policy = OnDemandPositives(eng)
        recorded = []

        class Recorder:
            def positive(self, p, k):
                recorded.append((p.atoms, tuple(k)))
                return policy.positive(p, k)

            def hist(self, var, k):
                return policy.hist(var, k)

        complete_ct(point, keep, Recorder(), use_butterfly=use_butterfly)
        predicted = sorted((p.atoms, tuple(k))
                           for p, k in positive_queries(point, keep,
                                                        use_butterfly))
        assert sorted(recorded) == predicted, \
            f"butterfly={use_butterfly} keep={[str(v) for v in keep]}"


# ------------------------------------------------- complete-CT serving ----

@pytest.mark.parametrize("ex", sorted(EXECUTORS))
def test_service_complete_many_matches_complete_ct(ex):
    """Complete-CT queries through the service (batched positive AND
    negative phases) == per-query complete_ct."""
    db = mixed_db()
    eng = CountingEngine(db, ex, CostStats())
    svc = CountingService(eng, max_batch_size=16)
    lattice = build_lattice(db.schema, 2)
    queries = [(p, None) for p in lattice]
    tabs = svc.complete_many(queries)
    ref = OnDemandPositives(CountingEngine(db, ex, CostStats()))
    for (p, _), tab in zip(queries, tabs):
        keep = tuple(p.all_ct_vars(db.schema, include_rind=True))
        want = complete_ct(p, keep, ref)
        assert tab.vars == want.vars
        np.testing.assert_allclose(np.asarray(tab.counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=f"{ex} {p}")
    snap = svc.stats()
    assert snap["complete_requests"] == len(queries)
    assert snap["requests"] == len(queries)


def test_service_complete_flood_batches_negative_phase():
    """A same-signature complete-CT flood runs ONE batched transform
    dispatch, not one per family."""
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=16)
    points = build_lattice(db.schema, 1)       # 5 same-shape k=1 queries
    # attr + indicator axes (a kept edge-attr axis would force the
    # blockwise fallback — that is complete_ct semantics, not batching's)
    keeps = [tuple(v for v in p.all_ct_vars(db.schema, include_rind=True)
                   if v.kind != "edge") for p in points]
    tabs = svc.complete_many(list(zip(points, keeps)))
    snap = svc.stats()
    assert snap["mobius_batches"] == 1
    assert snap["mobius_stacked"] == len(points)
    ref = OnDemandPositives(CountingEngine(db, "sparse", CostStats()))
    for p, keep, tab in zip(points, keeps, tabs):
        want = complete_ct(p, keep, ref)
        np.testing.assert_allclose(np.asarray(tab.counts),
                                   np.asarray(want.counts), atol=1e-3)
    # resident now: a repeat short-circuits on the family cache
    t = svc.submit_complete(points[0], keeps[0])
    assert t.done and svc.metrics.cache_hits >= 1


def test_service_complete_coalesces_and_buckets_separately():
    """Identical in-flight complete queries coalesce; complete and
    positive queries with the same point never share a bucket."""
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=16)
    point = build_lattice(db.schema, 1)[0]
    keep = tuple(point.all_ct_vars(db.schema, include_rind=False))
    c1 = svc.submit_complete(point, keep)
    c2 = svc.submit_complete(point, keep)      # identical -> coalesced
    p1 = svc.submit(point, keep)               # same axes, positive query
    assert svc.metrics.coalesced == 1
    assert svc.pending() == 2                  # complete + positive entries
    svc.flush()
    np.testing.assert_array_equal(np.asarray(c1.result().counts),
                                  np.asarray(c2.result().counts))
    # k=0 complete over attrs-only axes counts ALL groundings (the
    # indicator is summed out), not just the positive ones
    assert c1.result().total() >= p1.result().total()


# --------------------------------------------------- dispatcher thread ----

def test_dispatcher_fires_max_wait_without_submit():
    """Acceptance: max_wait_s fires with NO subsequent submit — the
    dispatcher thread drains the queue on its own."""
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=64, max_wait_s=0.05,
                          dispatcher=True)
    try:
        assert svc.running
        ticket = svc.submit(build_lattice(db.schema, 1)[0])
        assert not ticket.done                 # below every other trigger
        deadline = time.perf_counter() + 5.0
        while not ticket.done and time.perf_counter() < deadline:
            time.sleep(0.005)                  # NO submit, NO flush
        assert ticket.done, "dispatcher never fired the max_wait deadline"
        assert svc.metrics.wait_flushes >= 1
        ref = eng.executor.positive(db, eng.plan(
            build_lattice(db.schema, 1)[0], None))
        np.testing.assert_array_equal(np.asarray(ticket.result().counts),
                                      np.asarray(ref.counts))
    finally:
        svc.shutdown()
    assert not svc.running


def test_dispatcher_start_idempotent_and_rearms_on_submit():
    db = flood_db()
    svc = CountingService(CountingEngine(db, "sparse", CostStats()),
                          max_wait_s=0.02)
    try:
        svc.start()
        first = svc._dispatcher_thread
        assert svc.start() is svc              # idempotent
        assert svc._dispatcher_thread is first
        points = build_lattice(db.schema, 1)
        tickets = [svc.submit(p) for p in points[:2]]
        deadline = time.perf_counter() + 5.0
        while (not all(t.done for t in tickets)
               and time.perf_counter() < deadline):
            time.sleep(0.005)
        assert all(t.done for t in tickets)
    finally:
        svc.shutdown()


def test_dispatcher_survives_failed_batch():
    """A batch that raises (e.g. a client sink throws) fails its own
    waiters but must NOT kill the dispatcher thread."""
    db = flood_db()
    svc = CountingService(CountingEngine(db, "sparse", CostStats()),
                          max_wait_s=0.02, dispatcher=True)
    try:
        points = build_lattice(db.schema, 1)
        boom = svc.submit(points[0], None,
                          sink=lambda p, k, t: (_ for _ in ()).throw(
                              RuntimeError("sink boom")))
        deadline = time.perf_counter() + 5.0
        while not boom.done and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert boom.done
        with pytest.raises(RuntimeError, match="sink boom"):
            boom.result(timeout=1.0)
        assert svc.running                     # the dispatcher survived …
        ok = svc.submit(points[1])
        deadline = time.perf_counter() + 5.0
        while not ok.done and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert ok.done                         # … and still fires deadlines
    finally:
        svc.shutdown()


# ------------------------------------------------------------ shutdown ----

def test_shutdown_drains_pending_waiters():
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=64, max_wait_s=10.0,
                          dispatcher=True)
    points = build_lattice(db.schema, 1)
    tickets = [svc.submit(p) for p in points]
    assert svc.pending() == len(points)
    svc.shutdown()                             # drain=True executes them
    for p, t in zip(points, tickets):
        assert t.done
        ref = eng.executor.positive(db, eng.plan(p, None))
        np.testing.assert_array_equal(np.asarray(t.result().counts),
                                      np.asarray(ref.counts))


def test_shutdown_fails_pending_waiters_cleanly():
    """Regression: shutdown with queries pending must propagate a clean
    error to every waiter — no ticket may hang."""
    db = flood_db()
    svc = CountingService(CountingEngine(db, "sparse", CostStats()),
                          max_batch_size=64, max_wait_s=10.0,
                          dispatcher=True)
    points = build_lattice(db.schema, 1)
    tickets = [svc.submit(p) for p in points]
    assert svc.pending() == len(points)
    # one waiter is already parked on the raw completion event when the
    # shutdown lands — it must be signalled, not left hanging
    parked = {}

    def park(t):
        parked["signalled"] = t._entry.event.wait(5.0)

    th = threading.Thread(target=park, args=(tickets[0],))
    th.start()
    svc.shutdown(drain=False)
    th.join(timeout=5.0)
    assert not th.is_alive(), "waiter hung through shutdown"
    assert parked["signalled"]
    results = {}

    def waiter(i, t):
        try:
            results[i] = t.result(timeout=5.0)
        except BaseException as e:             # noqa: BLE001 — recording
            results[i] = e

    threads = [threading.Thread(target=waiter, args=(i, t))
               for i, t in enumerate(tickets)]
    for w in threads:
        w.start()
    for w in threads:
        w.join(timeout=5.0)
        assert not w.is_alive(), "waiter hung through shutdown"
    for i in range(len(tickets)):
        assert isinstance(results[i], ServiceShutdown)
    with pytest.raises(ServiceShutdown):       # and new submits are refused
        svc.submit(points[0])
    svc.shutdown()                             # idempotent


def test_service_metrics_snapshot_shape():
    db = flood_db()
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, metrics=ServiceMetrics())
    svc.count_many([(p, None) for p in build_lattice(db.schema, 1)])
    snap = svc.stats()
    assert snap["batched_queries"] == 5
    assert snap["buckets"] and snap["buckets"][0]["queries"] == 5
    assert {"hits", "misses", "evictions", "dropped"} <= set(snap["cache"])
