"""Batched Möbius negative phase == unbatched == oracle.

Three layers are pinned down:

* the pure transform: :func:`repro.core.mobius.butterfly_batch` and the
  executors' jitted :meth:`~repro.core.executors.Executor.mobius_batch`
  are bit-identical to per-stack :func:`~repro.core.mobius
  .superset_mobius` (including the Pallas kernel path and non-power-of-two
  batch sizes, which exercise the padding);
* the assembly: :func:`repro.core.mobius.complete_ct_many` equals
  per-query :func:`~repro.core.mobius.complete_ct` under BOTH evaluation
  orders (butterfly and blockwise);
* the strategies: ``family_ct_many`` (which now routes whole rounds
  through the batched negative phase) == per-family ``family_ct`` ==
  brute-force oracle for all four strategies × both executors, including
  ``k == 0`` keeps (no indicator axes — nothing to transform) and card-1
  attribute domains.
"""

import itertools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (CostStats, CountingEngine, build_lattice,
                        butterfly_batch, complete_ct, complete_ct_many,
                        make_strategy, superset_mobius)
from repro.core.engine import OnDemandPositives
from repro.core.executors import make_executor
from repro.core.oracle import oracle_ct
from repro.core.strategies import STRATEGIES
from tests.test_executor_edge_cases import edge_case_db
from tests.test_engine_equivalence import random_db, random_keeps
from tests.test_serve import mixed_db

STRAT_X_EXEC = list(itertools.product(sorted(STRATEGIES),
                                      ("dense", "sparse")))


# ------------------------------------------------------------ transform ----

def _random_stacks(rng, b, k, attr_shape):
    return [jnp.asarray(rng.integers(0, 50, size=(2,) * k + attr_shape)
                        .astype(np.float32)) for _ in range(b)]


@pytest.mark.parametrize("b,k,attr_shape", [
    (1, 1, (3,)), (2, 2, (3, 2)), (3, 1, ()), (5, 3, (4,)), (8, 2, (2, 1)),
])
def test_butterfly_batch_equals_per_stack(b, k, attr_shape):
    rng = np.random.default_rng(b * 10 + k)
    stacks = _random_stacks(rng, b, k, attr_shape)
    want = [superset_mobius(s, k) for s in stacks]
    got = butterfly_batch(stacks, k)
    assert len(got) == b
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_executor_mobius_batch_identical_to_mobius(use_pallas):
    """The jitted batched step == the per-stack step, for the pure-jnp
    mirror and the Pallas kernel, across batch sizes that do and do not
    hit the power-of-two padding."""
    ex = make_executor("sparse", use_pallas_mobius=use_pallas)
    rng = np.random.default_rng(7)
    for b, k, attr_shape in ((1, 1, (3,)), (3, 2, (2, 3)), (4, 1, (5,)),
                             (7, 2, ())):
        stacks = _random_stacks(rng, b, k, attr_shape)
        want = [ex.mobius(s, k) for s in stacks]
        got = ex.mobius_batch(stacks, k)
        for w, g in zip(want, got):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       atol=1e-4)
    assert ex.mobius_batch([], 1) == []


def test_mobius_batch_jit_cache_is_keyed_by_shape():
    ex = make_executor("dense")
    rng = np.random.default_rng(3)
    ex.mobius_batch(_random_stacks(rng, 3, 1, (2,)), 1)
    n_keys = len(ex._batch_cache)
    ex.mobius_batch(_random_stacks(rng, 4, 1, (2,)), 1)   # same pad bucket
    assert len(ex._batch_cache) == n_keys
    ex.mobius_batch(_random_stacks(rng, 3, 2, (2,)), 2)   # new shape
    assert len(ex._batch_cache) == n_keys + 1


# ------------------------------------------------------------- assembly ----

@pytest.mark.parametrize("ex", ["dense", "sparse"])
def test_complete_ct_many_equals_complete_ct_both_orders(ex):
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    rng = np.random.default_rng(5)
    queries = []
    for point in (lattice[0], lattice[-1]):
        pool = list(point.all_ct_vars(db.schema, include_rind=True))
        queries.append((point, tuple(pool)))
        queries.append((point, ()))                       # k == 0, scalar
        queries.append((point, tuple(v for v in pool
                                     if v.kind == "attr")))  # k == 0
        for _ in range(3):
            k = rng.integers(1, len(pool) + 1)
            pick = rng.choice(len(pool), size=k, replace=False)
            queries.append((point, tuple(pool[i] for i in sorted(pick))))

    for use_butterfly in (True, False):
        eng = CountingEngine(db, ex, CostStats())
        policy = OnDemandPositives(eng)
        got = complete_ct_many(queries, policy, use_butterfly=use_butterfly,
                               mobius_batch_fn=eng.executor.mobius_batch)
        ref_eng = CountingEngine(db, ex, CostStats())
        ref_policy = OnDemandPositives(ref_eng)
        for (point, keep), g in zip(queries, got):
            want = complete_ct(point, keep, ref_policy,
                               use_butterfly=use_butterfly)
            assert g.vars == want.vars
            np.testing.assert_allclose(
                np.asarray(g.counts), np.asarray(want.counts), atol=1e-3,
                err_msg=f"{ex} butterfly={use_butterfly} "
                        f"keep={[str(v) for v in keep]}")


@pytest.mark.parametrize("ex,use_pallas", [("dense", False),
                                           ("sparse", False),
                                           ("sparse", True)])
def test_complete_ct_many_fused_equals_unfused(ex, use_pallas):
    """The FUSED batched path (stack assembly + transform + finalise
    transpose in one jitted dispatch per (shape, perm) group) is
    bit-identical to the unfused batched path and per-query complete_ct,
    for the pure-jnp step and the Pallas kernel."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    rng = np.random.default_rng(9)
    queries = []
    for point in (lattice[0], lattice[-1]):
        pool = list(point.all_ct_vars(db.schema, include_rind=True))
        queries.append((point, tuple(pool)))
        queries.append((point, ()))                       # k == 0 fallback
        for _ in range(4):
            k = rng.integers(1, len(pool) + 1)
            pick = rng.choice(len(pool), size=k, replace=False)
            queries.append((point, tuple(pool[i] for i in sorted(pick))))
    executor = make_executor(ex, use_pallas_mobius=use_pallas)
    eng = CountingEngine(db, executor, CostStats())
    got = complete_ct_many(queries, OnDemandPositives(eng),
                           mobius_fused_fn=executor.mobius_batch_fused)
    ref_eng = CountingEngine(db, ex, CostStats())
    ref_policy = OnDemandPositives(ref_eng)
    unfused = complete_ct_many(queries, ref_policy,
                               mobius_batch_fn=ref_eng.executor.mobius_batch)
    for (point, keep), g, u in zip(queries, got, unfused):
        want = complete_ct(point, keep, ref_policy)
        assert g.vars == want.vars
        np.testing.assert_allclose(
            np.asarray(g.counts), np.asarray(want.counts), atol=1e-3,
            err_msg=f"{ex}/pallas={use_pallas} keep={[str(v) for v in keep]}")
        np.testing.assert_allclose(np.asarray(g.counts),
                                   np.asarray(u.counts), atol=1e-3)


def test_mobius_batch_fused_one_dispatch_per_group():
    """All queries of one (shape, perm) group share ONE jit entry, and
    padding keeps the cache keyed by a handful of batch sizes."""
    ex = make_executor("sparse")
    rng = np.random.default_rng(4)
    blocks = lambda b, k, shp: [[jnp.asarray(
        rng.integers(0, 9, size=shp).astype(np.float32))
        for _ in range(1 << k)] for _ in range(b)]
    perm = (0, 1)
    ex.mobius_batch_fused(blocks(3, 1, (2,)), 1, perm)
    n_keys = len(ex._batch_cache)
    ex.mobius_batch_fused(blocks(4, 1, (2,)), 1, perm)    # same pad bucket
    assert len(ex._batch_cache) == n_keys
    ex.mobius_batch_fused(blocks(3, 1, (2,)), 1, (1, 0))  # new perm group
    assert len(ex._batch_cache) == n_keys + 1
    assert ex.mobius_batch_fused([], 1, perm) == []


# ------------------------------------------------------------ strategies ----

@pytest.mark.parametrize("sname,ex", STRAT_X_EXEC)
def test_batched_rounds_match_unbatched_and_oracle(sname, ex):
    """family_ct_many (batched negative phase) == per-family butterfly ==
    per-family blockwise == oracle, on a random schema."""
    db = random_db(0)
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    rng = np.random.default_rng(11)
    keeps = random_keeps(rng, point, db.schema)
    keeps.append(())

    batched = make_strategy(sname, executor=ex)
    batched.prepare(db, lattice)
    got = batched.family_ct_many(point, keeps)

    butterfly = make_strategy(sname, executor=ex)
    butterfly.prepare(db, lattice)
    blockwise = make_strategy(sname, executor=ex, use_butterfly=False)
    blockwise.prepare(db, lattice)
    for keep, g in zip(keeps, got):
        want = oracle_ct(db, point, keep)
        msg = f"{sname}/{ex} keep={[str(v) for v in keep]}"
        np.testing.assert_allclose(np.asarray(g.counts), want, atol=1e-3,
                                   err_msg=msg)
        for ref in (butterfly, blockwise):
            w = ref.family_ct(point, keep)
            assert w.vars == g.vars
            np.testing.assert_allclose(np.asarray(g.counts),
                                       np.asarray(w.counts), atol=1e-3,
                                       err_msg=msg)


@pytest.mark.parametrize("sname,ex", STRAT_X_EXEC)
def test_batched_rounds_card1_domains(sname, ex):
    """Card-1 attribute domains and an empty relationship table through
    the batched negative phase."""
    db = edge_case_db()
    lattice = build_lattice(db.schema, 2)
    point = lattice[-1]
    pool = list(point.all_ct_vars(db.schema, include_rind=True))
    keeps = [tuple(pool), (),
             tuple(v for v in pool if v.kind == "attr"),
             tuple(v for v in pool if v.kind in ("attr", "rind"))]
    st = make_strategy(sname, executor=ex)
    st.prepare(db, lattice)
    got = st.family_ct_many(point, keeps)
    for keep, g in zip(keeps, got):
        want = oracle_ct(db, point, keep)
        np.testing.assert_allclose(
            np.asarray(g.counts), want, atol=1e-3,
            err_msg=f"{sname}/{ex} keep={[str(v) for v in keep]}")
