"""Tests for the §Perf hillclimb features: shard_map expert parallelism,
sequence-parallel attention, the parallel-scan linear-attention core, the
flash-attention Pallas kernel, and the slice-aware HLO byte accounting.

All distributed tests run on 8 fake CPU devices (2 data x 4 model)."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.kernels import ops, ref
from repro.models.attention import block_attention, sharded_attention
from repro.models.linear_attn import chunked_linear_attention
from repro.models.moe import moe_apply, moe_init


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# ------------------------------------------------------------------ MoE EP --

def test_moe_ep_matches_spmd(mesh):
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                          jnp.float32)
    with jax.sharding.set_mesh(mesh):
        o_ep, a_ep = jax.jit(
            lambda p, x: moe_apply(p, x, cfg.replace(moe_impl="ep")))(p, x)
        o_sp, a_sp = jax.jit(
            lambda p, x: moe_apply(p, x, cfg.replace(moe_impl="spmd")))(p, x)
    np.testing.assert_allclose(np.asarray(o_ep), np.asarray(o_sp),
                               rtol=2e-3, atol=2e-3)
    assert abs(float(a_ep) - float(a_sp)) < 1e-5


def test_moe_ep_grads_match_spmd(mesh):
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)

    def loss(p, x, impl):
        o, a = moe_apply(p, x, cfg.replace(moe_impl=impl))
        return jnp.sum(o ** 2) * 1e-3 + a

    with jax.sharding.set_mesh(mesh):
        g_ep = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "ep")
        g_sp = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "spmd")
    for n in ("router", "wi", "wo", "wg"):
        np.testing.assert_allclose(np.asarray(getattr(g_ep, n)),
                                   np.asarray(getattr(g_sp, n)),
                                   rtol=5e-3, atol=5e-3)


def test_moe_ep_no_mesh_fallback():
    cfg = get_reduced("qwen3-moe-30b-a3b")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    o, a = moe_apply(p, x, cfg)          # no mesh context -> spmd body
    assert o.shape == x.shape and np.isfinite(float(a))


# ------------------------------------------------------ SP attention (H2) --

def test_sharded_attention_matches_reference(mesh):
    # 5 heads do NOT divide the 4-way model axis -> SP path taken
    q = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 5, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 5, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 5, 16))
    want = block_attention(q, k, v, causal=True, chunk=8)
    with jax.sharding.set_mesh(mesh):
        got = jax.jit(lambda q, k, v: sharded_attention(
            q, k, v, causal=True, chunk=8))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_sharded_attention_divisible_heads_plain_path(mesh):
    # 4 heads divide the model axis -> plain GSPMD path, same numbers
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 8))
    k, v = q + 1.0, q - 0.5
    want = block_attention(q, k, v, causal=True, chunk=8)
    with jax.sharding.set_mesh(mesh):
        got = jax.jit(lambda q, k, v: sharded_attention(
            q, k, v, causal=True, chunk=8))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_block_attention_q_offset():
    # offset mask must equal slicing the full computation
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 2, 8))
    full = block_attention(q, k, v, causal=True, chunk=8)
    part = block_attention(q[:, 16:], k, v, causal=True, chunk=8, q_offset=16)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, 16:]),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------- parallel-scan linear attention ----

def _seq_oracle(r, k, v, logw, u=None):
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    S = np.zeros((b, h, dk, dv), np.float32)
    r, k, v, lw = (np.asarray(t, np.float32) for t in (r, k, v, logw))
    outs = []
    for t in range(s):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]
        eff = S + (u[None, :, :, None] * kv if u is not None else 0)
        outs.append(np.einsum("bhd,bhdv->bhv", r[:, t], eff))
        S = S * np.exp(lw[:, t])[..., None] + kv
    return np.stack(outs, 1), S


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.sampled_from([16, 32, 48]),
       st.booleans())
def test_parallel_scan_linear_attention_matches_oracle(b, h, s, with_u):
    dk, dv = 4, 5
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + h * 10 + s), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    logw = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h, dk)))
    u = jax.random.normal(ks[4], (h, dk)) if with_u else None
    o, S = chunked_linear_attention(r, k, v, logw, u=u, chunk=16)
    o_ref, S_ref = _seq_oracle(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), o_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_parallel_scan_sharded_matches_plain(mesh):
    b, s, h, dk, dv = 2, 64, 3, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    logw = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h, dk)))
    o_plain, S_plain = chunked_linear_attention(r, k, v, logw, chunk=16)
    with jax.sharding.set_mesh(mesh):   # n=4 chunks shard over model=4
        o_mesh, S_mesh = jax.jit(
            lambda *a: chunked_linear_attention(*a, chunk=16))(r, k, v, logw)
    np.testing.assert_allclose(np.asarray(o_mesh), np.asarray(o_plain),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(S_mesh), np.asarray(S_plain),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------- flash attention kernel --

@pytest.mark.parametrize("b,s,h,hd,causal,bq,bk", [
    (2, 64, 3, 16, True, 16, 16),
    (1, 128, 2, 32, True, 32, 64),
    (2, 48, 2, 8, False, 16, 16),
    (1, 100, 1, 20, True, 32, 32),        # non-divisible seq -> padding
])
def test_flash_attention_kernel(b, s, h, hd, causal, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    got = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_block_attention():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 4, 16))
    flash = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    blocked = block_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(blocked),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------- HLO slice-aware accounting --

def test_hlo_analysis_caps_sliced_operands():
    """A dynamic-slice read from a big stacked buffer must be charged at
    slice granularity, not the whole buffer."""
    from repro.hlo_analysis import analyze

    def f(stack, i):
        return jax.lax.dynamic_index_in_dim(stack, i, 0, keepdims=False) * 2.0

    stack = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)
    i = jax.ShapeDtypeStruct((), jnp.int32)
    hlo = jax.jit(f).lower(stack, i).compile().as_text()
    totals = analyze(hlo)
    full = 64 * 128 * 128 * 4
    # traffic must be ~slice-sized (a few x 64 KiB), far below the 4 MiB stack
    assert totals["bytes"] < full, totals


def test_custom_rms_norm_grad_matches_autodiff():
    from repro.models.layers import rms_norm

    def naive(x, w, eps=1e-6):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        return ((x32 * jax.lax.rsqrt(var + eps))
                * w.astype(jnp.float32)).astype(x.dtype)

    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 32)) * 3
    w = jax.random.normal(jax.random.PRNGKey(1), (32,)) * 0.5 + 1.0
    g1 = jax.grad(lambda x, w: jnp.sum(jnp.sin(rms_norm(x, w))), (0, 1))(x, w)
    g2 = jax.grad(lambda x, w: jnp.sum(jnp.sin(naive(x, w))), (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               rtol=1e-5, atol=1e-5)
