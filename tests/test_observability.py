"""Observability-stack tests: tracing, percentile telemetry, export.

* :mod:`repro.obs.hist` — log-bucket percentile histograms whose merge is
  exactly associative/commutative (what lets per-shard histograms roll up
  into fleet percentiles without bias).
* :mod:`repro.obs.trace` — ring-buffered request spans: implicit
  same-thread nesting, explicit cross-thread parenting by value,
  retroactive spans, and tree reconstruction.
* :mod:`repro.serve.metrics` — lock-guarded counters stay EXACT under a
  concurrent flood (the seed's plain ``+=`` lost increments); snapshots
  are derived from ``dataclasses.fields`` so no counter can silently
  vanish from dashboards; ``merged`` is an element-wise sum.
* End to end — a traced flood over a 2-shard router reconstructs, per
  query, the full path router submit → shard queue → bucket execution →
  shard merge → cache install, with per-stage percentiles exported to
  Prometheus text and JSON.
"""

import dataclasses
import json
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (CostStats, CountingEngine, build_lattice,
                        shard_database)
from repro.obs import (LatencyHistogram, MetricsRegistry, N_BUCKETS,
                       NULL_TRACER, SlowQueryLog, Tracer, build_trees,
                       default_tracer)
from repro.obs import profile
from repro.obs.trace import NullTracer
from repro.serve import (CountingRouter, CountingService, RouterMetrics,
                         ServiceMetrics)
from tests.test_distributed_counting import _routable_points
from tests.test_mutations import fresh_pairs
from tests.test_serve import flood_db, mixed_db


# ------------------------------------------------------------- histogram --

def _random_hist(rng, n=50, scale=0.02):
    h = LatencyHistogram()
    for _ in range(n):
        h.observe(float(rng.uniform(0, scale)))
    return h


def test_histogram_buckets_and_percentiles():
    h = LatencyHistogram()
    assert h.percentile(0.99) == 0.0                 # empty reports zero
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 100):      # one 100ms straggler
        h.observe(ms / 1e3)
    assert h.count == 10
    assert h.percentile(0.50) <= h.percentile(0.95) <= h.percentile(0.99)
    # the p99 bucket bound is within 2x of the true tail by construction
    assert 0.1 <= h.percentile(0.99) <= 0.2
    assert h.max_s == pytest.approx(0.1)
    d = h.as_dict()
    assert set(d) == {"count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s"}
    # a single observation's percentile is capped at the observed max
    one = LatencyHistogram()
    one.observe(0.003)
    assert one.percentile(0.5) == pytest.approx(0.003)


def test_histogram_bucket_of_bounds():
    assert LatencyHistogram.bucket_of(0.0) == 0
    assert LatencyHistogram.bucket_of(-1.0) == 0
    assert LatencyHistogram.bucket_of(1e12) == N_BUCKETS - 1
    for d in (1e-9, 1e-6, 1e-3, 1.0):
        i = LatencyHistogram.bucket_of(d)
        assert d <= LatencyHistogram.bucket_upper_s(i)


def test_histogram_merge_associative_and_commutative():
    rng = np.random.default_rng(0)
    hs = [_random_hist(rng) for _ in range(4)]
    left = LatencyHistogram()
    for h in hs:
        left.merge(h)
    right = LatencyHistogram()
    for h in reversed(hs):
        right.merge(h)
    nested = LatencyHistogram.merged(
        [LatencyHistogram.merged(hs[:2]), LatencyHistogram.merged(hs[2:])])
    assert left == right == nested
    assert left.count == sum(h.count for h in hs)
    assert left.sum_s == pytest.approx(sum(h.sum_s for h in hs))
    assert left.max_s == max(h.max_s for h in hs)
    for i in range(N_BUCKETS):
        assert left.counts[i] == sum(h.counts[i] for h in hs)
    for h in hs:                                     # inputs untouched
        assert h.count == 50


def test_histogram_prometheus_bucket_shape():
    h = LatencyHistogram()
    for ms in (1, 2, 4, 50):
        h.observe(ms / 1e3)
    buckets = h.nonzero_buckets()
    assert buckets[-1][1] == h.count                 # cumulative counts
    uppers = [u for u, _ in buckets]
    assert uppers == sorted(uppers)


# ----------------------------------------------------------------- tracer --

def test_tracer_nesting_and_trees():
    tr = Tracer(capacity=64)
    with tr.span("root", mode="fanout") as root:
        ctx = root.context
        with tr.span("child"):
            pass
    tr.record("retro", 0.0, 1.0, parent=ctx, shard=1)
    tr.event("mark", parent=ctx)
    trees = tr.trees()
    assert len(trees) == 1
    (t,) = trees
    assert t["spans"] == 4
    (r,) = t["roots"]
    assert r["name"] == "root" and r["attrs"]["mode"] == "fanout"
    assert {c["name"] for c in r["children"]} == {"child", "retro", "mark"}


def test_tracer_cross_thread_parenting():
    tr = Tracer()
    with tr.span("submit") as sp:
        ctx = sp.context

    def worker():
        t0 = time.perf_counter()
        tr.record("queue", t0, time.perf_counter(), parent=ctx)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    recs = tr.records()
    assert len({r.trace_id for r in recs}) == 1      # one trace, two threads
    child = next(r for r in recs if r.name == "queue")
    parent = next(r for r in recs if r.name == "submit")
    assert child.parent_id == parent.span_id
    assert child.thread != parent.thread


def test_tracer_ring_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.event(f"e{i}")
    snap = tr.snapshot()
    assert snap["recorded"] == 10
    assert snap["resident"] == 4
    assert snap["dropped"] == 6
    assert [r.name for r in tr.records()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert tr.snapshot()["recorded"] == 0


def test_build_trees_promotes_orphans():
    tr = Tracer(capacity=2)                          # parent falls off
    ctx = tr.record("parent", 0.0, 1.0)
    tr.event("a", parent=ctx)
    tr.event("b", parent=ctx)
    assert [r.name for r in tr.records()] == ["a", "b"]
    trees = build_trees(tr.records())
    (t,) = trees
    assert {r["name"] for r in t["roots"]} == {"a", "b"}


def test_null_tracer_is_inert():
    tr = NULL_TRACER
    assert not tr.enabled and tr.slow is None
    with tr.span("x", attrs=1) as sp:
        assert sp.context is None
        sp.set(y=2)
    assert tr.record("r", 0.0, 1.0) is None
    assert tr.records() == [] and tr.trees() == []
    assert tr.snapshot()["enabled"] is False


def test_default_tracer_env_knob(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert default_tracer() is NULL_TRACER
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert default_tracer() is NULL_TRACER
    monkeypatch.setenv("REPRO_TRACE", "4096")
    tr = default_tracer()
    assert isinstance(tr, Tracer) and tr.capacity == 4096
    monkeypatch.setenv("REPRO_TRACE", "on")
    assert default_tracer().capacity == 65536
    monkeypatch.setenv("REPRO_TRACE_SLOW_MS", "10")
    assert default_tracer().slow.threshold_s == pytest.approx(0.01)


def test_service_picks_up_env_tracer(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "512")
    db = flood_db(n_rels=2, edges=8)
    eng = CountingEngine(db, "sparse", CostStats())
    svc = CountingService(eng, max_batch_size=8)
    try:
        assert svc.tracer.enabled and svc.tracer.capacity == 512
        # one tracer instance threaded through engine/executor/cache
        assert eng.tracer is svc.tracer
        assert eng.executor.tracer is svc.tracer
        assert eng.cache.tracer is svc.tracer
        point = build_lattice(db.schema, 1)[0]
        svc.count(point)
        names = {r.name for r in svc.tracer.records()}
        assert "service.queue" in names
        assert "service.exec" in names
        assert svc.stats()["tracer"]["enabled"] is True
    finally:
        svc.shutdown()


# --------------------------------------------------------------- slow log --

def test_slow_query_log_keeps_top_k():
    log = SlowQueryLog(threshold_s=0.0, top_k=3)
    for i, ms in enumerate([5, 1, 9, 3, 7]):
        log.offer(f"q{i}", ms / 1e3, shard=i)
    got = [round(q.duration_s * 1e3) for q in log.entries()]
    assert got == [9, 7, 5]                          # slowest first
    assert log.offered == 5 and log.admitted >= 3
    assert log.entries()[0].info["shard"] == 2
    assert all(set(d) == {"name", "duration_s", "at", "info"}
               for d in log.as_dicts())


def test_slow_query_log_threshold_and_disable():
    log = SlowQueryLog(threshold_s=0.05, top_k=4)
    assert not log.offer("fast", 0.01)
    assert log.offer("slow", 0.10)
    assert len(log.entries()) == 1
    off = SlowQueryLog(threshold_s=None)
    assert not off.offer("anything", 99.0)
    assert off.entries() == []


# ---------------------------------------------------------------- metrics --

def test_snapshots_cover_every_dataclass_field():
    """Satellite: snapshots are field-derived — a newly added counter
    cannot silently vanish from dashboards."""
    svc_snap = ServiceMetrics().snapshot()
    for f in dataclasses.fields(ServiceMetrics):
        if not f.name.startswith("_"):
            assert f.name in svc_snap, f.name
    rt_snap = RouterMetrics().snapshot()
    for f in dataclasses.fields(RouterMetrics):
        if not f.name.startswith("_"):
            assert f.name in rt_snap, f.name
    # histograms snapshot as percentile summaries
    assert svc_snap["queue_wait_hist"]["count"] == 0
    assert rt_snap["merge_hist"]["p99_s"] == 0.0


def test_metrics_inc_exact_under_concurrent_flood():
    """Satellite: the seed's racy ``metrics.x += 1`` lost increments when
    client/dispatcher/fan-out threads collided; ``inc`` must be exact."""
    m = ServiceMetrics()
    n_threads, n_iter = 8, 2000
    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-6)                      # force interleavings
    try:
        def worker():
            for _ in range(n_iter):
                m.inc(requests=1, enqueued=1)
                m.observe_wait(1e-6)
        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)
    assert m.requests == n_threads * n_iter
    assert m.enqueued == n_threads * n_iter
    assert m.queue_wait_hist.count == n_threads * n_iter


def test_service_metrics_merged_is_elementwise_sum():
    """Satellite property: ``merged`` equals the element-wise sum over
    every numeric field, histogram, and signature bucket (where
    ``max_batch`` takes the max, not the sum)."""
    rng = np.random.default_rng(3)
    many = []
    for i in range(3):
        m = ServiceMetrics()
        for name in ServiceMetrics._numeric_fields():
            m.inc(**{name: int(rng.integers(0, 50))})
        for _ in range(int(rng.integers(5, 25))):
            m.observe_wait(float(rng.uniform(0, 0.01)))
            m.observe_e2e(float(rng.uniform(0, 0.05)))
        m.observe_batch(("sig", i % 2), int(rng.integers(1, 9)), 0.001)
        many.append(m)
    agg = ServiceMetrics.merged(many)
    for name in ServiceMetrics._numeric_fields():
        assert getattr(agg, name) == pytest.approx(
            sum(getattr(m, name) for m in many)), name
    for name in ServiceMetrics._hist_fields():
        assert getattr(agg, name) == LatencyHistogram.merged(
            getattr(m, name) for m in many), name
    for sig, b in agg.buckets.items():
        parts = [m.buckets[sig] for m in many if sig in m.buckets]
        assert b.queries == sum(p.queries for p in parts)
        assert b.batches == sum(p.batches for p in parts)
        assert b.exec_s == pytest.approx(sum(p.exec_s for p in parts))
        assert b.max_batch == max(p.max_batch for p in parts)
    for m in many:                                   # inputs untouched
        assert m is not agg


def test_router_metrics_merge_and_e2e_histograms():
    m = RouterMetrics()
    m.observe_merge(0.002)
    m.observe_e2e(0.004)
    snap = m.snapshot()
    assert snap["merge_hist"]["count"] == 1
    assert snap["e2e_hist"]["count"] == 1
    assert snap["e2e_hist"]["max_s"] == pytest.approx(0.004)


# --------------------------------------------------------------- registry --

def test_registry_prometheus_and_json_rendering():
    m = ServiceMetrics()
    m.inc(requests=3, cache_hits=1)
    m.observe_wait(0.002)
    reg = MetricsRegistry()
    reg.register("svc", m.snapshot)                  # callable source
    reg.register("hists", lambda: {"queue_wait": m.queue_wait_hist})
    reg.register("plain", {"up": True, "shards": [1, 2]})
    assert reg.sources() == ["hists", "plain", "svc"]
    text = reg.prometheus()
    assert "repro_svc_requests 3" in text
    assert "repro_svc_cache_hits 1" in text
    assert "repro_svc_queue_wait_hist_p99_s" in text   # flattened summary
    assert 'repro_hists_queue_wait_bucket{le="+Inf"} 1' in text
    assert "repro_hists_queue_wait_count 1" in text    # native histogram
    assert "repro_plain_up 1" in text
    assert "repro_plain_shards_1 2" in text
    data = json.loads(reg.to_json(indent=2))
    assert data["svc"]["requests"] == 3
    assert data["hists"]["queue_wait"]["count"] == 1
    reg.unregister("plain")
    assert "repro_plain_up" not in reg.prometheus()


def test_registry_rejects_unusable_source():
    reg = MetricsRegistry()
    reg.register("bad", 42)
    with pytest.raises(TypeError):
        reg.collect()


# ---------------------------------------------------------------- profile --

def test_profiler_annotation_knob():
    assert not profile.enabled()
    with profile.annotate("off"):                    # inert when disabled
        pass
    profile.enable()
    try:
        assert profile.enabled()
        with profile.annotate("exec.positive_batch"):
            pass
    finally:
        profile.disable()
    assert not profile.enabled()


# ------------------------------------------------------------ end to end --

def _assert_trace_integrity(records):
    """Every recorded span closed, and parents precede their children."""
    by_id = {r.span_id: r for r in records}
    for r in records:
        assert r.t1 >= r.t0, r
        if r.parent_id is not None and r.parent_id in by_id:
            parent = by_id[r.parent_id]
            assert parent.trace_id == r.trace_id
            assert parent.t0 <= r.t0 + 1e-9, (parent, r)


def test_traced_sharded_flood_reconstructs_span_trees():
    """Acceptance: a traced flood over 2 shards yields, per query, a
    span tree covering router submit → shard queue → bucket execution →
    shard merge → cache install, with per-stage percentiles exported."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    tracer = Tracer(capacity=1 << 14, slow_threshold_s=0.0)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=8,
                            tracer=tracer)
    points = _routable_points(sdb, lattice)
    # per-ticket path: submit everything, then resolve (result() flushes)
    tickets = [router.submit(p) for p in points]
    for t in tickets:
        t.result()
    records = tracer.records()
    _assert_trace_integrity(records)
    names = {r.name for r in records}
    assert {"router.submit", "service.queue", "service.exec",
            "router.merge", "router.cache_install"} <= names
    trees = build_trees(records)
    fanout_roots = [r for t in trees for r in t["roots"]
                    if r["name"] == "router.submit"
                    and r["attrs"].get("mode") == "fanout"]
    assert fanout_roots
    for root in fanout_roots:
        kids = {c["name"] for c in root["children"]}
        assert {"service.queue", "router.merge",
                "router.cache_install"} <= kids, kids
        queues = [c for c in root["children"] if c["name"] == "service.queue"]
        assert len(queues) == 2                      # one per shard
        # bucket execution hangs off the queue residency span
        assert any(g["name"] == "service.exec"
                   for q in queues for g in q["children"])
        merge = next(c for c in root["children"]
                     if c["name"] == "router.merge")
        assert merge["attrs"]["straggler_shard"] in (0, 1)
        assert merge["attrs"]["path"] == "overlapped"
    # per-stage percentiles surfaced through the snapshots
    snap = router.stats()
    assert snap["router"]["e2e_hist"]["count"] >= len(fanout_roots)
    assert snap["router"]["merge_hist"]["count"] >= 1
    assert snap["aggregate"]["queue_wait_hist"]["count"] >= 1
    assert snap["aggregate"]["bucket_exec_hist"]["count"] >= 1
    assert snap["aggregate"]["e2e_hist"]["count"] >= 1
    assert snap["tracer"]["slow_queries"]             # threshold 0: logged
    # cache hit short-circuit is traced too
    router.count(points[0])
    assert any(r.name == "router.submit"
               and (r.attrs or {}).get("mode") == "cache_hit"
               for r in tracer.records())
    # and the whole thing exports to Prometheus + JSON
    reg = MetricsRegistry()
    reg.register("router", router.stats)
    text = reg.prometheus()
    assert "repro_router_router_e2e_hist_p99_s" in text
    assert "repro_router_aggregate_queue_wait_hist_p50_s" in text
    assert "repro_router_tracer_recorded" in text
    data = json.loads(reg.to_json())
    assert data["router"]["router"]["requests"] == len(points) + 1


def test_traced_mixed_read_write_flood_counters_exact():
    """Satellite acceptance: counters stay exact and traces stay
    well-formed under a concurrent mixed read/write flood."""
    db = mixed_db()
    ref_db = mixed_db()                # mutated in lockstep: fresh edges
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    tracer = Tracer(capacity=1 << 15)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=4,
                            tracer=tracer)
    points = _routable_points(sdb, lattice)
    n_readers, n_reads, n_writes = 4, 6, 3
    errors = []

    def reader(seed):
        rng = np.random.default_rng(seed)
        for _ in range(n_reads):
            try:
                router.count(points[int(rng.integers(len(points)))])
            except Exception as e:                   # pragma: no cover
                errors.append(e)

    def writer():
        rng = np.random.default_rng(99)
        for _ in range(n_writes):
            rel = sorted(db.relations)[int(rng.integers(3))]
            src, dst = fresh_pairs(ref_db, rel, 1, rng)
            attrs = {a.name: rng.integers(0, a.card, size=1).astype(np.int32)
                     for a in ref_db.relations[rel].type.attrs}
            try:
                router.insert_facts(rel, src, dst, attrs)
                ref_db.insert_facts(rel, src, dst, attrs)
            except Exception as e:                   # pragma: no cover
                errors.append(e)

    threads = [threading.Thread(target=reader, args=(s,))
               for s in range(n_readers)] + [threading.Thread(target=writer)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    snap = router.stats()
    assert snap["router"]["requests"] == n_readers * n_reads   # exact
    assert snap["router"]["deltas"] == n_writes                # exact
    _assert_trace_integrity(tracer.records())
    names = {r.name for r in tracer.records()}
    assert "engine.apply_delta" in names
    assert "router.submit" in names


def test_count_many_fanout_fast_path_is_traced():
    """The fused fan-out fast path records retroactive per-query roots so
    a trace still shows which dispatch answered each query."""
    db = flood_db(n_rels=3, edges=16)
    lattice = build_lattice(db.schema, 1)
    sdb = shard_database(db, 2)
    tracer = Tracer(capacity=4096)
    router = CountingRouter(sdb, executor="sparse", tracer=tracer)
    # keep only fan-out-routed points so the fused fast path is
    # guaranteed to fire — a hash-partitioned relation always routes
    # "fanout", so this can never be empty (no silent skip)
    points = [p for p in _routable_points(sdb, lattice)
              if sdb.route(p)[0] == "fanout"]
    assert points, "workload must contain fan-out-routable points"
    router.count_many([(p, None) for p in points])
    assert router.stats()["router"]["fused_dispatches"] >= 1
    records = tracer.records()
    _assert_trace_integrity(records)
    fused = [r for r in records if r.name == "router.submit"
             and (r.attrs or {}).get("mode") == "fanout_fused"]
    assert fused
    trees = build_trees(records)
    roots = [r for t in trees for r in t["roots"]
             if r["attrs"].get("mode") == "fanout_fused"]
    assert roots and all(
        any(c["name"] == "router.merge"
            and c["attrs"]["path"] == "fanout_fused"
            for c in r["children"]) for r in roots)


def test_tracing_can_be_turned_off_again():
    db = flood_db(n_rels=2, edges=8)
    sdb = shard_database(db, 2)
    tracer = Tracer(capacity=256)
    router = CountingRouter(sdb, executor="sparse", tracer=tracer)
    points = _routable_points(sdb, build_lattice(db.schema, 1))
    router.count(points[0])
    assert tracer.records()
    router.set_tracer(NULL_TRACER)
    tracer.clear()
    router.count(points[-1] if len(points) > 1 else points[0])
    assert tracer.records() == []                    # fully unwired
    for svc in router.services:
        assert isinstance(svc.tracer, NullTracer)
        assert not svc.tracer.enabled
