"""Substrate tests: optimizer, checkpoint (incl. elastic re-shard), data
pipeline determinism, gradient compression."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, Adafactor, OptConfig, global_norm
from repro.optim.compress import make_compressor, init_error_feedback
from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticCorpus


def small_params(key=0):
    k = jax.random.PRNGKey(key)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (8, 16), jnp.float32),
            "norm1": jnp.ones((16,), jnp.float32),
            "nested": {"embed": jax.random.normal(k2, (32, 8), jnp.bfloat16)}}


def quad_loss(params):
    return (jnp.sum(jnp.square(params["w"]))
            + jnp.sum(jnp.square(params["nested"]["embed"].astype(jnp.float32) - 1.0))
            + jnp.sum(jnp.square(params["norm1"] - 0.5)))


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_decreases_loss(kind):
    cfg = OptConfig(lr=5e-2, warmup_steps=0, total_steps=100, kind=kind,
                    weight_decay=0.0)
    from repro.optim.adamw import make_optimizer
    opt = make_optimizer(cfg)
    params = small_params()
    state = opt.init(params)
    l0 = float(quad_loss(params))
    for _ in range(50):
        grads = jax.grad(quad_loss)(params)
        params, state, metrics = opt.update(params, grads, state)
    l1 = float(quad_loss(params))
    assert l1 < 0.5 * l0, (l0, l1)
    assert np.isfinite(metrics["lr"])


def test_adamw_state_dtype():
    opt = AdamW(OptConfig(state_dtype="bfloat16"))
    params = small_params()
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    params = small_params()
    save_checkpoint(tmp_path, 7, params)
    assert latest_step(tmp_path) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    back = restore_checkpoint(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_gc_and_latest(tmp_path):
    params = small_params()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, params, keep_last=2)
    assert latest_step(tmp_path) == 5
    import os
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one device layout, restore under another (subprocess with 8
    fake devices saves; this 1-device process restores)."""
    import os, subprocess, sys, textwrap
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import save_checkpoint
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        x = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        save_checkpoint(r"{tmp_path}", 3, {{"x": x}})
        print("SAVED")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([os.path.abspath("src"),
                                         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "SAVED" in out.stdout, out.stderr[-2000:]
    like = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    back = restore_checkpoint(tmp_path, 3, like)
    np.testing.assert_array_equal(np.asarray(back["x"]),
                                  np.arange(64, dtype=np.float32).reshape(8, 8))


def test_data_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8, seed=3)
    c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
    b1, b2 = c1.batch(11), c2.batch(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    # labels are next tokens
    # host sharding partitions the global batch deterministically
    h0 = SyntheticCorpus(DataConfig(64, 16, 8, seed=3, hosts=2, host_id=0))
    h1 = SyntheticCorpus(DataConfig(64, 16, 8, seed=3, hosts=2, host_id=1))
    a, b = h0.batch(5), h1.batch(5)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=32, seq_len=128, global_batch=16, seed=0,
                     bigram_weight=0.9)
    c = SyntheticCorpus(cfg)
    b = c.batch(0)
    toks, labels = b["tokens"], b["labels"]
    follow = c.succ[toks]
    frac = float(np.mean(follow == labels))
    assert frac > 0.5, frac     # the planted bigram dominates


def test_prefetcher():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=4, seed=1)
    pf = Prefetcher(SyntheticCorpus(cfg), start_step=42)
    step, batch = next(pf)
    assert step == 42 and batch["tokens"].shape == (4, 8)
    step2, _ = next(pf)
    assert step2 == 43
    pf.close()


def test_gradient_compression_error_feedback():
    compress = make_compressor()
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        0, 1e-3, (64, 64)).astype(np.float32))}
    state = {"ef": init_error_feedback(grads)}
    total_true = np.zeros((64, 64), np.float32)
    total_sent = np.zeros((64, 64), np.float32)
    for i in range(20):
        g = {"w": grads["w"] * (1 + 0.1 * i)}
        total_true += np.asarray(g["w"])
        gq, state = compress(g, state)
        total_sent += np.asarray(gq["w"])
    # error feedback: accumulated quantised stream tracks the true stream
    err = np.abs(total_sent - total_true).max()
    scale = np.abs(total_true).max()
    assert err < 0.05 * scale, (err, scale)
