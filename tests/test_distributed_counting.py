"""Sharded counting == single-device counting.

Runs in a subprocess with 8 fake host devices (XLA_FLAGS must be set before
jax initialises, so the main test process — which needs 1 device — can't do
it in-process)."""

import os
import subprocess
import sys
import textwrap

import numpy as np

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import positive_ct, point_from_rels, superset_mobius
    from repro.core.distributed import sharded_positive_ct, superset_mobius_sharded
    import jax.numpy as jnp
    from tests.test_counting_core import tiny_db

    db = tiny_db(4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for rels in (["Reg"], ["Reg", "RA"]):
        point = point_from_rels(db.schema, rels)
        keep = point.all_ct_vars(db.schema, include_rind=False)
        a = positive_ct(db, point, keep)
        b = sharded_positive_ct(db, point, keep, mesh=mesh)
        np.testing.assert_allclose(np.asarray(a.counts), np.asarray(b.counts),
                                   atol=1e-3)
    x = jnp.arange(2 * 2 * 16, dtype=jnp.float32).reshape(2, 2, 16)
    with jax.set_mesh(mesh):
        y = superset_mobius_sharded(x, 2, mesh=mesh)
    np.testing.assert_allclose(np.asarray(y), np.asarray(superset_mobius(x, 2)))
    print("DISTRIBUTED-OK")
""")


def test_sharded_counting_matches(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath("src"), os.path.abspath("."),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED-OK" in out.stdout
