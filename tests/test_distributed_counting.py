"""Distributed counting == single-device counting.

Two layers are covered:

* **mesh sharding** (``core/distributed.py``): the dense and sparse
  executors with their hops sharded over a device mesh produce counts
  numerically identical to the single-device path and the brute-force
  oracle — including every strategy over ``ShardedSparseExecutor`` on a
  >= 2-shard mesh.  These run in a subprocess with 8 fake host devices
  (XLA_FLAGS must be set before jax initialises, so the main test process
  — which needs 1 device — can't do it in-process).
* **database sharding** (``ShardedDatabase`` + ``serve/router.py``): a
  horizontally hash-partitioned database behind one CountingService per
  shard merges, at the router, to the exact single-database answer —
  including under a concurrent mixed-signature flood.  These need no
  extra devices and run in-process.
"""

import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import (CostStats, CountingEngine, LatticePoint,
                        NotRoutableError, build_lattice, shard_database)
from repro.core.variables import Atom, Var
from repro.serve import CountingRouter, RouterMetrics
from tests.test_serve import mixed_db

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import positive_ct, point_from_rels, superset_mobius
    from repro.core.distributed import (ShardedSparseExecutor,
                                        sharded_positive_ct,
                                        sharded_sparse_positive_ct,
                                        superset_mobius_sharded)
    import jax.numpy as jnp
    from tests.test_counting_core import tiny_db

    db = tiny_db(4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for rels in (["Reg"], ["Reg", "RA"]):
        point = point_from_rels(db.schema, rels)
        keep = point.all_ct_vars(db.schema, include_rind=False)
        a = positive_ct(db, point, keep)
        b = sharded_positive_ct(db, point, keep, mesh=mesh)
        np.testing.assert_allclose(np.asarray(a.counts), np.asarray(b.counts),
                                   atol=1e-3)
        c = sharded_sparse_positive_ct(db, point, keep, mesh=mesh)
        np.testing.assert_allclose(np.asarray(a.counts), np.asarray(c.counts),
                                   atol=1e-3)
    x = jnp.arange(2 * 2 * 16, dtype=jnp.float32).reshape(2, 2, 16)
    with jax.set_mesh(mesh):
        y = superset_mobius_sharded(x, 2, mesh=mesh)
    np.testing.assert_allclose(np.asarray(y), np.asarray(superset_mobius(x, 2)))
    print("DISTRIBUTED-OK")
""")

# Sharded sparse == unsharded sparse == brute-force oracle, for all four
# strategies, on an 8-shard data mesh (the ISSUE's >= 2-shard property).
STRATEGY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import build_lattice, make_strategy
    from repro.core.distributed import ShardedSparseExecutor
    from repro.core.oracle import oracle_ct
    from repro.core.strategies import STRATEGIES
    from tests.test_engine_equivalence import random_db, random_keeps

    mesh = jax.make_mesh((8,), ("data",))
    for seed in (0, 1):
        db = random_db(seed)
        rng = np.random.default_rng(seed + 50)
        lattice = build_lattice(db.schema, 2)
        point = lattice[-1]
        keeps = random_keeps(rng, point, db.schema)
        oracles = [oracle_ct(db, point, keep) for keep in keeps]
        plain = make_strategy("ONDEMAND", executor="sparse")
        plain.prepare(db, lattice)
        for sname in sorted(STRATEGIES):
            ex = ShardedSparseExecutor(mesh=mesh, axis="data")
            assert ex.n_ranks == 8
            st = make_strategy(sname, executor=ex)
            st.prepare(db, lattice)
            for keep, want in zip(keeps, oracles):
                got = st.family_ct(point, keep)
                np.testing.assert_allclose(
                    np.asarray(got.counts), want, atol=1e-3,
                    err_msg=f"seed={seed} {sname} "
                            f"keep={[str(v) for v in keep]}")
                ref = plain.family_ct(point, keep)
                np.testing.assert_allclose(
                    np.asarray(got.counts), np.asarray(ref.counts),
                    atol=1e-3)

    # mutations on the 8-rank mesh: delta maintenance stays oracle-exact,
    # and the delta path's local_mode contractions never build new
    # shard_map closures (a handful of delta edges must not pay padding
    # + psum per hop)
    from tests.test_mutations import random_delete, random_insert
    db = random_db(0)
    lattice = build_lattice(db.schema, 2)
    ex = ShardedSparseExecutor(mesh=mesh, axis="data")
    st = make_strategy("HYBRID", executor=ex)
    st.prepare(db, lattice)
    point = lattice[-1]
    keep = point.all_ct_vars(db.schema, include_rind=True)
    st.family_ct(point, keep)
    rng = np.random.default_rng(5)
    rel = sorted(point.rels)[0]
    n_closures = len(ex._shard_fn_cache)
    rep = st.apply_delta(random_insert(db, rel, 2, rng))
    assert rep.updated + rep.invalidated > 0, rep
    assert len(ex._shard_fn_cache) == n_closures     # local_mode: no
    for delta_round in range(2):                     # sharded delta hops
        got = st.family_ct(point, keep)
        np.testing.assert_allclose(np.asarray(got.counts),
                                   oracle_ct(db, point, keep), atol=1e-3)
        d = random_delete(db, rel, 1, rng)
        if d is not None:
            st.apply_delta(d)
    got = st.family_ct(point, keep)
    np.testing.assert_allclose(np.asarray(got.counts),
                               oracle_ct(db, point, keep), atol=1e-3)
    print("SHARDED-SPARSE-OK")
""")


# The sharded executor's shard_map closures are cached per device-step
# shape: a flood of same-shape hops must trace each step ONCE (PR-3
# follow-up: no per-hop retracing).
TRACE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import CostStats, CountingEngine, build_lattice
    from repro.core.distributed import ShardedSparseExecutor
    from tests.test_serve import mixed_db

    mesh = jax.make_mesh((8,), ("data",))
    db = mixed_db()
    ex = ShardedSparseExecutor(mesh=mesh, axis="data")
    eng = CountingEngine(db, ex, CostStats())
    ref = CountingEngine(db, "sparse", CostStats())
    plans = [eng.plan(p, None) for p in build_lattice(db.schema, 2)]
    for plan in plans:                       # first pass: traces happen here
        got = ex.positive(db, plan)
        want = ref.executor.positive(db, plan)
        np.testing.assert_allclose(np.asarray(got.counts),
                                   np.asarray(want.counts), atol=1e-3)
    first = dict(ex.trace_counts)
    assert first and all(v == 1 for v in first.values()), first
    for _ in range(3):                       # the flood: same-shape re-runs
        for plan in plans:
            ex.positive(db, plan)
    assert ex.trace_counts == first, (ex.trace_counts, first)
    assert len(ex._shard_fn_cache) == len(first)
    print("TRACE-FLAT-OK")
""")


def _run_subprocess(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath("src"), os.path.abspath("."),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_counting_matches(tmp_path):
    assert "DISTRIBUTED-OK" in _run_subprocess(SCRIPT)


def test_sharded_sparse_strategies_match_oracle():
    assert "SHARDED-SPARSE-OK" in _run_subprocess(STRATEGY_SCRIPT)


def test_sharded_sparse_trace_counts_stay_flat():
    assert "TRACE-FLAT-OK" in _run_subprocess(TRACE_SCRIPT)


# ---------------------------------------------------------------------------
# ShardedDatabase: partition invariants + routing decisions (in-process)
# ---------------------------------------------------------------------------

def test_shard_database_partition_invariants():
    db = mixed_db()
    sdb = shard_database(db, 3)
    assert sdb.n_shards == 3
    assert sdb.root_etype == "A"            # most-incident entity type
    assert sdb.partitioned == {"R0", "R2"}  # A-incident rels; R1 replicated
    for name, tab in db.relations.items():
        if name in sdb.partitioned:
            # every edge on exactly one shard, attribute columns aligned
            parts = [s.relations[name] for s in sdb.shards]
            assert sum(p.num_edges for p in parts) == tab.num_edges
            got = sorted(
                (int(a), int(b)) for p in parts
                for a, b in zip(p.src, p.dst))
            assert got == sorted(
                (int(a), int(b)) for a, b in zip(tab.src, tab.dst))
        else:
            for s in sdb.shards:
                assert s.relations[name] is tab      # replicated, shared
    for s in sdb.shards:
        s.validate()
        for ename, etab in s.entities.items():       # entities replicated
            assert etab is db.entities[ename]


def test_shard_database_rejects_bad_args():
    db = mixed_db()
    with pytest.raises(ValueError):
        shard_database(db, 0)
    with pytest.raises(ValueError):
        shard_database(db, 2, root_etype="nope")


def test_route_decisions():
    db = mixed_db()
    sdb = shard_database(db, 2, root_etype="A")
    lattice = build_lattice(db.schema, 2)
    modes = {str(p): sdb.route(p) for p in lattice}
    assert modes["R1(B0,C0)"][0] == "single"        # only replicated tables
    assert modes["R0(A0,B0)"] == ("fanout", None)   # one partitioned atom
    assert modes["R0(A0,B0)&R2(A0,C0)"] == ("fanout", None)  # shared A0
    # single-shard picks a shard deterministically and in range
    mode, shard = modes["R1(B0,C0)"]
    assert 0 <= shard < 2


def test_route_rejects_incoherent_partition_vars():
    """Two partitioned atoms meeting the root type at DIFFERENT variables:
    their edges hash by different grounding values, so per-shard counts
    are not additive and route() must refuse."""
    db = mixed_db()
    sdb = shard_database(db, 2, root_etype="A")
    bad = LatticePoint((Atom("R0", Var("A", 1), Var("B", 0)),
                        Atom("R2", Var("A", 0), Var("C", 0))))
    with pytest.raises(NotRoutableError):
        sdb.route(bad)


# ---------------------------------------------------------------------------
# CountingRouter: merged answers == single-database answers
# ---------------------------------------------------------------------------

def _routable_points(sdb, lattice):
    out = []
    for p in lattice:
        try:
            sdb.route(p)
            out.append(p)
        except NotRoutableError:
            pass
    return out


@pytest.mark.parametrize("n_shards", [2, 3])
def test_router_merges_to_single_db_answer(n_shards):
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, n_shards)
    router = CountingRouter(sdb, executor="sparse")
    eng = CountingEngine(db, "sparse", CostStats())
    points = _routable_points(sdb, lattice)
    assert points                                   # workload is non-empty
    for point in points:
        want = eng.contract(point, None)
        got = router.count(point)
        assert got.vars == want.vars
        np.testing.assert_allclose(np.asarray(got.counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=str(point))
    snap = router.stats()
    assert snap["router"]["requests"] == len(points)
    assert snap["router"]["fanout_requests"] >= 1
    assert snap["router"]["single_shard_requests"] >= 1
    assert snap["aggregate"]["requests"] >= len(points)


def test_router_count_many_batches_per_shard():
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="dense", max_batch_size=32)
    eng = CountingEngine(db, "dense", CostStats())
    points = _routable_points(sdb, lattice)
    queries = [(p, None) for p in points] * 3       # repeats coalesce/hit
    tabs = router.count_many(queries)
    for (p, _), tab in zip(queries, tabs):
        want = eng.contract(p, None)
        np.testing.assert_allclose(np.asarray(tab.counts),
                                   np.asarray(want.counts), atol=1e-3)
    agg = router.stats()["aggregate"]
    rt = router.stats()["router"]
    assert agg["batched_queries"] >= 1              # shard services batched
    # repeats were cheap: absorbed by the router's own cache/in-flight
    # table (or, failing that, by the shard services)
    assert (rt["cache_hits"] + rt["coalesced"]
            + agg["cache"]["hits"] + agg["coalesced"]) >= 1


def test_router_mixed_flood_concurrent_clients():
    """Acceptance: a mixed flood over 2 database shards merges to the
    single-DB answer under concurrent client threads."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=4,
                            metrics=RouterMetrics())
    points = _routable_points(sdb, lattice)
    eng = CountingEngine(db, "sparse", CostStats())
    ref = {p: np.asarray(eng.contract(p, None).counts) for p in points}
    errors = []

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(6):
            p = points[int(rng.integers(len(points)))]
            try:
                tab = router.count(p)
                np.testing.assert_allclose(np.asarray(tab.counts), ref[p],
                                           atol=1e-3)
            except Exception as e:          # surface in the main thread
                errors.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    snap = router.stats()
    assert snap["router"]["requests"] == 24
    assert snap["router"]["merged_tables"] >= 1
    assert len(snap["shards"]) == 2


def test_router_count_many_prevalidates_mixed_list():
    """A non-routable query anywhere in a count_many list must fail the
    whole call BEFORE any shard work is enqueued."""
    db = mixed_db()
    sdb = shard_database(db, 2, root_etype="A")
    router = CountingRouter(sdb, executor="sparse")
    good = build_lattice(db.schema, 1)[0]
    bad = LatticePoint((Atom("R0", Var("A", 1), Var("B", 0)),
                        Atom("R2", Var("A", 0), Var("C", 0))))
    with pytest.raises(NotRoutableError):
        router.count_many([(good, None), (bad, None)])
    assert router.pending() == 0
    assert router.stats()["aggregate"]["enqueued"] == 0


def test_router_result_cache_and_coalescing():
    """A repeated query is served from the router's merged-result cache
    without touching any shard; identical concurrent fan-out queries
    coalesce onto ONE in-flight ticket (one execute + one merge)."""
    db = mixed_db()
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse")
    lattice = build_lattice(db.schema, 2)
    fanout = next(p for p in _routable_points(sdb, lattice)
                  if sdb.route(p)[0] == "fanout")

    # coalescing: two submits before any result -> the SAME ticket
    t1 = router.submit(fanout)
    t2 = router.submit(fanout)
    assert t2 is t1
    router.flush()
    tab1 = t1.result()
    np.testing.assert_array_equal(np.asarray(t2.result().counts),
                                  np.asarray(tab1.counts))
    rt = router.stats()["router"]
    assert rt["coalesced"] == 1
    assert rt["merged_tables"] == 2                 # merged exactly once

    # result cache: a later identical submit never reaches the shards
    shard_requests_before = router.stats()["aggregate"]["requests"]
    t3 = router.submit(fanout)
    assert t3.done
    np.testing.assert_array_equal(np.asarray(t3.result().counts),
                                  np.asarray(tab1.counts))
    snap = router.stats()
    assert snap["router"]["cache_hits"] == 1
    assert snap["aggregate"]["requests"] == shard_requests_before
    assert snap["router"]["merged_tables"] == 2     # still exactly once


def test_router_cache_disabled_and_lru_trim():
    db = mixed_db()
    sdb = shard_database(db, 2)
    points = _routable_points(sdb, build_lattice(db.schema, 2))
    off = CountingRouter(sdb, executor="sparse", cache_entries=0)
    off.count(points[0])
    off.count(points[0])
    assert off.stats()["router"]["cache_hits"] == 0
    tiny = CountingRouter(sdb, executor="sparse", cache_entries=1)
    tiny.count(points[0])
    tiny.count(points[1])                           # evicts points[0]
    assert len(tiny._results) == 1
    tiny.count(points[0])                           # miss -> recompute
    assert tiny.stats()["router"]["cache_hits"] == 0


def test_router_invalidate_keeps_stale_results_out():
    """invalidate() mid-flight: the ticket settles its waiters, but its
    pre-invalidate table must NOT be re-published into the cache."""
    db = mixed_db()
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse")
    p = _routable_points(sdb, build_lattice(db.schema, 2))[0]
    t = router.submit(p)
    router.invalidate()                   # data "refreshed" mid-flight
    assert t.result() is not None         # waiters settle fine …
    assert len(router._results) == 0      # … but stale data is not cached
    router.count(p)
    assert len(router._results) == 1      # the fresh epoch caches again


def test_router_metrics_rollup_counts_not_routable():
    db = mixed_db()
    sdb = shard_database(db, 2, root_etype="A")
    router = CountingRouter(sdb, executor="sparse")
    bad = LatticePoint((Atom("R0", Var("A", 1), Var("B", 0)),
                        Atom("R2", Var("A", 0), Var("C", 0))))
    with pytest.raises(NotRoutableError):
        router.submit(bad)
    snap = router.stats()["router"]
    assert snap["not_routable"] == 1 and snap["requests"] == 1


# ---------------------------------------------------------------------------
# Device-side merging: the fan-out reassembly fast path, the fused drain
# flush, and their host-path fallback all agree with the single-DB answer
# ---------------------------------------------------------------------------

def _force_host_merge(router):
    """Disable both fused device-merge paths on THIS router instance —
    count_many falls back to per-shard service submits and flush() to one
    concurrent svc.flush() per shard, so answers come through the
    original per-ticket merge."""
    router._count_many_fanout = lambda *a, **k: None
    router._fused_groups = lambda *a, **k: None


def _completable_points(sdb, lattice):
    """Routable points whose every butterfly positive sub-query is also
    routable (what complete-CT needs)."""
    from repro.core.mobius import positive_queries
    out = []
    for p in _routable_points(sdb, lattice):
        keep = tuple(p.all_ct_vars(sdb.schema, include_rind=True))
        try:
            for sp, _ in positive_queries(p, keep, use_butterfly=True):
                sdb.route(sp)
        except NotRoutableError:
            continue
        out.append(p)
    return out


@pytest.mark.parametrize("sname", ["HYBRID", "ONDEMAND", "PRECOUNT",
                                   "TUPLEID"])
def test_merge_parity_device_host_single_db_per_strategy(sname):
    """Device merge == host merge == single-DB strategy answer, for every
    counting strategy: the strategy computes the complete family CT on
    the unsharded database; a default router (fused device merging) and a
    host-fallback router answer the same workload over 2 shards."""
    from repro.core import make_strategy

    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    st = make_strategy(sname, executor="sparse")
    st.prepare(db, lattice)
    points = _completable_points(sdb, lattice)
    assert points
    queries = [(p, tuple(p.all_ct_vars(db.schema, include_rind=True)))
               for p in points]
    want = [np.asarray(st.family_ct(p, k).counts) for p, k in queries]

    dev = CountingRouter(sdb, executor="sparse")
    host = CountingRouter(sdb, executor="sparse")
    _force_host_merge(host)
    for router in (dev, host):
        tabs = router.complete_many(queries)
        for (p, _), tab, ref in zip(queries, tabs, want):
            np.testing.assert_allclose(
                np.asarray(tab.counts), ref, atol=1e-3,
                err_msg=f"{sname} {p} via "
                        f"{'device' if router is dev else 'host'} merge")
    # both routers merged on device (complete workloads mix fan-out and
    # single-shard sub-queries, so the FUSED dispatch may not engage —
    # but the host-forced router must never have fused)
    assert dev.stats()["router"]["device_merges"] >= 1
    assert host.stats()["router"]["fused_dispatches"] == 0
    assert host.stats()["router"]["merged_tables"] >= 1


def test_count_many_fanout_fast_path_bypasses_services():
    """An all-fan-out count_many reassembles shard inputs and answers at
    single-DB cost: no shard service sees a request, answers equal the
    single-DB engine, repeats hit the router cache, and invalidate()
    forces a fresh evaluation."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse")
    eng = CountingEngine(db, "sparse", CostStats())
    points = [p for p in _routable_points(sdb, lattice)
              if sdb.route(p)[0] == "fanout"]
    assert len(points) >= 2
    queries = [(p, None) for p in points]

    tabs = router.count_many(queries)
    for (p, _), tab in zip(queries, tabs):
        want = eng.contract(p, None)
        assert tab.vars == want.vars
        np.testing.assert_allclose(np.asarray(tab.counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=str(p))
    rt = router.stats()["router"]
    agg = router.stats()["aggregate"]
    assert rt["fused_dispatches"] >= 1
    assert rt["device_merges"] >= 1
    assert rt["fanout_requests"] == len(points)
    assert rt["merged_tables"] == len(points) * 2
    assert agg["enqueued"] == 0                     # services bypassed

    # duplicates inside ONE list: first occurrence evaluates, repeats
    # are absorbed (in-flight coalesce) without extra dispatches
    router.invalidate()
    before = router.stats()["router"]["fused_dispatches"]
    dup = router.count_many(queries + queries)
    np.testing.assert_array_equal(np.asarray(dup[0].counts),
                                  np.asarray(dup[len(points)].counts))
    rt = router.stats()["router"]
    assert rt["coalesced"] >= len(points)
    assert rt["fused_dispatches"] >= before + 1

    # repeats across calls: served from the router's merged-result cache
    before = rt["fused_dispatches"]
    router.count_many(queries)
    rt = router.stats()["router"]
    assert rt["cache_hits"] >= len(points)
    assert rt["fused_dispatches"] == before         # nothing re-evaluated

    # a later submit() of the same key is already resolved
    t = router.submit(points[0])
    assert t.done


def test_fused_flush_serves_submitted_tickets():
    """submit() + flush(): the drain-based fused dispatch computes every
    shard's table AND the merged table in one evaluation — tickets get
    the merged answer, shard services get their per-shard deliveries
    (metrics + caches), and the answers equal the single-DB engine."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=64)
    eng = CountingEngine(db, "sparse", CostStats())
    points = [p for p in _routable_points(sdb, lattice)
              if sdb.route(p)[0] == "fanout"]
    tickets = [router.submit(p) for p in points]
    router.flush()
    for p, t in zip(points, tickets):
        want = eng.contract(p, None)
        np.testing.assert_allclose(np.asarray(t.result().counts),
                                   np.asarray(want.counts), atol=1e-3,
                                   err_msg=str(p))
    snap = router.stats()
    assert snap["router"]["fused_dispatches"] >= 1
    # per-shard deliveries reached the services: batches observed and
    # results cached shard-side
    assert snap["aggregate"]["batches"] >= 2
    assert snap["aggregate"]["batched_queries"] >= 2 * len(points)
    assert snap["aggregate"]["cache"]["entries"] >= 1


def test_fused_flush_falls_back_on_misaligned_queues():
    """Unequal shard queues (a direct shard-service client alongside the
    router) cannot fuse: the drained work must still execute per shard
    and every waiter must settle with the right answer."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=64)
    eng = CountingEngine(db, "sparse", CostStats())
    points = [p for p in _routable_points(sdb, lattice)
              if sdb.route(p)[0] == "fanout"]
    services = router._snapshot()[1]
    t_router = router.submit(points[0])
    extra = points[1]
    t_direct = services[0].submit(extra)     # shard 0 queue is now longer
    router.flush()
    np.testing.assert_allclose(
        np.asarray(t_router.result().counts),
        np.asarray(eng.contract(points[0], None).counts), atol=1e-3)
    # the direct ticket holds shard 0's PARTIAL count (its slice of the
    # partitioned edges), not the merged answer — it must settle too
    assert t_direct.result() is not None
    assert router.stats()["router"]["fused_dispatches"] == 0


def test_partial_overlapped_merge_under_staggered_shards():
    """Host-path merging with 3 shards: when two shards settle before the
    third, their tables fold into a running partial while the last shard
    executes — partial_merges counts the overlapped fold, and the final
    table still equals the single-DB answer."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 3)
    router = CountingRouter(sdb, executor="sparse", max_batch_size=64)
    _force_host_merge(router)
    eng = CountingEngine(db, "sparse", CostStats())
    p = next(q for q in _routable_points(sdb, lattice)
             if sdb.route(q)[0] == "fanout")
    t = router.submit(p)
    services = router._snapshot()[1]
    services[0].flush()                      # two shards settle early …
    services[1].flush()
    tab = t.result()                         # … third flushes inside wait
    np.testing.assert_allclose(np.asarray(tab.counts),
                               np.asarray(eng.contract(p, None).counts),
                               atol=1e-3)
    rt = router.stats()["router"]
    assert rt["partial_merges"] >= 1
    assert rt["merged_tables"] == 3

    # and the merged table landed in the router cache zero-copy: the
    # cached entry IS the ticket's table object
    key = (p.atoms, router.engines[0].plan(p, None).keep)
    assert router._results[key] is tab


def test_fanout_fast_path_concurrent_with_deltas():
    """The fan-out fast path linearizes against apply_delta: concurrent
    floods and inserts interleave without torn reads — every flood answer
    matches the single-DB engine at SOME insert prefix (never a mix)."""
    db = mixed_db()
    lattice = build_lattice(db.schema, 2)
    sdb = shard_database(db, 2)
    router = CountingRouter(sdb, executor="sparse")
    points = [p for p in _routable_points(sdb, lattice)
              if sdb.route(p)[0] == "fanout"
              and any(a.rel == "R1" for a in p.atoms)][:3]
    assert points

    # two fresh ("R1" has no attrs) edges not present in the base store
    present = {(int(s), int(d)) for s, d in zip(db.relations["R1"].src,
                                                db.relations["R1"].dst)}
    inserts = [(s, d) for s in range(7) for d in range(6)
               if (s, d) not in present][:2]

    # reference tables at every insert prefix, from fresh single engines
    prefixes = []
    for i in range(len(inserts) + 1):
        ref_db = mixed_db()
        for s, d in inserts[:i]:
            ref_db.insert_facts("R1", [s], [d], None)
        eng = CountingEngine(ref_db, "sparse", CostStats())
        prefixes.append({p: np.asarray(eng.contract(p, None).counts)
                         for p in points})
    errors = []

    def flood():
        try:
            for _ in range(4):
                router.invalidate()        # measure the store, not cache
                tabs = router.count_many([(p, None) for p in points])
                got = {p: np.asarray(t.counts)
                       for p, t in zip(points, tabs)}
                ok = any(all(np.array_equal(got[p], pref[p])
                             for p in points) for pref in prefixes)
                assert ok, "flood observed a torn (mixed-delta) answer"
        except Exception as e:                   # pragma: no cover
            errors.append(e)

    def writer():
        try:
            for s, d in inserts:
                router.apply_delta("R1", [s], [d], None)
        except Exception as e:                   # pragma: no cover
            errors.append(e)

    ts = [threading.Thread(target=flood), threading.Thread(target=writer)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors
