"""Executor edge cases vs the brute-force oracle: empty relationship
tables, singleton (card-1) attribute domains, and ``keep=()`` queries must
all *count correctly*, not error — for both executors, unbatched and
batched, and for every strategy's complete-table path.
"""

import itertools

import numpy as np
import pytest

from repro.core import (Attribute, EntityType, Relationship, Schema,
                        CostStats, CountingEngine, build_lattice,
                        make_strategy, synth_db)
from repro.core.executors import EXECUTORS
from repro.core.oracle import oracle_ct
from repro.core.strategies import STRATEGIES

att = Attribute


def edge_case_db():
    """Empty relation (R1), card-1 entity attr (a0), card-1 edge attr (e2)
    in one schema."""
    ents = (EntityType("A", 6, (att("a0", 1), att("a1", 3))),
            EntityType("B", 5, (att("b0", 2),)))
    rels = (Relationship("R1", "A", "B", (att("e1", 3),)),
            Relationship("R2", "B", "A", (att("e2", 1),)))
    schema = Schema(ents, rels)
    return synth_db(schema, {"R1": 0, "R2": 6}, seed=0)


def chain_db():
    """Two-hop chain whose second relation is empty."""
    ents = (EntityType("A", 5, (att("a0", 2),)),
            EntityType("B", 4, (att("b0", 1),)),
            EntityType("C", 4, (att("c0", 3),)))
    rels = (Relationship("R0", "A", "B", ()),
            Relationship("R1", "B", "C", (att("e1", 2),)))
    schema = Schema(ents, rels)
    return synth_db(schema, {"R0": 7, "R1": 0}, seed=1)


@pytest.mark.parametrize("make_db", [edge_case_db, chain_db])
def test_positive_edge_cases_match_oracle(make_db):
    db = make_db()
    for point in build_lattice(db.schema, 2):
        for keep in [point.all_ct_vars(db.schema, include_rind=False), ()]:
            want = oracle_ct(db, point, keep, require_positive=True)
            for ex in sorted(EXECUTORS):
                eng = CountingEngine(db, ex, CostStats())
                got = eng.contract(point, keep)
                np.testing.assert_allclose(
                    np.asarray(got.counts), want, atol=1e-3,
                    err_msg=f"{ex} point={point} "
                            f"keep={[str(v) for v in keep]}")


@pytest.mark.parametrize("make_db", [edge_case_db, chain_db])
def test_batched_positive_edge_cases_match_oracle(make_db):
    """The stacked/vmapped path handles the same degenerate inputs."""
    db = make_db()
    for point in build_lattice(db.schema, 2):
        for keep in [point.all_ct_vars(db.schema, include_rind=False), ()]:
            want = oracle_ct(db, point, keep, require_positive=True)
            for ex in sorted(EXECUTORS):
                eng = CountingEngine(db, ex, CostStats())
                plan = eng.plan(point, keep)
                tabs = eng.executor.positive_batch(db, [plan, plan, plan],
                                                   CostStats())
                for got in tabs:
                    np.testing.assert_allclose(
                        np.asarray(got.counts), want, atol=1e-3,
                        err_msg=f"batched {ex} point={point}")


def test_complete_edge_cases_all_strategies():
    db = chain_db()
    lattice = build_lattice(db.schema, 2)
    chain = next(p for p in lattice if p.length == 2)
    keep_all = chain.all_ct_vars(db.schema, include_rind=True)
    want_all = oracle_ct(db, chain, keep_all)
    want_scalar = oracle_ct(db, chain, ())
    for sname, ex in itertools.product(sorted(STRATEGIES), sorted(EXECUTORS)):
        st = make_strategy(sname, executor=ex)
        st.prepare(db, lattice)
        got = st.family_ct(chain, keep_all)
        np.testing.assert_allclose(np.asarray(got.counts), want_all,
                                   atol=1e-3, err_msg=f"{sname}/{ex}")
        got0 = st.family_ct(chain, ())
        np.testing.assert_allclose(np.asarray(got0.counts), want_scalar,
                                   atol=1e-3, err_msg=f"{sname}/{ex} keep=()")


def test_validate_accepts_empty_relation():
    db = edge_case_db()
    assert db.relations["R1"].num_edges == 0
    db.validate()           # must not raise on the empty table
