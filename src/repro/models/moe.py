"""Routed mixture-of-experts with sort-based capacity dispatch.

TPU adaptation: instead of the GShard one-hot dispatch einsum (whose
[groups, tokens, experts, capacity] tensor is quadratically wasteful at
top-8/128e), tokens are ranked *within their expert* via an argsort +
running-position trick — all static shapes — and scattered into a
[B, E, C, D] capacity buffer.  Expert FFNs are a batched einsum over the
expert axis, which the sharding rules place on the ``model`` mesh axis
(expert parallelism); the scatter/gather across the batch->expert sharding
boundary is the MoE all-to-all.

Over-capacity tokens are dropped (standard capacity-factor semantics); the
router uses softmax-then-topk with the auxiliary load-balancing loss of
Shazeer et al.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init
from .mlp import MlpParams, mlp_apply
from .pspec import constrain


class MoeParams(NamedTuple):
    router: jnp.ndarray              # [D, E]
    wi: jnp.ndarray                  # [E, D, F]
    wo: jnp.ndarray                  # [E, F, D]
    wg: Optional[jnp.ndarray] = None # [E, D, F] (swiglu)


def moe_init(key, cfg: ModelConfig) -> MoeParams:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 4)
    shape_in = (e, d, f)
    wi = (jax.random.normal(ks[0], shape_in, jnp.float32) * d ** -0.5).astype(dt)
    wo = (jax.random.normal(ks[1], (e, f, d), jnp.float32) * f ** -0.5).astype(dt)
    wg = ((jax.random.normal(ks[3], shape_in, jnp.float32) * d ** -0.5).astype(dt)
          if cfg.mlp == "swiglu" else None)
    return MoeParams(router=dense_init(ks[2], d, e, jnp.float32),
                     wi=wi, wo=wo, wg=wg)


def _capacity(tokens_per_group: int, top_k: int, n_experts: int,
              factor: float) -> int:
    c = int(tokens_per_group * top_k * factor / n_experts)
    return max(c, 1)


def moe_apply(p: MoeParams, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).  Dispatches on
    ``cfg.moe_impl``: "ep" = shard_map expert parallelism (local dispatch +
    one psum combine), "spmd" = sharding-constraint GSPMD path (baseline;
    XLA replicates the dispatch scatter — see EXPERIMENTS.md §Perf H1)."""
    if cfg.moe_impl == "ep":
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty and "model" in am.axis_names \
                and cfg.n_experts % am.shape["model"] == 0:
            return _moe_apply_ep(p, x, cfg, am)
    return _moe_apply_spmd(p, x, cfg)


def _moe_apply_ep(p: MoeParams, x: jnp.ndarray, cfg: ModelConfig, am
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert parallelism as shard_map: activations are replicated across
    ``model`` (the Megatron MLP invariant), so every expert shard computes
    the (cheap) routing redundantly, *locally* gathers only the tokens bound
    for its own experts, runs its expert FFNs, scatters partial outputs back
    to token order, and one ``psum`` over ``model`` combines.  Dispatch
    moves ZERO bytes over links; combine costs one [b_l, S, D] all-reduce
    per layer — the same wire cost as a dense TP MLP."""
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P

    names = set(am.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    n_fsdp = int(np.prod([am.shape[a] for a in fsdp])) if fsdp else 1
    bspec = fsdp if (fsdp and x.shape[0] % n_fsdp == 0) else None
    n_model = am.shape["model"]

    x_spec = P(bspec, None, None)
    w_spec = MoeParams(router=P(None, None), wi=P("model", None, None),
                       wo=P("model", None, None),
                       wg=None if p.wg is None else P("model", None, None))

    def body(x_l, p_l):
        out, me, ce = _moe_local(p_l, x_l, cfg, n_model)
        out = jax.lax.psum(out, "model")
        if bspec:
            me = jax.lax.pmean(me, bspec)    # global load stats, so the
            ce = jax.lax.pmean(ce, bspec)    # nonlinear aux matches GSPMD
        aux = jnp.sum(me * ce) * cfg.n_experts
        return out, aux.astype(jnp.float32)

    fn = shard_map(body, mesh=am, in_specs=(x_spec, w_spec),
                   out_specs=(x_spec, P()), check_vma=False)
    return fn(x, p)


def _moe_local(p: MoeParams, x: jnp.ndarray, cfg: ModelConfig, n_model: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-shard MoE: route all tokens, keep only local experts' slots."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    epl = e // n_model                                   # experts per shard
    c = _capacity(s, k, e, cfg.capacity_factor)
    m_idx = jax.lax.axis_index("model") if n_model > 1 else 0
    lo = m_idx * epl

    logits = jnp.einsum("bsd,de->bse", x, p.router.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # [B,S,K]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1)) / k

    a = s * k
    flat_e = eidx.reshape(b, a)
    flat_t = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(a)
    flat_g = gate.reshape(b, a)
    order = jnp.argsort(flat_e, axis=1)
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    t_sorted = jnp.take_along_axis(jnp.broadcast_to(flat_t, (b, a)), order, axis=1)
    g_sorted = jnp.take_along_axis(flat_g, order, axis=1)
    ar = jnp.arange(a)
    change = jnp.concatenate(
        [jnp.ones((b, 1), bool), e_sorted[:, 1:] != e_sorted[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(change, ar[None, :], 0), axis=1)
    pos = ar[None, :] - run_start
    local = (pos < c) & (e_sorted >= lo) & (e_sorted < lo + epl)
    slot = jnp.where(local, (e_sorted - lo) * c + pos, epl * c)

    xt = jnp.take_along_axis(x, t_sorted[..., None], axis=1)   # [B, A, D]
    bidx = jnp.arange(b)[:, None]
    buf = jnp.zeros((b, epl * c + 1, d), x.dtype)
    buf = buf.at[bidx, slot].add(xt)                     # local scatter
    buf = buf[:, : epl * c].reshape(b, epl, c, d)

    wi = jax.lax.dynamic_slice_in_dim(p.wi, lo, epl, 0) \
        if p.wi.shape[0] != epl else p.wi
    wo = jax.lax.dynamic_slice_in_dim(p.wo, lo, epl, 0) \
        if p.wo.shape[0] != epl else p.wo
    h = jnp.einsum("becd,edf->becf", buf, wi.astype(buf.dtype))
    if p.wg is not None:
        wg = jax.lax.dynamic_slice_in_dim(p.wg, lo, epl, 0) \
            if p.wg.shape[0] != epl else p.wg
        g2 = jnp.einsum("becd,edf->becf", buf, wg.astype(buf.dtype))
        h = jax.nn.silu(g2.astype(jnp.float32)).astype(buf.dtype) * h
    else:
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(buf.dtype)
    eo = jnp.einsum("becf,efd->becd", h, wo.astype(buf.dtype))
    eo = eo.reshape(b, epl * c, d)
    eo = jnp.concatenate([eo, jnp.zeros((b, 1, d), eo.dtype)], axis=1)

    back = eo[bidx, slot]                                # [B, A, D]
    back = back * (g_sorted * local)[..., None].astype(back.dtype)
    out = jnp.zeros((b, s, d), x.dtype)
    out = out.at[bidx, t_sorted].add(back)
    return out, me, ce


def _moe_apply_spmd(p: MoeParams, x: jnp.ndarray, cfg: ModelConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GSPMD baseline (sharding constraints only)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(s, k, e, cfg.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p.router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # [B,S,K]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # aux load-balancing loss
    me = jnp.mean(probs, axis=(0, 1))                    # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=2), axis=(0, 1)) / k
    aux = jnp.sum(me * ce) * e

    # ---- sort-based positions within expert, per group --------------------
    a = s * k
    flat_e = eidx.reshape(b, a)                          # [B, A]
    flat_t = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(a)
    flat_g = gate.reshape(b, a)
    order = jnp.argsort(flat_e, axis=1)                  # stable
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    t_sorted = jnp.take_along_axis(jnp.broadcast_to(flat_t, (b, a)), order, axis=1)
    g_sorted = jnp.take_along_axis(flat_g, order, axis=1)
    ar = jnp.arange(a)
    change = jnp.concatenate(
        [jnp.ones((b, 1), bool), e_sorted[:, 1:] != e_sorted[:, :-1]], axis=1)
    run_start = jax.lax.cummax(jnp.where(change, ar[None, :], 0), axis=1)
    pos = ar[None, :] - run_start                        # rank within expert
    keep = pos < c
    slot = jnp.where(keep, e_sorted * c + pos, e * c)    # drop -> sentinel row

    # ---- dispatch: gather token features into the capacity buffer ---------
    xt = jnp.take_along_axis(x, t_sorted[..., None], axis=1)   # [B, A, D]
    buf = jnp.zeros((b, e * c + 1, d), x.dtype)
    bidx = jnp.arange(b)[:, None]
    buf = buf.at[bidx, slot].add(xt)                     # all-to-all boundary
    buf = buf[:, : e * c].reshape(b, e, c, d)
    buf = constrain(buf, "B", "T", None, None)           # EP layout

    # ---- expert FFN (batched over the expert axis = EP) -------------------
    h = jnp.einsum("becd,edf->becf", buf, p.wi.astype(buf.dtype))
    if p.wg is not None:
        g2 = jnp.einsum("becd,edf->becf", buf, p.wg.astype(buf.dtype))
        h = jax.nn.silu(g2.astype(jnp.float32)).astype(buf.dtype) * h
    else:
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(buf.dtype)
    eo = jnp.einsum("becf,efd->becd", h, p.wo.astype(buf.dtype))
    eo = constrain(eo, "B", "T", None, None)
    eo = eo.reshape(b, e * c, d)
    eo = jnp.concatenate([eo, jnp.zeros((b, 1, d), eo.dtype)], axis=1)

    # ---- combine: weighted scatter-add back to token order ----------------
    back = eo[bidx, slot]                                # [B, A, D]
    back = back * (g_sorted * keep)[..., None].astype(back.dtype)
    out = jnp.zeros((b, s, d), x.dtype)
    out = out.at[bidx, t_sorted].add(back)
    return out, aux.astype(jnp.float32)
