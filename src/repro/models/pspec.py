"""Activation sharding constraints that respect the ambient mesh.

``constrain(x, "B", "T", None, ...)`` applies with_sharding_constraint using
the current abstract mesh: "B" -> the FSDP/batch axes present in the mesh
(('pod','data') or ('data',)), "T" -> the tensor axis 'model'.  Outside any
mesh context (CPU smoke tests) it is a no-op, so model code stays portable.
Dims that do not divide the axis size are left unconstrained."""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def constrain(x, *dims):
    am = jax.sharding.get_abstract_mesh()
    if am is None or am.empty:
        return x
    names = set(am.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for sym, size in zip(dims, x.shape):
        if sym == "B" and fsdp:
            n = int(np.prod([am.shape[a] for a in fsdp]))
            spec.append(fsdp if size % n == 0 else None)
        elif sym == "T" and "model" in names:
            spec.append("model" if size % am.shape["model"] == 0 else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
