"""Model and shape configuration.

One :class:`ModelConfig` describes any architecture in the assigned pool —
dense / MoE / VLM / SSM / hybrid / encoder-decoder — through the ``block``
field plus family-specific knobs.  Performance levers that the §Perf
hillclimb iterates on (attention chunk size, MoE capacity factor, remat
policy, optimizer state dtype, logits sharding) are explicit fields so every
experiment is a config diff.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block: str = "attn"            # attn | moe | rwkv | hymba
    head_dim: Optional[int] = None # defaults to d_model // n_heads
    mlp: str = "swiglu"            # swiglu | sq_relu | gelu
    qkv_bias: bool = False
    rope: str = "rope"             # rope | mrope | none
    rope_theta: float = 1e6

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    moe_impl: str = "ep"           # ep (shard_map expert-parallel) | spmd

    # --- RWKV / SSM ---
    ssm_state: int = 16
    rwkv_head_dim: int = 64
    ssm_heads: int = 0             # hymba parallel mamba heads

    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500         # stub frontend output length

    # --- modality frontend stub (vlm / audio): inputs are embeddings ---
    embeds_input: bool = False

    # --- numerics / perf levers ---
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    attn_chunk: int = 512          # query-block size for chunked attention
    remat: bool = True
    scan_layers: bool = True
    microbatch: int = 1            # gradient-accumulation steps
    logits_fp32: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.block == "moe"

    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (for MODEL_FLOPS = 6*N*D roofline term)
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq, hk, hd = self.n_heads, self.n_kv_heads, self.hd
        def attn_p():
            return d * hq * hd + 2 * d * hk * hd + hq * hd * d
        def mlp_p(ff):
            return d * ff * (3 if self.mlp == "swiglu" else 2)
        per_layer = 0
        if self.block == "attn":
            per_layer = attn_p() + mlp_p(f) + 2 * d
        elif self.block == "moe":
            ne = (self.top_k if active_only else self.n_experts)
            per_layer = attn_p() + ne * mlp_p(f) + 2 * d
            if self.dense_residual:
                per_layer += mlp_p(f)
            per_layer += d * self.n_experts  # router
        elif self.block == "rwkv":
            hr = self.d_model // self.rwkv_head_dim
            per_layer = 6 * d * d + mlp_p(f) + 2 * d   # r,k,v,g,o,decay + channel mix
        elif self.block == "hymba":
            n = self.ssm_state
            ssm = d * (2 * d) + d * (2 * n) + d + d * d   # in/out proj + B,C,dt
            per_layer = attn_p() + ssm + mlp_p(f) + 2 * d
        n_p = self.n_layers * per_layer + v * d + d
        if self.enc_dec:
            enc_per = attn_p() + mlp_p(f) + 2 * d
            cross = attn_p()
            n_p += self.enc_layers * enc_per + self.n_layers * cross
        return int(n_p)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = ("rwkv6-1.6b", "hymba-1.5b")


def shape_cells(arch: str) -> Tuple[str, ...]:
    """The shape cells assigned to an architecture (skip rules per DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        cells.append("long_500k")
    return tuple(cells)
