"""Model assembly: blocks, scan-over-layers stacks, train/prefill/decode.

* One homogeneous block type per architecture (attn | moe | rwkv | hymba),
  stacked with ``lax.scan`` over a [L, ...] parameter pytree (HLO size is
  O(1) in depth — essential for 96-layer dry-runs) and per-layer ``remat``.
* Decode: KV caches are [L, B, S, Hkv, hd] with the sequence axis shardable
  over the ``model`` mesh axis; the flash-decoding combine runs inside
  ``shard_map`` (see ``decode_attention``).
* Whisper: encoder stack + decoder blocks with cross-attention; the audio
  frontend is a stub — inputs are precomputed frame embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from ..compat import shard_map

from .attention import (AttnParams, attn_init, block_attention,
                        combine_partials, decode_partial, qkv_project,
                        sharded_attention)
from .config import ModelConfig
from .layers import (embed_init, embed_lookup, rms_norm, sinusoidal_positions,
                     tied_logits)
from .mlp import MlpParams, mlp_apply, mlp_init
from .moe import MoeParams, moe_apply, moe_init
from .rwkv import (RwkvParams, rwkv_channel_mix, rwkv_channel_mix_decode,
                   rwkv_init, rwkv_token_mix, rwkv_token_mix_decode)
from .ssm import SsmParams, ssm_apply, ssm_decode, ssm_init


# ---------------------------------------------------------------- blocks ---

def block_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.ones((d,), jnp.float32),
                         "norm2": jnp.ones((d,), jnp.float32)}
    if cfg.block == "attn":
        p["attn"] = attn_init(ks[0], cfg)
        p["mlp"] = mlp_init(ks[1], cfg)
    elif cfg.block == "moe":
        p["attn"] = attn_init(ks[0], cfg)
        p["moe"] = moe_init(ks[1], cfg)
        if cfg.dense_residual:
            p["dense"] = mlp_init(ks[2], cfg)
    elif cfg.block == "rwkv":
        p["rwkv"] = rwkv_init(ks[0], cfg)
    elif cfg.block == "hymba":
        p["attn"] = attn_init(ks[0], cfg)
        p["ssm"] = ssm_init(ks[1], cfg)
        p["mlp"] = mlp_init(ks[2], cfg)
    else:
        raise ValueError(cfg.block)
    return p


def block_apply(p: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig,
                positions, causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block (train / prefill).  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.block == "rwkv":
        h, _ = rwkv_token_mix(p["rwkv"], rms_norm(x, p["norm1"]), cfg)
        x = x + h
        h, _ = rwkv_channel_mix(p["rwkv"], rms_norm(x, p["norm2"]))
        return x + h, aux
    n1 = rms_norm(x, p["norm1"])
    q, k, v = qkv_project(p["attn"], n1, cfg, positions)
    ao = sharded_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    b, s, hq, hd = ao.shape
    ao = jnp.einsum("bsh,hd->bsd", ao.reshape(b, s, hq * hd),
                    p["attn"].wo.astype(x.dtype))
    if cfg.block == "hymba":
        so, _ = ssm_apply(p["ssm"], n1, cfg)
        ao = (ao + so) * 0.5
    x = x + ao
    n2 = rms_norm(x, p["norm2"])
    if cfg.block == "moe":
        mo, aux = moe_apply(p["moe"], n2, cfg)
        if cfg.dense_residual:
            mo = mo + mlp_apply(p["dense"], n2, cfg.mlp)
    else:
        mo = mlp_apply(p["mlp"], n2, cfg.mlp)
    return x + mo, aux


# ------------------------------------------------------- decode attention ---

def decode_attention(q, cache_k, cache_v, new_k, new_v, pos,
                     dp_axes: Optional[tuple], seq_axis: Optional[str],
                     mesh=None):
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q [B,Hq,hd]; cache_k/v [B,S,Hkv,hd]; new_k/v [B,Hkv,hd]; pos scalar i32.
    When ``seq_axis`` is set the cache S axis is sharded over that mesh axis
    and the softmax is combined with one psum (flash-decoding)."""

    def local(q_, k_, v_, nk, nv, shards, shard_idx):
        s_local = k_.shape[1]
        off = shard_idx * s_local
        lpos = pos - off
        in_rng = (lpos >= 0) & (lpos < s_local)
        li = jnp.clip(lpos, 0, s_local - 1)
        k2 = jax.lax.dynamic_update_slice(k_, nk[:, None], (0, li, 0, 0))
        v2 = jax.lax.dynamic_update_slice(v_, nv[:, None], (0, li, 0, 0))
        k_ = jnp.where(in_rng, k2, k_)
        v_ = jnp.where(in_rng, v2, v_)
        valid = (off + jnp.arange(s_local))[None, :] <= pos
        valid = jnp.broadcast_to(valid, (k_.shape[0], s_local))
        part = decode_partial(q_, k_, v_, valid)
        return k_, v_, part

    if seq_axis is None:
        k_, v_, part = local(q, cache_k, cache_v, new_k, new_v, 1, 0)
        return combine_partials(part, None).astype(q.dtype), k_, v_

    def inner(q_, k_, v_, nk, nv):
        idx = jax.lax.axis_index(seq_axis)
        k_, v_, part = local(q_, k_, v_, nk, nv,
                             jax.lax.axis_size(seq_axis), idx)
        o = combine_partials(part, seq_axis)
        return o.astype(q_.dtype), k_, v_

    # batch must divide the dp axes to be shard_map'd over them; replicate
    # the batch otherwise (e.g. long_500k's global_batch=1)
    if dp_axes and mesh is not None:
        import numpy as _np
        dp_size = int(_np.prod([mesh.shape[a] for a in dp_axes]))
        if q.shape[0] % dp_size != 0:
            dp_axes = None
    qspec = P(dp_axes if dp_axes else None, None, None)
    kvspec = P(dp_axes if dp_axes else None, seq_axis, None, None)
    fn = shard_map(inner, mesh=mesh,
                   in_specs=(qspec, kvspec, kvspec, qspec, qspec),
                   out_specs=(qspec, kvspec, kvspec), check_vma=False)
    return fn(q, cache_k, cache_v, new_k, new_v)


def block_decode(p: Dict[str, Any], x1: jnp.ndarray, cache: Dict[str, Any],
                 cfg: ModelConfig, pos, positions,
                 dp_axes=None, seq_axis=None, mesh=None):
    """One-token block step.  x1 [B, D].  Returns (x1, new_cache)."""
    new_cache = dict(cache)
    if cfg.block == "rwkv":
        h, st = rwkv_token_mix_decode(
            p["rwkv"], rms_norm(x1, p["norm1"]), cfg,
            (cache["tm_x"], cache["wkv"]))
        x1 = x1 + h
        new_cache["tm_x"], new_cache["wkv"] = st
        h, cmx = rwkv_channel_mix_decode(
            p["rwkv"], rms_norm(x1, p["norm2"]), cache["cm_x"])
        new_cache["cm_x"] = cmx
        return x1 + h, new_cache
    n1 = rms_norm(x1, p["norm1"])
    q, k, v = qkv_project(p["attn"], n1[:, None], cfg, positions)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    o, ck, cv = decode_attention(q, cache["k"], cache["v"], k, v, pos,
                                 dp_axes, seq_axis, mesh)
    new_cache["k"], new_cache["v"] = ck, cv
    b = x1.shape[0]
    ao = (o.reshape(b, -1) @ p["attn"].wo.astype(x1.dtype))
    if cfg.block == "hymba":
        so, s1 = ssm_decode(p["ssm"], n1, cfg, cache["ssm"])
        new_cache["ssm"] = s1
        ao = (ao + so) * 0.5
    x1 = x1 + ao
    n2 = rms_norm(x1, p["norm2"])
    if cfg.block == "moe":
        mo, _ = moe_apply(p["moe"], n2[:, None], cfg)
        mo = mo[:, 0]
        if cfg.dense_residual:
            mo = mo + mlp_apply(p["dense"], n2[:, None], cfg.mlp)[:, 0]
    else:
        mo = mlp_apply(p["mlp"], n2[:, None], cfg.mlp)[:, 0]
    return x1 + mo, new_cache


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Per-layer decode cache (stacked [L, ...])."""
    l, hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    dt = cfg.act_dtype()
    c: Dict[str, Any] = {}
    if cfg.block in ("attn", "moe", "hymba"):
        c["k"] = jnp.zeros((l, batch, seq, hk, hd), dt)
        c["v"] = jnp.zeros((l, batch, seq, hk, hd), dt)
    if cfg.block == "hymba":
        h, hdv = cfg.ssm_heads, cfg.hd
        c["ssm"] = jnp.zeros((l, batch, h, cfg.ssm_state, hdv), jnp.float32)
    if cfg.block == "rwkv":
        d = cfg.d_model
        h = d // cfg.rwkv_head_dim
        c["tm_x"] = jnp.zeros((l, batch, d), dt)
        c["cm_x"] = jnp.zeros((l, batch, d), dt)
        c["wkv"] = jnp.zeros((l, batch, h, cfg.rwkv_head_dim,
                              cfg.rwkv_head_dim), jnp.float32)
    if cfg.enc_dec:
        c["xk"] = jnp.zeros((l, batch, cfg.enc_frames, hk, hd), dt)
        c["xv"] = jnp.zeros((l, batch, cfg.enc_frames, hk, hd), dt)
    return c


# ------------------------------------------------------ whisper enc/dec -----

def cross_block_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = block_init(ks[0], cfg)
    p["norm_x"] = jnp.ones((d,), jnp.float32)
    p["xattn"] = attn_init(ks[1], cfg)
    return p


def cross_block_apply(p, x, enc_kv, cfg: ModelConfig, positions):
    """Decoder block with cross-attention.  enc_kv = (k, v) precomputed."""
    n1 = rms_norm(x, p["norm1"])
    q, k, v = qkv_project(p["attn"], n1, cfg, positions)
    ao = sharded_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    b, s, hq, hd = ao.shape
    x = x + jnp.einsum("bsh,hd->bsd", ao.reshape(b, s, hq * hd),
                       p["attn"].wo.astype(x.dtype))
    nx = rms_norm(x, p["norm_x"])
    qx = jnp.einsum("bsd,dh->bsh", nx, p["xattn"].wq.astype(x.dtype))
    qx = qx.reshape(b, s, cfg.n_heads, hd)
    xo = block_attention(qx, enc_kv[0], enc_kv[1], causal=False,
                         chunk=cfg.attn_chunk)
    x = x + jnp.einsum("bsh,hd->bsd", xo.reshape(b, s, cfg.n_heads * hd),
                       p["xattn"].wo.astype(x.dtype))
    n2 = rms_norm(x, p["norm2"])
    return x + mlp_apply(p["mlp"], n2, cfg.mlp), jnp.zeros((), jnp.float32)


def cross_kv(p, enc_out, cfg: ModelConfig):
    b, f, d = enc_out.shape
    hk, hd = cfg.n_kv_heads, cfg.hd
    k = jnp.einsum("bfd,dh->bfh", enc_out, p["xattn"].wk.astype(enc_out.dtype))
    v = jnp.einsum("bfd,dh->bfh", enc_out, p["xattn"].wv.astype(enc_out.dtype))
    return k.reshape(b, f, hk, hd), v.reshape(b, f, hk, hd)
