"""Top-level models: decoder LMs (dense/MoE/RWKV/Hymba/VLM) and the whisper
encoder-decoder, with a uniform functional API:

    lm = build_model(cfg)
    params = lm.init(key)
    loss, metrics = lm.loss(params, batch)
    logits, cache = lm.prefill(params, batch)
    logits, cache = lm.decode_step(params, cache, batch)

``batch`` layouts are produced by ``repro.launch.specs.input_specs`` (real
arrays or ShapeDtypeStructs — the same code lowers for the dry-run)."""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import qkv_project, block_attention, sharded_attention
from .config import ModelConfig
from .layers import embed_init, embed_lookup, rms_norm, sinusoidal_positions, tied_logits
from .mlp import mlp_apply
from .moe import moe_apply
from .pspec import constrain
from .rwkv import rwkv_channel_mix, rwkv_token_mix
from .ssm import ssm_apply
from .transformer import (block_apply, block_decode, block_init,
                          cross_block_apply, cross_block_init, cross_kv,
                          init_cache)

AUX_COEF = 0.01


def _positions_for(cfg: ModelConfig, batch: Dict[str, Any], seq: int):
    if cfg.rope == "none":
        return None
    if cfg.rope == "mrope":
        return batch["positions"]                    # [3, B, S]
    lead = (batch.get("tokens", batch.get("embeds"))).shape[0]
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (lead, seq))


class LM:
    """Decoder-only language model with scan-over-layers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_blocks, k_out = jax.random.split(key, 3)
        block_keys = jax.random.split(k_blocks, cfg.n_layers)
        blocks = jax.vmap(lambda k: block_init(k, cfg))(block_keys)
        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.p_dtype()),
            "blocks": blocks,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }

    # ------------------------------------------------------------- forward
    def _embed_in(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.embeds_input:
            x = batch["embeds"].astype(cfg.act_dtype())
        else:
            x = embed_lookup(params["embed"], batch["tokens"]
                             ).astype(cfg.act_dtype())
        return constrain(x, "B", None, None)

    def _stack(self, params, x, positions):
        cfg = self.cfg

        def body(carry, layer_params):
            h, aux = carry
            h, a = block_apply(layer_params, h, cfg, positions)
            return (h, aux + a), None

        fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                       params["blocks"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["blocks"])
                (x, aux), _ = fn((x, aux), lp)
        return x, aux

    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        x = self._embed_in(params, batch)
        positions = _positions_for(cfg, batch, x.shape[1])
        x, aux = self._stack(params, x, positions)
        x = rms_norm(x, params["final_norm"])
        logits = tied_logits(params["embed"], x, fp32=cfg.logits_fp32)
        return constrain(logits, "B", None, "T"), aux

    # ---------------------------------------------------------------- loss
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(nll)
        total = ce + AUX_COEF * aux
        return total, {"ce": ce, "aux": aux,
                       "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}

    # ------------------------------------------------------------- prefill
    def prefill(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Returns (last-position logits [B, V], cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        b, s, _ = x.shape
        positions = _positions_for(cfg, batch, s)

        def body(carry, layer_params):
            h, aux = carry
            h, kv, a = self._block_prefill(layer_params, h, positions)
            return (h, aux + a), kv

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, _), kvs = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        x = rms_norm(x, params["final_norm"])
        logits = tied_logits(params["embed"], x[:, -1:], fp32=cfg.logits_fp32)
        return logits[:, 0], kvs

    def _block_prefill(self, p, x, positions):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if cfg.block == "rwkv":
            n1 = rms_norm(x, p["norm1"])
            h, (tm_x, wkv) = rwkv_token_mix(p["rwkv"], n1, cfg)
            x = x + h
            h, cm_x = rwkv_channel_mix(p["rwkv"], rms_norm(x, p["norm2"]))
            return x + h, {"tm_x": tm_x, "cm_x": cm_x, "wkv": wkv}, aux
        n1 = rms_norm(x, p["norm1"])
        q, k, v = qkv_project(p["attn"], n1, cfg, positions)
        ao = sharded_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        b, s, hq, hd = ao.shape
        ao = jnp.einsum("bsh,hd->bsd", ao.reshape(b, s, hq * hd),
                        p["attn"].wo.astype(x.dtype))
        kv = {"k": k, "v": v}
        if cfg.block == "hymba":
            so, s1 = ssm_apply(p["ssm"], n1, cfg)
            kv["ssm"] = s1
            ao = (ao + so) * 0.5
        x = x + ao
        n2 = rms_norm(x, p["norm2"])
        if cfg.block == "moe":
            mo, aux = moe_apply(p["moe"], n2, cfg)
            if cfg.dense_residual:
                mo = mo + mlp_apply(p["dense"], n2, cfg.mlp)
        else:
            mo = mlp_apply(p["mlp"], n2, cfg.mlp)
        return x + mo, kv, aux

    # ---------------------------------------------------------- decode step
    def decode_step(self, params, cache, batch, *, dp_axes=None,
                    seq_axis=None, mesh=None):
        """One token for the whole batch.  batch: {"token": [B,1], "pos": i32
        scalar (position being written)}.  Returns (logits [B, V], cache)."""
        cfg = self.cfg
        pos = batch["pos"]
        if cfg.embeds_input and "embed1" in batch:
            x1 = batch["embed1"].astype(cfg.act_dtype())[:, 0]
        else:
            x1 = embed_lookup(params["embed"], batch["token"][:, 0]
                              ).astype(cfg.act_dtype())
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(
                pos.astype(jnp.int32), (3, x1.shape[0], 1))
        elif cfg.rope == "rope":
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (x1.shape[0], 1))
        else:
            positions = None

        def body(x1, layer_in):
            layer_params, layer_cache = layer_in
            x1, new_cache = block_decode(layer_params, x1, layer_cache, cfg,
                                         pos, positions, dp_axes=dp_axes,
                                         seq_axis=seq_axis, mesh=mesh)
            return x1, new_cache

        x1, new_cache = jax.lax.scan(body, x1, (params["blocks"], cache))
        x1 = rms_norm(x1, params["final_norm"])
        logits = tied_logits(params["embed"], x1, fp32=cfg.logits_fp32)
        return logits, new_cache

    def init_cache(self, batch: int, seq: int):
        return init_cache(self.cfg, batch, seq)


class EncDecLM(LM):
    """Whisper-style encoder-decoder (few layers: unrolled, no scan)."""

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_enc, k_dec = jax.random.split(key, 3)
        enc_keys = jax.random.split(k_enc, cfg.enc_layers)
        dec_keys = jax.random.split(k_dec, cfg.n_layers)
        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.p_dtype()),
            "enc": [block_init(k, cfg) for k in enc_keys],
            "dec": [cross_block_init(k, cfg) for k in dec_keys],
            "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames.astype(cfg.act_dtype())
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        for p in params["enc"]:
            x, _ = block_apply(p, x, cfg, None, causal=False)
        return rms_norm(x, params["enc_norm"])

    def forward(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = embed_lookup(params["embed"], batch["tokens"]).astype(cfg.act_dtype())
        s = x.shape[1]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
        for p in params["dec"]:
            ekv = cross_kv(p, enc_out, cfg)
            x, _ = cross_block_apply(p, x, ekv, cfg, None)
        x = rms_norm(x, params["final_norm"])
        return tied_logits(params["embed"], x, fp32=cfg.logits_fp32), jnp.zeros((), jnp.float32)

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = embed_lookup(params["embed"], batch["tokens"]).astype(cfg.act_dtype())
        b, s = x.shape[:2]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
        cache: Dict[str, Any] = {"k": [], "v": [], "xk": [], "xv": []}
        for p in params["dec"]:
            ekv = cross_kv(p, enc_out, cfg)
            n1 = rms_norm(x, p["norm1"])
            q, k, v = qkv_project(p["attn"], n1, cfg, None)
            cache["k"].append(k)
            cache["v"].append(v)
            cache["xk"].append(ekv[0])
            cache["xv"].append(ekv[1])
            x, _ = cross_block_apply(p, x, ekv, cfg, None)
        x = rms_norm(x, params["final_norm"])
        logits = tied_logits(params["embed"], x[:, -1:], fp32=cfg.logits_fp32)
        cache = {k2: jnp.stack(v2) for k2, v2 in cache.items()}
        return logits[:, 0], cache

    def decode_step(self, params, cache, batch, *, dp_axes=None,
                    seq_axis=None, mesh=None):
        cfg = self.cfg
        pos = batch["pos"]
        x1 = embed_lookup(params["embed"], batch["token"][:, 0]
                          ).astype(cfg.act_dtype())
        s_max = cache["k"].shape[2]
        postab = sinusoidal_positions(s_max, cfg.d_model)
        x1 = x1 + jax.lax.dynamic_index_in_dim(
            postab, pos, 0, keepdims=False).astype(x1.dtype)
        new_cache = {k: [] for k in cache}
        from .transformer import decode_attention
        for i, p in enumerate(params["dec"]):
            lc = {k: cache[k][i] for k in cache}
            n1 = rms_norm(x1, p["norm1"])
            q, k, v = qkv_project(p["attn"], n1[:, None], cfg, None)
            o, ck, cv = decode_attention(q[:, 0], lc["k"], lc["v"], k[:, 0],
                                         v[:, 0], pos, dp_axes, seq_axis, mesh)
            b = x1.shape[0]
            x1 = x1 + o.reshape(b, -1) @ p["attn"].wo.astype(x1.dtype)
            nx = rms_norm(x1, p["norm_x"])
            qx = (nx @ p["xattn"].wq.astype(x1.dtype)).reshape(
                b, 1, cfg.n_heads, cfg.hd)
            xo = block_attention(qx, lc["xk"], lc["xv"], causal=False,
                                 chunk=cfg.attn_chunk)
            x1 = x1 + xo.reshape(b, -1) @ p["xattn"].wo.astype(x1.dtype)
            n2 = rms_norm(x1, p["norm2"])
            x1 = x1 + mlp_apply(p["mlp"], n2[:, None], cfg.mlp)[:, 0]
            for kk, vv in (("k", ck), ("v", cv), ("xk", lc["xk"]), ("xv", lc["xv"])):
                new_cache[kk].append(vv)
        x1 = rms_norm(x1, params["final_norm"])
        logits = tied_logits(params["embed"], x1, fp32=cfg.logits_fp32)
        return logits, {k: jnp.stack(v) for k, v in new_cache.items()}


def build_model(cfg: ModelConfig) -> LM:
    return EncDecLM(cfg) if cfg.enc_dec else LM(cfg)
