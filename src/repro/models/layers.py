"""Primitive layers: norms, projections, embeddings, RoPE / M-RoPE.

Functional style: ``*_init(key, ...) -> params pytree`` plus pure apply
functions.  Parameter names are the contract with ``distributed/sharding.py``
(which assigns PartitionSpecs by path), so keep them stable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with a hand-written VJP.

    The autodiff backward of the naive (upcast-to-f32) expression
    materialises ~6 f32 [B,S,D] intermediates per call at fusion boundaries
    (measured: ~9 TB/step for a 48L model — §Perf H1 it.4).  The custom VJP
    saves only (x: act-dtype, rstd: f32[...,1]) and emits dx in the
    activation dtype from a single fused expression."""
    return _rms_fwd(x, scale, eps)[0]


def _rms_fwd(x, scale, eps):
    x32 = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    y = ((x32 * r) * scale.astype(jnp.float32)).astype(x.dtype)
    return y, (x, scale, r)


def _rms_bwd(eps, res, g):
    x, scale, r = res
    x32 = x.astype(jnp.float32)
    gw = g.astype(jnp.float32) * scale.astype(jnp.float32)
    mean_gx = jnp.mean(gw * x32, axis=-1, keepdims=True)
    dx = (gw * r - x32 * (r * r * r) * mean_gx).astype(x.dtype)
    dscale = jnp.sum((g.astype(jnp.float32) * x32 * r).reshape(-1, x.shape[-1]),
                     axis=0).astype(scale.dtype)
    return dx, dscale


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * (d_model ** -0.5)).astype(dtype)


def embed_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids, axis=0)


def tied_logits(table: jnp.ndarray, x: jnp.ndarray, fp32: bool = True) -> jnp.ndarray:
    """Output head tied to the embedding (saves one vocab x d_model tensor)."""
    w = table.astype(jnp.float32) if fp32 else table
    xx = x.astype(w.dtype)
    return jnp.einsum("...d,vd->...v", xx, w)


# ------------------------------------------------------------------- RoPE ---

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                               # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs      # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...] = (2, 1, 1)) -> jnp.ndarray:
    """M-RoPE (Qwen2-VL): positions [3, B, S] = (temporal, height, width) ids;
    the head-dim rotary spectrum is split across the three id streams in
    proportion ``sections``."""
    hd = x.shape[-1]
    half = hd // 2
    tot = sum(sections)
    bounds, acc = [], 0
    for s in sections:
        acc += (half * s) // tot
        bounds.append(acc)
    bounds[-1] = half
    freqs = rope_freqs(hd, theta)                               # [half]
    # build per-frequency position ids by section
    ang_parts = []
    start = 0
    for sec_idx, end in enumerate(bounds):
        pos = positions[sec_idx]                                # [B, S]
        ang_parts.append(pos[..., None].astype(jnp.float32) * freqs[start:end])
        start = end
    ang = jnp.concatenate(ang_parts, axis=-1)                   # [B, S, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jnp.ndarray:
    """Fixed sinusoidal table (whisper encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    out = jnp.zeros((seq, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
