"""RWKV-6 (Finch) block: token-mix with data-dependent vector decay +
squared-ReLU channel-mix, both with token shift.

Decode state per layer: (prev token for the two shifts, the [H, dk, dv] wkv
state) — O(1) in sequence length, which is why rwkv6 runs the ``long_500k``
cell."""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .linear_attn import chunked_linear_attention, linear_attention_decode


class RwkvParams(NamedTuple):
    # token mix
    mu_r: jnp.ndarray; mu_k: jnp.ndarray; mu_v: jnp.ndarray
    mu_w: jnp.ndarray; mu_g: jnp.ndarray            # [D] lerp coefficients
    wr: jnp.ndarray; wk: jnp.ndarray; wv: jnp.ndarray
    wg: jnp.ndarray; wo: jnp.ndarray                # [D, D]
    w_decay: jnp.ndarray                            # [D, D] data-dependent decay
    decay_base: jnp.ndarray                         # [D]
    u_bonus: jnp.ndarray                            # [H, hd]
    ln_x: jnp.ndarray                               # [D] group-norm-ish scale
    # channel mix
    mu_ck: jnp.ndarray; mu_cr: jnp.ndarray          # [D]
    ck: jnp.ndarray                                 # [D, F]
    cv: jnp.ndarray                                 # [F, D]
    cr: jnp.ndarray                                 # [D, D]


def rwkv_init(key, cfg: ModelConfig) -> RwkvParams:
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    h = d // hd
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 10)
    mk = lambda i, di, do, s=None: dense_init(ks[i], di, do, dt, scale=s)
    half = jnp.full((d,), 0.5, dt)
    return RwkvParams(
        mu_r=half, mu_k=half, mu_v=half, mu_w=half, mu_g=half,
        wr=mk(0, d, d), wk=mk(1, d, d), wv=mk(2, d, d),
        wg=mk(3, d, d), wo=mk(4, d, d),
        w_decay=mk(5, d, d, 0.01), decay_base=jnp.full((d,), -2.0, jnp.float32),
        u_bonus=jnp.zeros((h, hd), jnp.float32),
        ln_x=jnp.ones((d,), jnp.float32),
        mu_ck=half, mu_cr=half,
        ck=mk(6, d, f), cv=mk(7, f, d, f ** -0.5), cr=mk(8, d, d),
    )


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token shift: x_{t-1} (zeros/prev-carry at t=0).  x [B,S,D]."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _decay_logw(p: RwkvParams, xw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent log-decay in (-inf, 0): -exp(base + proj(x))."""
    raw = p.decay_base + jnp.einsum(
        "bsd,de->bse", xw.astype(jnp.float32), p.w_decay.astype(jnp.float32))
    return -jnp.exp(jnp.clip(raw, -8.0, 4.0))


def rwkv_token_mix(p: RwkvParams, x: jnp.ndarray, cfg: ModelConfig,
                   state: Optional[Tuple] = None):
    """x [B,S,D] -> (out [B,S,D], new_state).  state = (prev_x, S)."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev_x, S0 = (None, None) if state is None else state
    xs = _shift(x, prev_x)
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, p.mu_r), p.wr.astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, p.mu_k), p.wk.astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, p.mu_v), p.wv.astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", _mix(x, xs, p.mu_g), p.wg.astype(x.dtype))
    logw = _decay_logw(p, _mix(x, xs, p.mu_w))
    rh = r.reshape(b, s, h, hd)
    kh = k.reshape(b, s, h, hd)
    vh = v.reshape(b, s, h, hd)
    wh = logw.reshape(b, s, h, hd)
    o, S1 = chunked_linear_attention(rh, kh, vh, wh, u=p.u_bonus,
                                     chunk=64, state0=S0)
    o = o.reshape(b, s, d)
    # simple rms "ln_x" normalisation per head-dim then gate
    o32 = o.astype(jnp.float32)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, -1, keepdims=True) + 1e-6)
    o = (o32 * p.ln_x).astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", o, p.wo.astype(x.dtype))
    return out, (x[:, -1], S1)


def rwkv_channel_mix(p: RwkvParams, x: jnp.ndarray,
                     prev_x: Optional[jnp.ndarray] = None):
    xs = _shift(x, prev_x)
    k = jnp.einsum("bsd,df->bsf", _mix(x, xs, p.mu_ck), p.ck.astype(x.dtype))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p.cv.astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", _mix(x, xs, p.mu_cr), p.cr.astype(x.dtype)).astype(jnp.float32))
    return (rgate.astype(x.dtype) * kv), x[:, -1]


def rwkv_token_mix_decode(p: RwkvParams, x1: jnp.ndarray, cfg: ModelConfig,
                          state: Tuple):
    """Single-token token-mix.  x1 [B, D]; state = (prev_x [B,D], S)."""
    b, d = x1.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    prev_x, S0 = state
    xs = prev_x
    mixn = lambda mu: x1 + (xs - x1) * mu.astype(x1.dtype)
    r = mixn(p.mu_r) @ p.wr.astype(x1.dtype)
    k = mixn(p.mu_k) @ p.wk.astype(x1.dtype)
    v = mixn(p.mu_v) @ p.wv.astype(x1.dtype)
    g = mixn(p.mu_g) @ p.wg.astype(x1.dtype)
    raw = p.decay_base + mixn(p.mu_w).astype(jnp.float32) @ p.w_decay.astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(raw, -8.0, 4.0))
    o, S1 = linear_attention_decode(
        r.reshape(b, h, hd), k.reshape(b, h, hd), v.reshape(b, h, hd),
        logw.reshape(b, h, hd), S0, u=p.u_bonus)
    o = o.reshape(b, d)
    o32 = o.astype(jnp.float32)
    o32 = o32 * jax.lax.rsqrt(jnp.mean(o32 * o32, -1, keepdims=True) + 1e-6)
    o = (o32 * p.ln_x).astype(x1.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x1.dtype)
    return o @ p.wo.astype(x1.dtype), (x1, S1)


def rwkv_channel_mix_decode(p: RwkvParams, x1: jnp.ndarray, prev_x: jnp.ndarray):
    mixn = lambda mu: x1 + (prev_x - x1) * mu.astype(x1.dtype)
    k = mixn(p.mu_ck) @ p.ck.astype(x1.dtype)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x1.dtype)
    kv = k @ p.cv.astype(x1.dtype)
    rg = jax.nn.sigmoid((mixn(p.mu_cr) @ p.cr.astype(x1.dtype)).astype(jnp.float32))
    return rg.astype(x1.dtype) * kv, x1
