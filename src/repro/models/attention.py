"""GQA attention: chunked-causal train/prefill and partial-softmax decode.

* Train/prefill runs a ``lax.scan`` over query blocks (bounded [B, C, H, S]
  logits workspace — 32k prefill never materialises the full S x S matrix).
  On real TPUs the Pallas flash kernel (kernels/attention_kernel.py) replaces
  the inner block computation; the scanned-jnp path is what the dry-run
  lowers (Mosaic cannot target the CPU backend) and is numerically identical.
* Decode computes *partial* softmax statistics (max, sum-exp, unnormalised
  output) so the sequence axis of the KV cache can be sharded over the
  ``model`` mesh axis and combined with one psum (flash-decoding style) —
  see ``distributed/decode.py``.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, dense_init


class AttnParams(NamedTuple):
    wq: jnp.ndarray           # [D, Hq*hd]
    wk: jnp.ndarray           # [D, Hkv*hd]
    wv: jnp.ndarray           # [D, Hkv*hd]
    wo: jnp.ndarray           # [Hq*hd, D]
    bq: Optional[jnp.ndarray] = None
    bk: Optional[jnp.ndarray] = None
    bv: Optional[jnp.ndarray] = None


def attn_init(key, cfg: ModelConfig) -> AttnParams:
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = cfg.p_dtype()
    bias = (jnp.zeros((hq * hd,), dt), jnp.zeros((hk * hd,), dt),
            jnp.zeros((hk * hd,), dt)) if cfg.qkv_bias else (None, None, None)
    return AttnParams(
        wq=dense_init(ks[0], d, hq * hd, dt),
        wk=dense_init(ks[1], d, hk * hd, dt),
        wv=dense_init(ks[2], d, hk * hd, dt),
        wo=dense_init(ks[3], hq * hd, d, dt, scale=(hq * hd) ** -0.5),
        bq=bias[0], bk=bias[1], bv=bias[2],
    )


def qkv_project(p: AttnParams, x: jnp.ndarray, cfg: ModelConfig,
                positions: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, ...]:
    b, s, _ = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p.wq.astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p.wk.astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p.wv.astype(x.dtype))
    if p.bq is not None:
        q, k, v = q + p.bq.astype(x.dtype), k + p.bk.astype(x.dtype), v + p.bv.astype(x.dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hk, hd)
    v = v.reshape(b, s, hk, hd)
    if cfg.rope == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope" and positions is not None:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _pick_chunk(s: int, want: int) -> int:
    """Largest divisor of ``s`` that is <= want (prefer the configured block)."""
    want = min(want, s)
    if s % want == 0:
        return want
    for c in range(want, 0, -1):
        if s % c == 0:
            return c
    return s


def block_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool, chunk: int,
                    kv_valid: Optional[jnp.ndarray] = None,
                    q_offset=0) -> jnp.ndarray:
    """q [B,Sq,Hq,hd] x k,v [B,Skv,Hkv,hd] -> [B,Sq,Hq,hd].

    Scans over query blocks; logits workspace is [B, C, Hq, Skv] f32.
    ``kv_valid`` [B, Skv] masks padded keys (encoder / ragged cross-attn).
    ``q_offset`` is the global position of q row 0 (sequence-parallel
    shards pass their shard offset so the causal mask stays global)."""
    b, sq, hq, hd = q.shape
    _, skv, hk, _ = k.shape
    g = hq // hk
    c = _pick_chunk(sq, chunk)
    nblk = sq // c
    scale = hd ** -0.5

    qb = q.reshape(b, nblk, c, hk, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kv_pos = jnp.arange(skv)

    def one_block(carry, inp):
        qi, blk_idx = inp
        # f32 accumulation WITHOUT materialising f32 copies of q/k (the MXU
        # accumulates in f32 natively; preferred_element_type expresses it)
        logits = jnp.einsum("bchgd,bshd->bchgs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        mask = None
        if causal:
            q_pos = q_offset + blk_idx * c + jnp.arange(c)
            mask = q_pos[:, None] >= kv_pos[None, :]            # [c, skv]
            mask = mask[None, :, None, None, :]
        if kv_valid is not None:
            kvm = kv_valid[:, None, None, None, :]
            mask = kvm if mask is None else (mask & kvm)
        if mask is not None:
            logits = jnp.where(mask, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        # probs in activation dtype @ v, f32 accumulation (flash-kernel
        # dtype policy; avoids an f32 copy of v per block)
        out = jnp.einsum("bchgs,bshd->bchgd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(q.dtype)

    # flash-attention residency: recompute logits/probs in the backward pass
    # instead of stacking [nblk, B, C, H, Skv] f32 score residuals (that
    # stack IS the full S x S matrix — §Perf H1 it.2 / H2)
    one_block = jax.checkpoint(one_block)

    _, outs = jax.lax.scan(one_block, None, (qb, jnp.arange(nblk)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, hd)
    return out


def sharded_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool, chunk: int,
                      kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Attention with automatic sequence parallelism over ``model``.

    When the query-head count divides the TP axis, GSPMD head sharding is
    already optimal and this is a plain :func:`block_attention`.  Otherwise
    (hymba's 25 heads, whisper's 8 on a 16-way axis) GSPMD replicates the
    whole attention on every chip; here we shard the *query sequence* axis
    over ``model`` instead — each shard computes all heads for Sq/tp query
    rows against the full KV (which TP already replicates at this point),
    with the causal mask offset to global positions.  Compute and score
    traffic drop by the TP degree; no extra collectives are introduced
    (outputs come back sequence-sharded and the next op's constraint
    re-lays them out).  §Perf H2."""
    am = jax.sharding.get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return block_attention(q, k, v, causal, chunk, kv_valid)
    tp = am.shape["model"]
    b, sq, hq, _ = q.shape
    if tp == 1 or hq % tp == 0 or sq % tp != 0 or q.shape[0] == 0:
        return block_attention(q, k, v, causal, chunk, kv_valid)

    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    names = set(am.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    n_fsdp = int(np.prod([am.shape[a] for a in fsdp])) if fsdp else 1
    bspec = fsdp if (fsdp and b % n_fsdp == 0) else None
    s_local = sq // tp

    qspec = P(bspec, "model", None, None)
    kvspec = P(bspec, None, None, None)
    vspec = None if kv_valid is None else P(bspec, None)

    if kv_valid is None:
        def body(q_l, k_l, v_l):
            off = jax.lax.axis_index("model") * s_local
            return block_attention(q_l, k_l, v_l, causal,
                                   min(chunk, s_local), None, q_offset=off)
        fn = shard_map(body, mesh=am, in_specs=(qspec, kvspec, kvspec),
                       out_specs=qspec, check_vma=False)
        return fn(q, k, v)

    def body_v(q_l, k_l, v_l, kvv_l):
        off = jax.lax.axis_index("model") * s_local
        return block_attention(q_l, k_l, v_l, causal,
                               min(chunk, s_local), kvv_l, q_offset=off)
    fn = shard_map(body_v, mesh=am, in_specs=(qspec, kvspec, kvspec, vspec),
                   out_specs=qspec, check_vma=False)
    return fn(q, k, v, kv_valid)


class DecodePartial(NamedTuple):
    """Unnormalised partial attention over a KV shard (flash-decoding)."""
    o: jnp.ndarray            # [B, Hq, hd]  sum softmax-unnorm * V
    m: jnp.ndarray            # [B, Hq]      running max logit
    l: jnp.ndarray            # [B, Hq]      sum exp(logit - m)


def decode_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   kv_valid: jnp.ndarray) -> DecodePartial:
    """q [B,Hq,hd]; k,v [B,S_shard,Hkv,hd]; kv_valid [B,S_shard] bool."""
    b, hq, hd = q.shape
    hk = k.shape[2]
    g = hq // hk
    scale = hd ** -0.5
    qf = q.reshape(b, hk, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32)) * scale
    logits = jnp.where(kv_valid[:, None, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    # guard fully-masked shards (m = -1e30): zero their weight
    dead = m <= -1e29
    p = jnp.where(dead[..., None], 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return DecodePartial(o=o.reshape(b, hq, hd),
                         m=jnp.where(dead, -jnp.inf, m).reshape(b, hq),
                         l=l.reshape(b, hq))


def combine_partials(parts: DecodePartial, axis_name: Optional[str] = None
                     ) -> jnp.ndarray:
    """Combine partial softmax stats; with ``axis_name`` the reduction runs as
    psum/pmax across mesh shards, otherwise the partials are already total."""
    o, m, l = parts
    if axis_name is None:
        safe_m = jnp.where(jnp.isinf(m), 0.0, m)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(o.dtype)
    gm = jax.lax.pmax(m, axis_name)
    gm_safe = jnp.where(jnp.isinf(gm), 0.0, gm)
    m_safe = jnp.where(jnp.isinf(m), gm_safe - 80.0, m)
    corr = jnp.exp(m_safe - gm_safe)
    o_sum = jax.lax.psum(o * corr[..., None], axis_name)
    l_sum = jax.lax.psum(l * corr, axis_name)
    return o_sum / jnp.maximum(l_sum, 1e-30)[..., None]
