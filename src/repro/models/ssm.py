"""Mamba-2/SSD-style selective-state-space heads for Hymba.

Hardware-adaptation note (DESIGN.md): Hymba's Mamba heads use a per-channel
Mamba-1 scan; we use the SSD formulation (scalar decay per head, state
``N = ssm_state``) so the sequence mix shares the chunked linear-attention
core with RWKV — identical TPU dataflow, O(1)-state decode.  The depthwise
conv of Mamba is folded into the stub frontend (noted as a simplification).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .linear_attn import chunked_linear_attention, linear_attention_decode


class SsmParams(NamedTuple):
    w_in: jnp.ndarray        # [D, dI]   value path
    w_gate: jnp.ndarray      # [D, dI]   silu gate
    w_bc: jnp.ndarray        # [D, 2N*H] B and C projections (per head)
    w_dt: jnp.ndarray        # [D, H]    per-head time step
    a_log: jnp.ndarray       # [H]       decay magnitude
    d_skip: jnp.ndarray      # [dI]
    w_out: jnp.ndarray       # [dI, D]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    h = cfg.ssm_heads
    hd = cfg.hd
    return h, hd, h * hd     # heads, head value dim, inner dim


def ssm_init(key, cfg: ModelConfig) -> SsmParams:
    d = cfg.d_model
    n = cfg.ssm_state
    h, hd, di = _dims(cfg)
    dt = cfg.p_dtype()
    ks = jax.random.split(key, 5)
    return SsmParams(
        w_in=dense_init(ks[0], d, di, dt),
        w_gate=dense_init(ks[1], d, di, dt),
        w_bc=dense_init(ks[2], d, 2 * n * h, dt),
        w_dt=dense_init(ks[3], d, h, jnp.float32),
        a_log=jnp.zeros((h,), jnp.float32),
        d_skip=jnp.ones((di,), jnp.float32),
        w_out=dense_init(ks[4], di, d, dt, scale=di ** -0.5),
    )


def _project(p: SsmParams, x: jnp.ndarray, cfg: ModelConfig):
    b = x.shape[0]
    lead = x.shape[:-1]
    n = cfg.ssm_state
    h, hd, di = _dims(cfg)
    xv = jnp.einsum("...d,de->...e", x, p.w_in.astype(x.dtype))
    gate = jnp.einsum("...d,de->...e", x, p.w_gate.astype(x.dtype))
    bc = jnp.einsum("...d,de->...e", x, p.w_bc.astype(x.dtype)).astype(jnp.float32)
    bmat, cmat = jnp.split(bc.reshape(lead + (h, 2 * n)), 2, axis=-1)
    dt_raw = jnp.einsum("...d,dh->...h", x.astype(jnp.float32), p.w_dt)
    dt = jax.nn.softplus(dt_raw)                              # [.., H]
    loga = -jnp.exp(p.a_log)                                  # [H] < 0
    logw = dt * loga[(None,) * len(lead)]                     # [.., H]
    v = xv.reshape(lead + (h, hd)).astype(jnp.float32) * dt[..., None]
    return v, bmat, cmat, logw, xv, gate


def ssm_apply(p: SsmParams, x: jnp.ndarray, cfg: ModelConfig,
              state: Optional[jnp.ndarray] = None):
    """x [B,S,D] -> (y [B,S,D], state [B,H,N,hd])."""
    b, s, d = x.shape
    n = cfg.ssm_state
    h, hd, di = _dims(cfg)
    v, bmat, cmat, logw, xv, gate = _project(p, x, cfg)
    logw_k = jnp.broadcast_to(logw[..., None], (b, s, h, n))
    o, S1 = chunked_linear_attention(cmat, bmat, v, logw_k, u=None,
                                     chunk=64, state0=state)
    y = o.reshape(b, s, di) + xv.astype(jnp.float32).reshape(b, s, di) * p.d_skip
    y = y.astype(x.dtype) * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p.w_out.astype(x.dtype)), S1


def ssm_decode(p: SsmParams, x1: jnp.ndarray, cfg: ModelConfig,
               state: jnp.ndarray):
    """x1 [B,D]; state [B,H,N,hd]."""
    b, d = x1.shape
    h, hd, di = _dims(cfg)
    v, bmat, cmat, logw, xv, gate = _project(p, x1, cfg)
    n = cfg.ssm_state
    logw_k = jnp.broadcast_to(logw[..., None], (b, h, n))
    o, S1 = linear_attention_decode(cmat, bmat, v, logw_k, state, u=None)
    y = o.reshape(b, di) + xv.astype(jnp.float32).reshape(b, di) * p.d_skip
    y = y.astype(x1.dtype) * jax.nn.silu(gate.astype(jnp.float32)).astype(x1.dtype)
    return y @ p.w_out.astype(x1.dtype), S1
