"""Feed-forward variants: SwiGLU (llama-family), squared-ReLU (nemotron,
rwkv channel-mix), GELU (whisper)."""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


class MlpParams(NamedTuple):
    wi: jnp.ndarray                 # [D, F]
    wo: jnp.ndarray                 # [F, D]
    wg: Optional[jnp.ndarray] = None  # [D, F] (swiglu gate)


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> MlpParams:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.p_dtype()
    k1, k2, k3 = jax.random.split(key, 3)
    wg = dense_init(k3, d, f, dt) if cfg.mlp == "swiglu" else None
    return MlpParams(wi=dense_init(k1, d, f, dt),
                     wo=dense_init(k2, f, d, dt, scale=f ** -0.5), wg=wg)


def mlp_apply(p: MlpParams, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p.wi.astype(x.dtype))
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p.wg.astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif kind == "sq_relu":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(kind)
    return jnp.einsum("bsf,fd->bsd", h, p.wo.astype(x.dtype))
