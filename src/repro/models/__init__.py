from .config import ModelConfig, ShapeConfig, SHAPES, shape_cells, SUBQUADRATIC
from .model import LM, EncDecLM, build_model

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_cells",
           "SUBQUADRATIC", "LM", "EncDecLM", "build_model"]
