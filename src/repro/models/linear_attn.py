"""Chunked linear attention with data-dependent decay.

Shared sequence-mixing core for RWKV-6 (vector decay per key channel, Finch)
and the Mamba-2/SSD-style heads in Hymba (scalar decay per head, broadcast to
the key channels).  Recurrence per head:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)      (u = 0 for SSD heads)

Chunk algorithm (numerically safe — every exponent is <= 0 because the
cumulative log-decay P is non-increasing):

    inter:  o_t += (r_t  exp(P_{t-1})) . S_0
    intra:  A[t,i] = sum_d r_t[d] k_i[d] exp(P_{t-1,d} - P_{i,d}),  i < t
    state:  S' = diag(exp(P_last)) S_0 + sum_i (k_i exp(P_last - P_i)) v_i^T

The O(c^2 d_k) pairwise tensor lives only inside one scan step — memory is
bounded by the chunk size, never by the sequence (this is what makes the
``long_500k`` cells runnable).  Decode is the O(1) recurrence update.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _chunk_mesh(n: int, b: int):
    """(mesh, batch_axes) when the chunk axis can shard over ``model``.

    The two heavy passes below are *batched over chunks* (no cross-chunk
    dependency), so the chunk axis shards over the TP axis — this is what
    makes the recurrent mixers scale on the mesh even when their head count
    (hymba: 25) does not divide it (§Perf H2 it.3).  shard_map (not a mere
    constraint) is required: GSPMD otherwise re-gathers around the
    surrounding transposes and keeps the compute replicated (measured —
    §Perf H2 it.3a, refuted)."""
    am = jax.sharding.get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return None, None
    if am.shape["model"] == 1 or n % am.shape["model"] != 0:
        return None, None
    names = set(am.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    import numpy as _np
    n_fsdp = int(_np.prod([am.shape[a] for a in fsdp])) if fsdp else 1
    bspec = fsdp if (fsdp and b % n_fsdp == 0) else None
    return am, bspec


def chunked_linear_attention(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             logw: jnp.ndarray,
                             u: Optional[jnp.ndarray] = None,
                             chunk: int = 64,
                             state0: Optional[jnp.ndarray] = None
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,logw: [B,S,H,dk]; v: [B,S,H,dv]; u: [H,dk] or None.

    Returns (o [B,S,H,dv], final_state [B,H,dk,dv]).

    Two-pass parallel-scan formulation (Mamba-2 / GLA style):
      pass 1 (chunk-parallel): local state contribution + total decay per chunk;
      combine (sequential, tiny): [n] x [b,h,dk,dv] state recurrence;
      pass 2 (chunk-parallel): inter- + intra-chunk outputs.
    Both heavy passes are batched einsums over the chunk axis, which is
    sharded over the ``model`` mesh axis — compute parallelises even for
    head counts that do not divide it.  All exponents remain <= 0."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    while s % c:
        c -= 1
    n = s // c

    rr = r.astype(jnp.float32).reshape(b, n, c, h, dk).transpose(1, 0, 3, 2, 4)
    kk = k.astype(jnp.float32).reshape(b, n, c, h, dk).transpose(1, 0, 3, 2, 4)
    vv = v.astype(jnp.float32).reshape(b, n, c, h, dv).transpose(1, 0, 3, 2, 4)
    lw = logw.astype(jnp.float32).reshape(b, n, c, h, dk).transpose(1, 0, 3, 2, 4)
    # shapes now [n, b, h, c, d*]

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    uu = None if u is None else u.astype(jnp.float32)

    # ---- pass 1: per-chunk local state contribution (no carry) ------------
    def local_state(ri, ki, vi, lwi):
        P = jnp.cumsum(lwi, axis=2)
        Plast = P[:, :, -1:, :]
        k_dec = ki * jnp.exp(Plast - P)                   # <= 0 exponents
        S_loc = jnp.einsum("bhtd,bhtv->bhdv", k_dec, vi)
        return S_loc, jnp.exp(Plast.squeeze(2))           # [b,h,dk,dv], [b,h,dk]

    # ---- pass 2: per-chunk outputs (inter from S0, intra pairwise) --------
    mask_ti = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_out(ri, ki, vi, lwi, S0):
        P = jnp.cumsum(lwi, axis=2)
        Pprev = P - lwi
        r_dec = ri * jnp.exp(Pprev)
        o_inter = jnp.einsum("bhtd,bhdv->bhtv", r_dec, S0)
        diff = Pprev[:, :, :, None, :] - P[:, :, None, :, :]   # [b,h,t,i,dk]
        M = jnp.where(mask_ti[None, None, :, :, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("bhtd,bhid,bhtid->bhti", ri, ki, M)
        o_intra = jnp.einsum("bhti,bhiv->bhtv", A, vi)
        if uu is not None:  # current-token bonus
            cur = jnp.einsum("bhtd,hd,bhtd->bht", ri, uu, ki)
            o_intra = o_intra + cur[..., None] * vi
        return o_inter + o_intra

    # recompute the O(c^2) pairwise tensors in the backward pass instead of
    # saving them (the [n,b,h,c,c,dk] f32 stack dominated HBM — §Perf H2)
    chunk_out = jax.checkpoint(chunk_out)

    # ---- combine: tiny sequential recurrence over n chunk states ----------
    def comb(S, inp):
        S_l, dec = inp
        S_new = S * dec[..., None] + S_l
        return S_new, S                                   # emit state *before* chunk

    am, bspec = _chunk_mesh(n, b)
    if am is None:
        S_loc, decay = jax.vmap(local_state)(rr, kk, vv, lw)
        S_final, S0s = jax.lax.scan(comb, state0, (S_loc, decay))
        outs = jax.vmap(chunk_out)(rr, kk, vv, lw, S0s)
    else:
        from ..compat import shard_map
        from jax.sharding import PartitionSpec as P
        cspec = P("model", bspec, None, None, None)       # [n, b, h, c, d]
        sspec = P("model", bspec, None, None, None)       # [n, b, h, dk, dv]
        dspec = P("model", bspec, None, None)             # [n, b, h, dk]
        p1 = shard_map(lambda a, b_, c_, d_: jax.vmap(local_state)(a, b_, c_, d_),
                       mesh=am, in_specs=(cspec,) * 4,
                       out_specs=(sspec, dspec), check_vma=False)
        S_loc, decay = p1(rr, kk, vv, lw)
        # tiny sequential combine over n states: replicated (105 MB-scale)
        S_final, S0s = jax.lax.scan(comb, state0, (S_loc, decay))
        p2 = shard_map(lambda a, b_, c_, d_, e_: jax.vmap(chunk_out)(a, b_, c_, d_, e_),
                       mesh=am, in_specs=(cspec,) * 4 + (sspec,),
                       out_specs=P("model", bspec, None, None, None),
                       check_vma=False)
        outs = p2(rr, kk, vv, lw, S0s)

    o = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dv)
    return o.astype(r.dtype), S_final


def linear_attention_decode(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            logw: jnp.ndarray, state: jnp.ndarray,
                            u: Optional[jnp.ndarray] = None
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token update.  r,k,logw [B,H,dk]; v [B,H,dv]; state [B,H,dk,dv]."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]               # [B,H,dk,dv]
    if u is not None:
        eff = state + u.astype(jnp.float32)[None, :, :, None] * kv
    else:
        eff = state
    o = jnp.einsum("bhd,bhdv->bhv", rf, eff)
    new_state = state * w[..., None] + kv
    return o.astype(r.dtype), new_state
