"""RWKV-6 (Finch) 1.6B: attention-free, data-dependent decay linear
attention [arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536,
    block="rwkv", rwkv_head_dim=64, mlp="sq_relu", rope="none",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=256, rwkv_head_dim=16)
