"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Each module defines ``CONFIG`` (the exact published figures from the brief)
and ``reduced()`` (a small same-family config for CPU smoke tests)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig, shape_cells

_MODULES = {
    "granite-8b": "granite_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "arctic-480b": "arctic_480b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-base": "whisper_base",
}

ARCHS = tuple(_MODULES)

# runtime-registered configs (examples / experiments)
_RUNTIME: Dict[str, ModelConfig] = {}


def register_config(name: str, cfg: ModelConfig,
                    reduced: ModelConfig | None = None) -> None:
    """Register an ad-hoc architecture so launchers accept ``--arch name``."""
    _RUNTIME[name] = cfg
    if reduced is not None:
        _RUNTIME[name + "/reduced"] = reduced


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    if arch in _RUNTIME:
        return _RUNTIME[arch]
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    if arch + "/reduced" in _RUNTIME:
        return _RUNTIME[arch + "/reduced"]
    if arch in _RUNTIME:
        return _RUNTIME[arch]
    return _mod(arch).reduced()


def all_cells() -> List[tuple]:
    """Every (arch, shape) dry-run cell, with skip rules applied."""
    return [(a, s) for a in ARCHS for s in shape_cells(a)]
