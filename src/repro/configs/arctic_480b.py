"""Snowflake Arctic 480B: MoE 128 experts top-2 with a dense residual MLP in
parallel [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
    block="moe", mlp="swiglu", rope="rope",
    n_experts=128, top_k=2, dense_residual=True,
    opt_state_dtype="bfloat16", microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=48, vocab=384, n_experts=8,
                          top_k=2, microbatch=1)
