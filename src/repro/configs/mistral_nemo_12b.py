"""Mistral-Nemo-12B: dense GQA, 128k context, head_dim 128 != d_model/heads
[hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072,
    block="attn", head_dim=128, mlp="swiglu", rope="rope",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=80, n_heads=4, n_kv_heads=2,
                          head_dim=24, d_ff=160, vocab=384)
