"""Hymba-1.5B: hybrid heads — attention and Mamba-style SSM heads run in
parallel within each block [arXiv:2411.13676]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
    block="hymba", head_dim=64, mlp="swiglu", rope="rope",
    ssm_state=16, ssm_heads=25,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab=256, ssm_heads=4)
