"""Qwen2-VL-72B backbone: dense GQA decoder with M-RoPE; the vision frontend
is a stub — input_specs() supplies patch embeddings [arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
    block="attn", mlp="swiglu", rope="mrope", embeds_input=True,
    opt_state_dtype="bfloat16", microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab=384, microbatch=1)
