"""Nemotron-4-340B: dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv_heads=8, d_ff=73728, vocab=256000,
    block="attn", mlp="sq_relu", rope="rope",
    # 340B params: bf16 Adam moments keep optimizer state within v5e HBM
    opt_state_dtype="bfloat16", microbatch=16,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                          head_dim=16, d_ff=384, vocab=512, microbatch=1)
