"""Whisper-base: encoder-decoder; the conv/audio frontend is a stub —
input_specs() supplies 1500 precomputed frame embeddings [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    block="attn", mlp="gelu", rope="none",
    enc_dec=True, enc_layers=6, enc_frames=1500, embeds_input=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, head_dim=16, d_ff=128, vocab=384,
                          enc_frames=64)
