"""Qwen2.5-3B: dense GQA (kv=2) with QKV bias [hf:Qwen/Qwen2.5]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936,
    block="attn", mlp="swiglu", qkv_bias=True, rope="rope",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab=384)
