"""Core library: the paper's contribution — pre/post/hybrid counts caching
for scalable statistical-relational model discovery — as composable JAX
modules, layered as planner (:mod:`.plan`) / executors (:mod:`.executors`)
/ cache (:mod:`.cache`) under thin strategy policies (:mod:`.strategies`).
"""

from .schema import Attribute, EntityType, Relationship, Schema
from .database import (RelationalDB, ShardedDatabase, NotRoutableError,
                       FactDelta, shard_database, synth_db,
                       paper_benchmark_db, PAPER_DATASETS)
from .variables import (Var, Atom, CtVar, LatticePoint, attr_var, edge_var,
                        rind_var, build_lattice, point_from_rels)
from .ct import CtTable
from .contract import CostStats, positive_ct, entity_hist
from .plan import ContractionPlan, compile_plan, group_by_signature
from .executors import (DenseExecutor, Executor, SparseExecutor, EXECUTORS,
                        make_executor, plan_input_arrays, plan_stack_key)
from .distributed import (ShardedSparseExecutor, sharded_positive_ct,
                          sharded_sparse_positive_ct)  # registers the
                          # "sparse_sharded" backend in EXECUTORS on import
from .cache import CtCache
from .engine import (CountingEngine, CachedFullPositives, DeltaReport,
                     OnDemandPositives, TupleIdPositives, key_deps)
from .mobius import (butterfly_batch, complete_ct, complete_ct_many,
                     positive_queries, superset_mobius)
from .strategies import (Strategy, Precount, OnDemand, Hybrid, TupleId,
                         make_strategy, STRATEGIES)
from .bdeu import bdeu_score_2d, bdeu_score_batch, family_score
from .search import StructureSearch, discover_model, BNModel

__all__ = [
    "Attribute", "EntityType", "Relationship", "Schema",
    "RelationalDB", "ShardedDatabase", "NotRoutableError", "FactDelta",
    "shard_database", "synth_db", "paper_benchmark_db", "PAPER_DATASETS",
    "Var", "Atom", "CtVar", "LatticePoint", "attr_var", "edge_var", "rind_var",
    "build_lattice", "point_from_rels", "CtTable",
    "CostStats", "positive_ct", "entity_hist",
    "ContractionPlan", "compile_plan", "group_by_signature",
    "Executor", "DenseExecutor", "SparseExecutor", "ShardedSparseExecutor",
    "EXECUTORS", "make_executor", "plan_input_arrays", "plan_stack_key",
    "sharded_positive_ct", "sharded_sparse_positive_ct",
    "CtCache", "CountingEngine", "DeltaReport", "key_deps",
    "CachedFullPositives", "OnDemandPositives", "TupleIdPositives",
    "butterfly_batch", "complete_ct", "complete_ct_many",
    "positive_queries", "superset_mobius",
    "Strategy", "Precount", "OnDemand", "Hybrid", "TupleId",
    "make_strategy", "STRATEGIES",
    "bdeu_score_2d", "bdeu_score_batch", "family_score",
    "StructureSearch", "discover_model", "BNModel",
]
