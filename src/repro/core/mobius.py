"""The Möbius Join: extend positive ct-tables to complete ct-tables.

Inclusion–exclusion over relationship indicators (Qian, Schulte & Sun 2014):
for a final configuration with relation set ``A`` true and ``B`` false,

    N[A=T, B=F, attrs] = sum_{S subseteq B} (-1)^|S| ct_+[A u S true, attrs]

No access to the original data is needed: every term is a positive ct-table of
a *sub-pattern*, served by a :class:`PositiveProvider` — one of the policies
in :mod:`repro.core.engine` (cached-full for PRECOUNT/HYBRID, on-demand for
ONDEMAND, message recombination for TUPLEID), all backed by the shared
planner/executor/cache machinery — with disconnected sub-patterns
factorising into outer products of component tables and per-variable
histograms.

Two equivalent evaluation orders are implemented:

* ``blockwise`` — explicit 3^k-term sum, handles kept edge attributes (whose
  axes only exist while their relation is true; when false they collapse to
  the N/A slot).
* ``butterfly`` — the superset Möbius transform as k in-place passes
  ``F-slice = *-slice − T-slice`` over a [2^k, D] stack; this is the
  memory-bound transform the Pallas kernel (kernels/mobius_kernel.py)
  implements.  Used when no edge-attr axes are kept.  The ``mobius_fn``
  hook is normally the executor's negative-phase step
  (:meth:`repro.core.executors.Executor.mobius`), which dispatches to the
  Pallas kernel when the executor was built with ``use_pallas_mobius``.

The transform output is integral and non-negative (counts); property tests
assert both.
"""

from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .contract import CostStats
from .ct import CtTable, scalar_table
from .variables import (Atom, CtVar, LatticePoint, Var, connected_components,
                        rind_var)


class PositiveProvider(Protocol):
    """Source of positive ct-tables and variable histograms."""

    def positive(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> CtTable: ...

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable: ...


# --------------------------------------------------------------------------
# superset Möbius transform (pure-jnp reference; Pallas kernel mirrors this)
# --------------------------------------------------------------------------

def superset_mobius(stack: jnp.ndarray, k: int) -> jnp.ndarray:
    """In the leading ``k`` axes (each of size 2, index 1 = "relation true",
    index 0 = "unconstrained"), replace index 0 with "relation false" by
    applying ``x0 <- x0 - x1`` per axis.  Equivalent to
    ``N[A] = sum_{S >= A} (-1)^{|S|-|A|} Y[S]``."""
    x = stack
    for i in range(k):
        x0 = jnp.take(x, 0, axis=i) - jnp.take(x, 1, axis=i)
        x1 = jnp.take(x, 1, axis=i)
        x = jnp.stack([x0, x1], axis=i)
    return x


# --------------------------------------------------------------------------
# pattern tables: positive count of a relation subset over the point's vars
# --------------------------------------------------------------------------

def _pattern_table(point: LatticePoint, rels: Set[str],
                   keep_axes: Tuple[CtVar, ...],
                   provider: PositiveProvider) -> CtTable:
    """ct_+ of the sub-pattern with ``rels`` true, over all vars of ``point``,
    projected onto ``keep_axes`` (entity attrs + edge attrs of rels)."""
    atoms = tuple(a for a in point.atoms if a.rel in rels)
    out: Optional[CtTable] = None
    covered: Set[Var] = set()
    for comp in connected_components(atoms):
        cp = LatticePoint(comp)
        comp_rels = {a.rel for a in comp}
        ckeep = tuple(v for v in keep_axes
                      if (v.kind == "attr" and v.owner[0] in cp.vars)
                      or (v.kind == "edge" and v.owner[0] in comp_rels))
        t = provider.positive(cp, ckeep)
        out = t if out is None else out.outer(t)
        covered.update(cp.vars)
    for var in point.vars:
        if var in covered:
            continue
        vkeep = tuple(v for v in keep_axes
                      if v.kind == "attr" and v.owner[0] == var)
        h = provider.hist(var, vkeep)
        out = h if out is None else out.outer(h)
    assert out is not None
    return out.transpose_to(tuple(v for v in keep_axes if v in out.vars)) \
        if set(out.vars) == set(keep_axes) else out.project(keep_axes)


def positive_queries(point: LatticePoint, keep: Sequence[CtVar],
                     use_butterfly: bool = True
                     ) -> List[Tuple[LatticePoint, Tuple[CtVar, ...]]]:
    """The positive sub-queries :func:`complete_ct` will request from its
    provider for ``(point, keep)``, in request order.

    This mirrors the Möbius join's own enumeration (butterfly vs blockwise
    branch, relation dropping, connected-component factorisation) without
    touching any data — it is what lets a serving layer batch a whole
    round of family queries into signature buckets *before* any Möbius
    join runs (see :meth:`repro.serve.service.CountingService.prefetch`).
    Per-variable histogram queries are omitted: they are cheap, shared,
    and cached on first use.  Duplicates across terms are preserved
    (callers dedupe); every entry is a connected sub-pattern.
    """
    keep = tuple(keep)
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges: Dict[str, List[CtVar]] = {}
    for v in keep:
        if v.kind == "edge":
            kept_edges.setdefault(v.owner[0], []).append(v)
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}
    effective = sorted(set(kept_edges) | kept_rinds)
    k = len(effective)

    out: List[Tuple[LatticePoint, Tuple[CtVar, ...]]] = []

    def pattern(rels: Set[str], keep_axes: Tuple[CtVar, ...]) -> None:
        atoms = tuple(a for a in point.atoms if a.rel in rels)
        for comp in connected_components(atoms):
            cp = LatticePoint(comp)
            comp_rels = {a.rel for a in comp}
            ckeep = tuple(v for v in keep_axes
                          if (v.kind == "attr" and v.owner[0] in cp.vars)
                          or (v.kind == "edge" and v.owner[0] in comp_rels))
            out.append((cp, ckeep))

    if use_butterfly and not kept_edges and k > 0:
        for bits in itertools.product((0, 1), repeat=k):
            pattern({r for r, b in zip(effective, bits) if b == 1},
                    kept_attrs)
    else:
        for r_bits in itertools.product((0, 1), repeat=k):
            A = {r for r, b in zip(effective, r_bits) if b == 1}
            B = [r for r in effective if r not in A]
            axes_A = kept_attrs + tuple(
                v for r in sorted(A) for v in kept_edges.get(r, ()))
            for j in range(len(B) + 1):
                for S in itertools.combinations(B, j):
                    pattern(A | set(S), axes_A)
    return out


# --------------------------------------------------------------------------
# complete ct-table
# --------------------------------------------------------------------------

def complete_ct(point: LatticePoint, keep: Sequence[CtVar],
                provider: PositiveProvider,
                stats: Optional[CostStats] = None,
                use_butterfly: bool = True,
                mobius_fn: Optional[Callable[[jnp.ndarray, int], jnp.ndarray]] = None
                ) -> CtTable:
    """Complete ct-table over ``keep`` — the Möbius Join.

    ``keep`` may contain entity-attr axes, edge-attr axes, and relationship
    indicator axes of the point.  Relations with neither a kept indicator nor
    a kept edge attribute impose no constraint once their indicator is summed
    out, so they are dropped from the pattern up front (this is what makes
    HYBRID's per-family tables small).
    """
    keep = tuple(keep)
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges: Dict[str, List[CtVar]] = {}
    for v in keep:
        if v.kind == "edge":
            kept_edges.setdefault(v.owner[0], []).append(v)
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}

    effective = sorted(set(kept_edges) | kept_rinds)
    k = len(effective)

    # final tensor
    shape = tuple(v.card for v in keep)
    final = jnp.zeros(shape, dtype=jnp.result_type(jnp.float32))

    kept_rinds_pre = {v.owner[0] for v in keep if v.kind == "rind"}
    # blocks for distinct A are disjoint iff every rel with a kept edge axis
    # also has its indicator kept (then the rind bits separate all blocks);
    # a kept edge axis WITHOUT its rind spans the N/A slot that the A-less
    # block writes, so those must accumulate.
    disjoint_blocks = all(r in kept_rinds_pre for v in keep if v.kind == "edge"
                          for r in [v.owner[0]])

    def embed(A: Set[str], table: CtTable) -> None:
        """Write block-A into `final`.  When blocks are disjoint a
        dynamic_update_slice (one cheap primitive) replaces the generic
        scatter-add that ``.at[idx].add`` lowers to (§Perf H3 it.2)."""
        nonlocal final
        starts: List[int] = []
        block_axes: List[CtVar] = []
        for v in keep:
            if v.kind == "rind":
                starts.append(1 if v.owner[0] in A else 0)
            elif v.kind == "edge" and v.owner[0] not in A:
                starts.append(v.card - 1)       # N/A slot
            else:
                starts.append(0)
                block_axes.append(v)
        aligned = table.transpose_to(tuple(block_axes))
        block = aligned.counts.astype(final.dtype)
        # expand pinned axes to size 1 for the slice write
        shape = tuple(v.card if v in block_axes else 1 for v in keep)
        block = block.reshape(shape)
        if disjoint_blocks:
            final = jax.lax.dynamic_update_slice(final, block, tuple(starts))
        else:
            idx = tuple(slice(st, st + sh) for st, sh in zip(starts, shape))
            final = final.at[idx].add(block)

    no_edge_axes = not kept_edges
    if use_butterfly and no_edge_axes and k > 0:
        # stack Y[c in {*,T}^k] = ct_+(T-set of c), butterfly to {F,T}^k
        fn = mobius_fn or superset_mobius
        blocks = []
        for bits in itertools.product((0, 1), repeat=k):
            X = {r for r, b in zip(effective, bits) if b == 1}
            t = _pattern_table(point, X, kept_attrs, provider)
            blocks.append(t.transpose_to(kept_attrs).counts)
        attr_shape = tuple(v.card for v in kept_attrs)
        stack = jnp.stack(blocks).reshape((2,) * k + attr_shape)
        out = fn(stack, k)
        # with no edge axes the complete table IS the transform output, up
        # to axis order: rind axis i = effective[i] ({0:F, 1:T} matches the
        # rind_var convention), attr axis k+j = kept_attrs[j].  One
        # transpose replaces 2^k scatter dispatches (§Perf H3 it.1).
        src_axis = ({rind_var(r).owner: i for i, r in enumerate(effective)}
                    | {v.owner: k + j for j, v in enumerate(kept_attrs)})
        perm = tuple(src_axis[v.owner] for v in keep)
        final = jnp.transpose(out, perm) \
            if perm != tuple(range(len(perm))) else out
    else:
        for r_bits in itertools.product((0, 1), repeat=k):
            A = {r for r, b in zip(effective, r_bits) if b == 1}
            B = [r for r in effective if r not in A]
            axes_A = kept_attrs + tuple(
                v for r in sorted(A) for v in kept_edges.get(r, ()))
            acc: Optional[jnp.ndarray] = None
            for j in range(len(B) + 1):
                for S in itertools.combinations(B, j):
                    t = _pattern_table(point, A | set(S), axes_A, provider)
                    contrib = t.transpose_to(axes_A).counts
                    sign = -1.0 if j % 2 else 1.0
                    acc = contrib * sign if acc is None else acc + sign * contrib
            assert acc is not None
            embed(A, CtTable(axes_A, acc))

    tab = CtTable(keep, final)
    if stats is not None:
        stats.ct_cells += tab.size
    return tab
