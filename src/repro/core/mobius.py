"""The Möbius Join: extend positive ct-tables to complete ct-tables.

Inclusion–exclusion over relationship indicators (Qian, Schulte & Sun 2014):
for a final configuration with relation set ``A`` true and ``B`` false,

    N[A=T, B=F, attrs] = sum_{S subseteq B} (-1)^|S| ct_+[A u S true, attrs]

No access to the original data is needed: every term is a positive ct-table of
a *sub-pattern*, served by a :class:`PositiveProvider` — one of the policies
in :mod:`repro.core.engine` (cached-full for PRECOUNT/HYBRID, on-demand for
ONDEMAND, message recombination for TUPLEID), all backed by the shared
planner/executor/cache machinery — with disconnected sub-patterns
factorising into outer products of component tables and per-variable
histograms.

Two equivalent evaluation orders are implemented:

* ``blockwise`` — explicit 3^k-term sum, handles kept edge attributes (whose
  axes only exist while their relation is true; when false they collapse to
  the N/A slot).
* ``butterfly`` — the superset Möbius transform as k in-place passes
  ``F-slice = *-slice − T-slice`` over a [2^k, D] stack; this is the
  memory-bound transform the Pallas kernel (kernels/mobius_kernel.py)
  implements.  Used when no edge-attr axes are kept.  The ``mobius_fn``
  hook is normally the executor's negative-phase step
  (:meth:`repro.core.executors.Executor.mobius`), which dispatches to the
  Pallas kernel when the executor was built with ``use_pallas_mobius``.

The butterfly path also batches ACROSS queries: butterfly input stacks of
same-``tree_signature`` families are same-shape by construction, so
:func:`complete_ct_many` stacks them into one ``[B, 2^k, D]`` tensor and
runs a single transform per shape group (:func:`butterfly_batch`, or the
executor's jitted :meth:`~repro.core.executors.Executor.mobius_batch`) —
one negative-phase dispatch for a whole hill-climbing round instead of one
per family.

The transform output is integral and non-negative (counts); property tests
assert both.
"""

from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .contract import CostStats
from .ct import CtTable, scalar_table
from .variables import (Atom, CtVar, LatticePoint, Var, connected_components,
                        rind_var)


class PositiveProvider(Protocol):
    """Source of positive ct-tables and variable histograms."""

    def positive(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> CtTable: ...

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable: ...


# --------------------------------------------------------------------------
# superset Möbius transform (pure-jnp reference; Pallas kernel mirrors this)
# --------------------------------------------------------------------------

def superset_mobius(stack: jnp.ndarray, k: int) -> jnp.ndarray:
    """In the leading ``k`` axes (each of size 2, index 1 = "relation true",
    index 0 = "unconstrained"), replace index 0 with "relation false" by
    applying ``x0 <- x0 - x1`` per axis.  Equivalent to
    ``N[A] = sum_{S >= A} (-1)^{|S|-|A|} Y[S]``."""
    x = stack
    for i in range(k):
        x0 = jnp.take(x, 0, axis=i) - jnp.take(x, 1, axis=i)
        x1 = jnp.take(x, 1, axis=i)
        x = jnp.stack([x0, x1], axis=i)
    return x


def butterfly_batch(stacks: Sequence[jnp.ndarray], k: int,
                    mobius_fn: Optional[Callable[[jnp.ndarray, int],
                                                 jnp.ndarray]] = None
                    ) -> List[jnp.ndarray]:
    """Apply the superset Möbius transform to MANY same-shape butterfly
    stacks in one dispatch.

    The transform only acts on the leading ``k`` binary axes and is
    elementwise over everything else, so batching is a layout trick: the
    stacks are stacked into ``[B, 2, ..., 2, attrs]``, the batch axis is
    moved to the *trailing* (attribute) side, and ``mobius_fn`` — any
    single-stack transform, the pure-jnp :func:`superset_mobius` or the
    Pallas kernel adapter — runs once over the widened attribute space.
    Results are bit-identical to per-stack application (the transform is
    elementwise across the batch axis; no op reordering occurs).

    Args:
        stacks: same-shape arrays, each ``(2,)*k + attr_shape``.
        k: number of leading indicator axes.
        mobius_fn: single-stack transform ``(stack, k) -> stack``; defaults
            to :func:`superset_mobius`.

    Returns:
        One transformed array per input, in input order.

    Usage::

        outs = butterfly_batch([s1, s2, s3], k)
    """
    stacks = list(stacks)
    if not stacks:
        return []
    fn = mobius_fn if mobius_fn is not None else superset_mobius
    if len(stacks) == 1:
        return [fn(stacks[0], k)]
    out = trailing_batch_transform(jnp.stack(stacks), k, fn)
    return [out[i] for i in range(len(stacks))]


def trailing_batch_transform(batch: jnp.ndarray, k: int,
                             fn: Callable[[jnp.ndarray, int], jnp.ndarray]
                             ) -> jnp.ndarray:
    """The batching layout trick shared by :func:`butterfly_batch` and
    :meth:`~repro.core.executors.Executor.mobius_batch`: move the leading
    batch axis of ``[B, 2..2, attrs]`` to the trailing (attribute) side —
    where the transform is elementwise — apply the single-stack ``fn``
    once, and move it back."""
    moved = jnp.moveaxis(batch, 0, -1)              # [2..2, attrs, B]
    return jnp.moveaxis(fn(moved, k), -1, 0)


# --------------------------------------------------------------------------
# butterfly plumbing shared by the per-query and batched complete-CT paths
# --------------------------------------------------------------------------

class _ButterflyPlan:
    """Static description of one butterfly-eligible complete-CT query:
    the kept axes split into attrs vs indicator relations, plus the final
    transpose from transform layout to request layout."""

    __slots__ = ("keep", "kept_attrs", "effective", "k", "perm")

    def __init__(self, keep, kept_attrs, effective, k, perm):
        self.keep, self.kept_attrs = keep, kept_attrs
        self.effective, self.k, self.perm = effective, k, perm


def _butterfly_plan(point: LatticePoint,
                    keep: Tuple[CtVar, ...]) -> Optional[_ButterflyPlan]:
    """The butterfly evaluation plan for ``(point, keep)``, or ``None``
    when the query is not butterfly-eligible (kept edge-attr axes need the
    blockwise N/A-slot handling; ``k == 0`` has no indicator axes to
    transform)."""
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges = [v for v in keep if v.kind == "edge"]
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}
    effective = tuple(sorted(kept_rinds))
    k = len(effective)
    if kept_edges or k == 0:
        return None
    # rind axis i = effective[i] ({0:F, 1:T} matches the rind_var
    # convention), attr axis k+j = kept_attrs[j]; one transpose replaces
    # 2^k scatter dispatches (§Perf H3 it.1).
    src_axis = ({rind_var(r).owner: i for i, r in enumerate(effective)}
                | {v.owner: k + j for j, v in enumerate(kept_attrs)})
    perm = tuple(src_axis[v.owner] for v in keep)
    return _ButterflyPlan(keep, kept_attrs, effective, k, perm)


def _butterfly_blocks(point: LatticePoint, bp: _ButterflyPlan,
                      provider: PositiveProvider,
                      memo: Optional[Dict] = None) -> List[jnp.ndarray]:
    """The aligned transform-input blocks, one per ``{*,T}^k`` corner in
    ``itertools.product`` order: Y[c] = ct_+(T-set of c) over the kept
    attrs (positive phase of the Möbius join).

    ``memo`` (used by :func:`complete_ct_many`) caches the aligned block
    arrays across a batch of queries: a same-signature flood shares its
    sub-pattern tables — most notably the all-unconstrained block, a pure
    product of histograms identical for every family over the same
    variables — so the per-query assembly glue runs once per DISTINCT
    block, not once per family."""
    blocks = []
    for bits in itertools.product((0, 1), repeat=bp.k):
        X = {r for r, b in zip(bp.effective, bits) if b == 1}
        blk = None
        mkey = None
        if memo is not None:
            # everything the block depends on: the sub-pattern's atoms,
            # the point's var set (histogram factors), the kept axes
            mkey = (tuple(a for a in point.atoms if a.rel in X),
                    tuple(point.vars), bp.kept_attrs)
            blk = memo.get(mkey)
        if blk is None:
            t = _pattern_table(point, X, bp.kept_attrs, provider)
            blk = t.transpose_to(bp.kept_attrs).counts
            if memo is not None:
                memo[mkey] = blk
        blocks.append(blk)
    return blocks


def _butterfly_stack(point: LatticePoint, bp: _ButterflyPlan,
                     provider: PositiveProvider,
                     memo: Optional[Dict] = None) -> jnp.ndarray:
    """The transform input: the blocks of :func:`_butterfly_blocks`
    stacked to ``(2,)*k + attr_shape`` (eager assembly glue; the fused
    batched path skips this and hands the raw blocks to the jitted
    evaluator instead — see :meth:`~repro.core.executors.Executor
    .mobius_batch_fused`)."""
    blocks = _butterfly_blocks(point, bp, provider, memo)
    attr_shape = tuple(v.card for v in bp.kept_attrs)
    return jnp.stack(blocks).reshape((2,) * bp.k + attr_shape)


def _butterfly_finalise(bp: _ButterflyPlan, out: jnp.ndarray) -> CtTable:
    """Transform output -> the complete ct-table in request axis order."""
    final = jnp.transpose(out, bp.perm) \
        if bp.perm != tuple(range(len(bp.perm))) else out
    return CtTable(bp.keep, final)


# --------------------------------------------------------------------------
# pattern tables: positive count of a relation subset over the point's vars
# --------------------------------------------------------------------------

def _pattern_table(point: LatticePoint, rels: Set[str],
                   keep_axes: Tuple[CtVar, ...],
                   provider: PositiveProvider) -> CtTable:
    """ct_+ of the sub-pattern with ``rels`` true, over all vars of ``point``,
    projected onto ``keep_axes`` (entity attrs + edge attrs of rels)."""
    atoms = tuple(a for a in point.atoms if a.rel in rels)
    out: Optional[CtTable] = None
    covered: Set[Var] = set()
    for comp in connected_components(atoms):
        cp = LatticePoint(comp)
        comp_rels = {a.rel for a in comp}
        ckeep = tuple(v for v in keep_axes
                      if (v.kind == "attr" and v.owner[0] in cp.vars)
                      or (v.kind == "edge" and v.owner[0] in comp_rels))
        t = provider.positive(cp, ckeep)
        out = t if out is None else out.outer(t)
        covered.update(cp.vars)
    for var in point.vars:
        if var in covered:
            continue
        vkeep = tuple(v for v in keep_axes
                      if v.kind == "attr" and v.owner[0] == var)
        h = provider.hist(var, vkeep)
        out = h if out is None else out.outer(h)
    assert out is not None
    return out.transpose_to(tuple(v for v in keep_axes if v in out.vars)) \
        if set(out.vars) == set(keep_axes) else out.project(keep_axes)


def positive_queries(point: LatticePoint, keep: Sequence[CtVar],
                     use_butterfly: bool = True
                     ) -> List[Tuple[LatticePoint, Tuple[CtVar, ...]]]:
    """The positive sub-queries :func:`complete_ct` will request from its
    provider for ``(point, keep)``, in request order.

    This mirrors the Möbius join's own enumeration (butterfly vs blockwise
    branch, relation dropping, connected-component factorisation) without
    touching any data — it is what lets a serving layer batch a whole
    round of family queries into signature buckets *before* any Möbius
    join runs (see :meth:`repro.serve.service.CountingService.prefetch`).
    Per-variable histogram queries are omitted: they are cheap, shared,
    and cached on first use.  Duplicates across terms are preserved
    (callers dedupe); every entry is a connected sub-pattern.
    """
    keep = tuple(keep)
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges: Dict[str, List[CtVar]] = {}
    for v in keep:
        if v.kind == "edge":
            kept_edges.setdefault(v.owner[0], []).append(v)
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}
    effective = sorted(set(kept_edges) | kept_rinds)
    k = len(effective)

    out: List[Tuple[LatticePoint, Tuple[CtVar, ...]]] = []

    def pattern(rels: Set[str], keep_axes: Tuple[CtVar, ...]) -> None:
        atoms = tuple(a for a in point.atoms if a.rel in rels)
        for comp in connected_components(atoms):
            cp = LatticePoint(comp)
            comp_rels = {a.rel for a in comp}
            ckeep = tuple(v for v in keep_axes
                          if (v.kind == "attr" and v.owner[0] in cp.vars)
                          or (v.kind == "edge" and v.owner[0] in comp_rels))
            out.append((cp, ckeep))

    if use_butterfly and not kept_edges and k > 0:
        for bits in itertools.product((0, 1), repeat=k):
            pattern({r for r, b in zip(effective, bits) if b == 1},
                    kept_attrs)
    else:
        for r_bits in itertools.product((0, 1), repeat=k):
            A = {r for r, b in zip(effective, r_bits) if b == 1}
            B = [r for r in effective if r not in A]
            axes_A = kept_attrs + tuple(
                v for r in sorted(A) for v in kept_edges.get(r, ()))
            for j in range(len(B) + 1):
                for S in itertools.combinations(B, j):
                    pattern(A | set(S), axes_A)
    return out


# --------------------------------------------------------------------------
# complete ct-table
# --------------------------------------------------------------------------

def complete_ct(point: LatticePoint, keep: Sequence[CtVar],
                provider: PositiveProvider,
                stats: Optional[CostStats] = None,
                use_butterfly: bool = True,
                mobius_fn: Optional[Callable[[jnp.ndarray, int], jnp.ndarray]] = None
                ) -> CtTable:
    """Complete ct-table over ``keep`` — the Möbius Join.

    ``keep`` may contain entity-attr axes, edge-attr axes, and relationship
    indicator axes of the point.  Relations with neither a kept indicator nor
    a kept edge attribute impose no constraint once their indicator is summed
    out, so they are dropped from the pattern up front (this is what makes
    HYBRID's per-family tables small).
    """
    keep = tuple(keep)
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges: Dict[str, List[CtVar]] = {}
    for v in keep:
        if v.kind == "edge":
            kept_edges.setdefault(v.owner[0], []).append(v)
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}

    effective = sorted(set(kept_edges) | kept_rinds)
    k = len(effective)

    # final tensor
    shape = tuple(v.card for v in keep)
    final = jnp.zeros(shape, dtype=jnp.result_type(jnp.float32))

    kept_rinds_pre = {v.owner[0] for v in keep if v.kind == "rind"}
    # blocks for distinct A are disjoint iff every rel with a kept edge axis
    # also has its indicator kept (then the rind bits separate all blocks);
    # a kept edge axis WITHOUT its rind spans the N/A slot that the A-less
    # block writes, so those must accumulate.
    disjoint_blocks = all(r in kept_rinds_pre for v in keep if v.kind == "edge"
                          for r in [v.owner[0]])

    def embed(A: Set[str], table: CtTable) -> None:
        """Write block-A into `final`.  When blocks are disjoint a
        dynamic_update_slice (one cheap primitive) replaces the generic
        scatter-add that ``.at[idx].add`` lowers to (§Perf H3 it.2)."""
        nonlocal final
        starts: List[int] = []
        block_axes: List[CtVar] = []
        for v in keep:
            if v.kind == "rind":
                starts.append(1 if v.owner[0] in A else 0)
            elif v.kind == "edge" and v.owner[0] not in A:
                starts.append(v.card - 1)       # N/A slot
            else:
                starts.append(0)
                block_axes.append(v)
        aligned = table.transpose_to(tuple(block_axes))
        block = aligned.counts.astype(final.dtype)
        # expand pinned axes to size 1 for the slice write
        shape = tuple(v.card if v in block_axes else 1 for v in keep)
        block = block.reshape(shape)
        if disjoint_blocks:
            final = jax.lax.dynamic_update_slice(final, block, tuple(starts))
        else:
            idx = tuple(slice(st, st + sh) for st, sh in zip(starts, shape))
            final = final.at[idx].add(block)

    bp = _butterfly_plan(point, keep) if use_butterfly else None
    if bp is not None:
        # stack Y[c in {*,T}^k] = ct_+(T-set of c), butterfly to {F,T}^k;
        # with no edge axes the complete table IS the transform output, up
        # to axis order.
        fn = mobius_fn or superset_mobius
        stack = _butterfly_stack(point, bp, provider)
        final = _butterfly_finalise(bp, fn(stack, bp.k)).counts
    else:
        for r_bits in itertools.product((0, 1), repeat=k):
            A = {r for r, b in zip(effective, r_bits) if b == 1}
            B = [r for r in effective if r not in A]
            axes_A = kept_attrs + tuple(
                v for r in sorted(A) for v in kept_edges.get(r, ()))
            acc: Optional[jnp.ndarray] = None
            for j in range(len(B) + 1):
                for S in itertools.combinations(B, j):
                    t = _pattern_table(point, A | set(S), axes_A, provider)
                    contrib = t.transpose_to(axes_A).counts
                    sign = -1.0 if j % 2 else 1.0
                    acc = contrib * sign if acc is None else acc + sign * contrib
            assert acc is not None
            embed(A, CtTable(axes_A, acc))

    tab = CtTable(keep, final)
    if stats is not None:
        stats.ct_cells += tab.size
    return tab


def complete_ct_many(queries: Sequence[Tuple[LatticePoint,
                                             Sequence[CtVar]]],
                     provider: PositiveProvider,
                     stats: Optional[CostStats] = None,
                     use_butterfly: bool = True,
                     mobius_fn: Optional[Callable[[jnp.ndarray, int],
                                                  jnp.ndarray]] = None,
                     mobius_batch_fn: Optional[Callable[
                         [Sequence[jnp.ndarray], int],
                         List[jnp.ndarray]]] = None,
                     mobius_fused_fn: Optional[Callable[
                         [Sequence[Sequence[jnp.ndarray]], int,
                          Tuple[int, ...]],
                         List[jnp.ndarray]]] = None) -> List[CtTable]:
    """Complete ct-tables for many ``(point, keep)`` queries, with the
    Möbius negative phase batched across same-shape butterfly stacks.

    Butterfly-eligible queries (no kept edge-attr axes, ``k > 0``) are
    grouped — same-signature families are same-shape by construction —
    and each group runs ONE transform.  With ``mobius_fused_fn`` (normally
    the executor's :meth:`~repro.core.executors.Executor
    .mobius_batch_fused`) the groups are keyed by ``(attr shape, k,
    finalise perm)`` and the *aligned blocks* go straight into the jitted
    evaluator — stack assembly, transform AND the finalise transpose are
    one dispatch per group, with per-query results sliced inside the jit.
    Without it, stacks are assembled eagerly and ``mobius_batch_fn``
    (normally :meth:`~repro.core.executors.Executor.mobius_batch`)
    transforms each ``(stack shape, k)`` group, paying per-query glue.
    Everything else (blockwise queries, ``k == 0``) falls back to
    :func:`complete_ct` per query.

    Args:
        queries: ``(point, keep)`` pairs; ``keep`` may contain attr and
            rind axes of the point (edge-attr axes force the blockwise
            fallback, exactly as in :func:`complete_ct`).
        provider: positive-table source (a policy from
            :mod:`repro.core.engine`).
        stats: optional :class:`~repro.core.contract.CostStats`;
            ``ct_cells`` accounting matches the per-query path.
        use_butterfly / mobius_fn: as for :func:`complete_ct`.
        mobius_batch_fn: batched transform ``(stacks, k) -> [stack]``;
            defaults to :func:`butterfly_batch` over ``mobius_fn``.
        mobius_fused_fn: fused batched transform ``(block_lists, k, perm)
            -> [table array]``; preferred over ``mobius_batch_fn`` when
            given.

    Returns:
        One :class:`~repro.core.ct.CtTable` per query, positionally
        aligned with ``queries`` and numerically identical to per-query
        :func:`complete_ct`.

    Usage::

        tabs = complete_ct_many([(point, keep) for keep in keeps], policy,
                                mobius_fused_fn=executor.mobius_batch_fused)
    """
    queries = [(point, tuple(keep)) for point, keep in queries]
    if mobius_batch_fn is None:
        mobius_batch_fn = lambda stacks, k: butterfly_batch(
            stacks, k, mobius_fn)
    results: List[Optional[CtTable]] = [None] * len(queries)
    eligible: List[Tuple[int, _ButterflyPlan, List[jnp.ndarray]]] = []
    memo: Dict = {}          # cross-query block reuse within this batch
    for i, (point, keep) in enumerate(queries):
        bp = _butterfly_plan(point, keep) if use_butterfly else None
        if bp is None:
            results[i] = complete_ct(point, keep, provider, stats,
                                     use_butterfly=use_butterfly,
                                     mobius_fn=mobius_fn)
        else:
            eligible.append((i, bp,
                             _butterfly_blocks(point, bp, provider, memo)))
    if mobius_fused_fn is not None:
        groups: Dict[Tuple, List] = {}
        for item in eligible:
            _, bp, _ = item
            attr_shape = tuple(v.card for v in bp.kept_attrs)
            groups.setdefault((attr_shape, bp.k, bp.perm), []).append(item)
        for (_, k, perm), members in groups.items():
            outs = mobius_fused_fn([blks for _, _, blks in members], k,
                                   perm)
            for (i, bp, _), arr in zip(members, outs):
                tab = CtTable(bp.keep, arr)     # already in request layout
                if stats is not None:
                    stats.ct_cells += tab.size
                results[i] = tab
        return results
    groups2: Dict[Tuple, List[Tuple[int, _ButterflyPlan, jnp.ndarray]]] = {}
    for i, bp, blks in eligible:
        attr_shape = tuple(v.card for v in bp.kept_attrs)
        stack = jnp.stack(blks).reshape((2,) * bp.k + attr_shape)
        groups2.setdefault((tuple(stack.shape), bp.k), []).append(
            (i, bp, stack))
    for (_, k), members in groups2.items():
        outs = mobius_batch_fn([s for _, _, s in members], k)
        for (i, bp, _), out in zip(members, outs):
            tab = _butterfly_finalise(bp, out)
            if stats is not None:
                stats.ct_cells += tab.size
            results[i] = tab
    return results


# --------------------------------------------------------------------------
# delta propagation THROUGH the butterfly: writes stop flushing the
# negative phase
# --------------------------------------------------------------------------

def _butterfly_delta_blocks(point: LatticePoint, bp: _ButterflyPlan,
                            rel: str, provider: PositiveProvider,
                            memo: Dict, zeros: Dict) -> List[jnp.ndarray]:
    """Transform-input blocks of the COMPLETE-table *delta* for a write to
    ``rel``, in the same ``{*,T}^k`` corner order as
    :func:`_butterfly_blocks`.

    Each corner's block is the positive table of the sub-pattern with
    corner set ``X`` true, so it depends on ``rel``'s edge table iff
    ``rel in X`` (atoms of other relations never enter the sub-pattern —
    see :func:`_pattern_table`).  Corners without ``rel`` therefore have an
    exactly-zero delta and are materialised as explicit zero blocks;
    corners with ``rel`` evaluate the SAME pattern assembly against a
    *delta provider* (positives contracted over the
    :meth:`~repro.core.database.FactDelta.as_db` view), which by
    multilinearity yields the exact per-block delta as long as the point
    uses ``rel`` in exactly one atom (callers guard this).

    ``memo``/``zeros`` are shared across a batch of queries: delta blocks
    dedupe by sub-pattern exactly like the full path's blocks, and one
    zero array serves every corner of a given ``(attr shape, dtype)``.
    """
    real: Dict[Tuple[int, ...], jnp.ndarray] = {}
    corners = list(itertools.product((0, 1), repeat=bp.k))
    for bits in corners:
        X = {r for r, b in zip(bp.effective, bits) if b == 1}
        if rel not in X:
            continue
        mkey = (tuple(a for a in point.atoms if a.rel in X),
                tuple(point.vars), bp.kept_attrs)
        blk = memo.get(mkey)
        if blk is None:
            t = _pattern_table(point, X, bp.kept_attrs, provider)
            blk = t.transpose_to(bp.kept_attrs).counts
            memo[mkey] = blk
        real[bits] = blk
    attr_shape = tuple(v.card for v in bp.kept_attrs)
    dtype = next(iter(real.values())).dtype
    zkey = (attr_shape, jnp.dtype(dtype).name)
    zblk = zeros.get(zkey)
    if zblk is None:
        zblk = zeros[zkey] = jnp.zeros(attr_shape, dtype=dtype)
    return [real.get(bits, zblk) for bits in corners]


def _blockwise_ct_delta(point: LatticePoint, keep: Tuple[CtVar, ...],
                        rel: str, provider: PositiveProvider,
                        memo: Dict) -> CtTable:
    """Blockwise complete-table delta for queries the butterfly cannot
    serve (kept edge-attr axes need the N/A-slot block assembly).

    Mirrors :func:`complete_ct`'s blockwise branch, but keeps only the
    inclusion–exclusion terms whose pattern contains ``rel`` — every other
    term is independent of ``rel``'s edge multiset, so its delta is
    exactly zero.  ``provider`` serves delta positives (contractions over
    the :meth:`~repro.core.database.FactDelta.as_db` view), so the
    assembled tensor is the exact signed-magnitude delta of the resident
    table; callers guard that ``rel`` appears in exactly one atom.
    ``memo`` dedupes pattern tables across a batch of queries, with the
    same keying as :func:`_butterfly_delta_blocks`.
    """
    kept_attrs = tuple(v for v in keep if v.kind == "attr")
    kept_edges: Dict[str, List[CtVar]] = {}
    for v in keep:
        if v.kind == "edge":
            kept_edges.setdefault(v.owner[0], []).append(v)
    kept_rinds = {v.owner[0] for v in keep if v.kind == "rind"}
    effective = sorted(set(kept_edges) | kept_rinds)
    shape = tuple(v.card for v in keep)
    final = jnp.zeros(shape, dtype=jnp.result_type(jnp.float32))
    disjoint_blocks = all(r in kept_rinds for v in keep if v.kind == "edge"
                          for r in [v.owner[0]])
    for r_bits in itertools.product((0, 1), repeat=len(effective)):
        A = {r for r, b in zip(effective, r_bits) if b == 1}
        B = [r for r in effective if r not in A]
        axes_A = kept_attrs + tuple(
            v for r in sorted(A) for v in kept_edges.get(r, ()))
        acc: Optional[jnp.ndarray] = None
        for j in range(len(B) + 1):
            for S in itertools.combinations(B, j):
                X = A | set(S)
                if rel not in X:
                    continue                  # term independent of rel
                mkey = (tuple(a for a in point.atoms if a.rel in X),
                        tuple(point.vars), axes_A)
                blk = memo.get(mkey)
                if blk is None:
                    t = _pattern_table(point, X, axes_A, provider)
                    blk = memo[mkey] = t.transpose_to(axes_A).counts
                sign = -1.0 if j % 2 else 1.0
                acc = blk * sign if acc is None else acc + sign * blk
        if acc is None:
            continue                          # block independent of rel
        starts: List[int] = []
        block_axes: List[CtVar] = []
        for v in keep:
            if v.kind == "rind":
                starts.append(1 if v.owner[0] in A else 0)
            elif v.kind == "edge" and v.owner[0] not in A:
                starts.append(v.card - 1)     # N/A slot
            else:
                starts.append(0)
                block_axes.append(v)
        aligned = CtTable(axes_A, acc).transpose_to(tuple(block_axes))
        block = aligned.counts.astype(final.dtype)
        bshape = tuple(v.card if v in block_axes else 1 for v in keep)
        block = block.reshape(bshape)
        if disjoint_blocks:
            final = jax.lax.dynamic_update_slice(final, block,
                                                 tuple(starts))
        else:
            idx = tuple(slice(s, s + sh) for s, sh in zip(starts, bshape))
            final = final.at[idx].add(block)
    return CtTable(keep, final)


def complete_ct_delta_many(queries: Sequence[Tuple[LatticePoint,
                                                   Sequence[CtVar]]],
                           rel: str,
                           provider: PositiveProvider,
                           stats: Optional[CostStats] = None,
                           mobius_fn: Optional[Callable[
                               [jnp.ndarray, int], jnp.ndarray]] = None,
                           mobius_batch_fn: Optional[Callable[
                               [Sequence[jnp.ndarray], int],
                               List[jnp.ndarray]]] = None,
                           mobius_fused_fn: Optional[Callable[
                               [Sequence[Sequence[jnp.ndarray]], int,
                                Tuple[int, ...]],
                               List[jnp.ndarray]]] = None
                           ) -> List[Tuple[str, Optional[CtTable]]]:
    """Delta tables for many resident complete-CT queries after a write to
    ``rel``, with the negative phase batched exactly like
    :func:`complete_ct_many`.

    The Möbius transform is linear in its input blocks, so the delta of a
    complete table is the transform of the per-block deltas — no resident
    data is re-read and no full butterfly recompute happens.  ``provider``
    must serve *delta* positives: contractions over the
    :meth:`~repro.core.database.FactDelta.as_db` view, so that (by
    multilinearity of positive counts in each relation's edge multiset)
    each affected block's delta is exact; the engine adds
    ``delta.sign * result`` onto the resident table.

    Args:
        queries: ``(point, keep)`` pairs for the RESIDENT entries being
            maintained.
        rel: the relationship the delta wrote.
        provider: delta-positive source (full-valued ``hist``; the engine
            wraps its policy in a view-backed provider).
        stats / mobius_fn / mobius_batch_fn / mobius_fused_fn: as for
            :func:`complete_ct_many`.

    Returns:
        One ``(status, table)`` per query, positionally aligned:

        * ``("delta", ct)`` — ``ct`` is the exact signed-magnitude delta in
          request axis order; add ``sign * ct`` to the resident table;
        * ``("zero", None)`` — the entry provably does not depend on
          ``rel``'s edges (indicator summed out): retain unchanged;
        * ``("fallback", None)`` — not delta-propagatable: ``rel``
          appears in more than one atom, where the delta view
          under-counts cross terms; the caller invalidates or recounts.
          (Kept edge-attr axes take the blockwise N/A-slot assembly
          instead of the transform — :func:`_blockwise_ct_delta` — but
          still yield ``"delta"``.)

    Usage::

        for (key, point, keep), (st, d) in zip(resident,
                complete_ct_delta_many(q, delta.rel, delta_provider)):
            ...
    """
    queries = [(point, tuple(keep)) for point, keep in queries]
    if mobius_batch_fn is None:
        mobius_batch_fn = lambda stacks, k: butterfly_batch(
            stacks, k, mobius_fn)
    results: List[Tuple[str, Optional[CtTable]]] = \
        [("fallback", None)] * len(queries)
    eligible: List[Tuple[int, _ButterflyPlan, List[jnp.ndarray]]] = []
    memo: Dict = {}
    zeros: Dict = {}
    for i, (point, keep) in enumerate(queries):
        bp = _butterfly_plan(point, keep)
        effective = bp.effective if bp is not None else tuple(
            {v.owner[0] for v in keep if v.kind in ("edge", "rind")})
        if rel not in effective:
            # rel's indicator is summed out (or rel is not in the pattern
            # at all): every transform block is independent of rel's edge
            # table, so the resident value is already exact.
            results[i] = ("zero", None)
            continue
        if sum(1 for a in point.atoms if a.rel == rel) != 1:
            continue                          # cross terms: fallback
        if bp is None:
            # kept edge-attr axes: same linearity, blockwise assembly
            tab = _blockwise_ct_delta(point, tuple(keep), rel, provider,
                                      memo)
            if stats is not None:
                stats.ct_cells += tab.size
            results[i] = ("delta", tab)
            continue
        eligible.append((i, bp, _butterfly_delta_blocks(
            point, bp, rel, provider, memo, zeros)))
    if mobius_fused_fn is not None:
        groups: Dict[Tuple, List] = {}
        for item in eligible:
            _, bp, _ = item
            attr_shape = tuple(v.card for v in bp.kept_attrs)
            groups.setdefault((attr_shape, bp.k, bp.perm), []).append(item)
        for (_, k, perm), members in groups.items():
            outs = mobius_fused_fn([blks for _, _, blks in members], k,
                                   perm)
            for (i, bp, _), arr in zip(members, outs):
                tab = CtTable(bp.keep, arr)   # already in request layout
                if stats is not None:
                    stats.ct_cells += tab.size
                results[i] = ("delta", tab)
        return results
    groups2: Dict[Tuple, List[Tuple[int, _ButterflyPlan, jnp.ndarray]]] = {}
    for i, bp, blks in eligible:
        attr_shape = tuple(v.card for v in bp.kept_attrs)
        stack = jnp.stack(blks).reshape((2,) * bp.k + attr_shape)
        groups2.setdefault((tuple(stack.shape), bp.k), []).append(
            (i, bp, stack))
    for (_, k), members in groups2.items():
        outs = mobius_batch_fn([s for _, _, s in members], k)
        for (i, bp, _), out in zip(members, outs):
            tab = _butterfly_finalise(bp, out)
            if stats is not None:
                stats.ct_cells += tab.size
            results[i] = ("delta", tab)
    return results


def butterfly_delta(point: LatticePoint, keep: Sequence[CtVar], rel: str,
                    provider: PositiveProvider,
                    stats: Optional[CostStats] = None,
                    mobius_fn: Optional[Callable[[jnp.ndarray, int],
                                                 jnp.ndarray]] = None
                    ) -> Tuple[str, Optional[CtTable]]:
    """Single-query convenience over :func:`complete_ct_delta_many` — the
    ``(status, delta table)`` for one resident complete-CT entry after a
    write to ``rel``."""
    return complete_ct_delta_many([(point, keep)], rel, provider, stats,
                                  mobius_fn=mobius_fn)[0]
