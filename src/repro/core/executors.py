"""Executors: pluggable backends that evaluate contraction plans.

The planner (:mod:`repro.core.plan`) fixes the traversal; an executor picks
the message representation:

* :class:`DenseExecutor` — the one-hot path: per-variable one-hot attribute
  encodings, per-relationship ``gather → (outer) multiply → segment_sum``
  hops, chunked Khatri-Rao reduction at the root.  Every hop costs
  O(edges × D) multiply-accumulates and materialises (n, D) messages — MXU
  friendly, but the Eq. (3) blowup is paid in *entities × D*.

* :class:`SparseExecutor` — the code path: attribute combinations are
  mixed-radix ``int32`` codes, never one-hot.  A leaf hop is a single
  ``jax.ops.segment_sum`` of ones over flattened ``(parent, code)`` keys —
  O(nnz) scatter-adds over the raw edge list with no per-entity one-hot
  materialisation — and the root combine segment-sums child messages by the
  root's own code.  Positive ct-tables therefore scale in ``nnz`` rather
  than ``entities × D``, which is what makes the paper's
  VisualGenome-scale configuration reachable.

Both executors expose the same interface (``positive`` / ``hist`` /
``leaf_hop`` / ``root_reduce`` / ``mobius``) so strategies, the Möbius join
and the tuple-ID variant are executor-agnostic.  The negative-phase step
(``mobius``) defaults to the pure-jnp superset transform and can be wired
to the Pallas kernel (``kernels/mobius_kernel.py``) with
``use_pallas_mobius=True``.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .contract import CostStats, _khatri_rao_reduce, _onehot
from .ct import CtTable
from .database import RelationalDB
from .plan import ContractionPlan, FactorSpec, HopSpec, NodeSpec
from .variables import Atom, CtVar, Var

_MAX_CHUNK_CELLS = 32_000_000
_INT32_LIMIT = 2 ** 31 - 1


def project_columns(m: jnp.ndarray, mvars: Tuple[CtVar, ...],
                    keep: Sequence[CtVar]
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
    """Marginalise the column axes of an entity-indexed message matrix
    ``(n, prod cards(mvars))`` onto the vars present in ``keep``."""
    want = tuple(v for v in mvars if v in keep)
    if want == tuple(mvars):
        return m, tuple(mvars)
    wide = m.reshape((m.shape[0],) + tuple(v.card for v in mvars))
    dropped = tuple(i + 1 for i, v in enumerate(mvars) if v not in keep)
    if dropped:
        wide = jnp.sum(wide, axis=dropped)
    return wide.reshape(m.shape[0], -1), want


def _finalise(flat: jnp.ndarray, mvars: Sequence[CtVar],
              keep: Sequence[CtVar], stats: Optional[CostStats]) -> CtTable:
    mvars = tuple(mvars)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars \
        else flat.reshape(())
    tab = CtTable(mvars, counts)
    order = tuple(v for v in keep if v in tab.vars)
    if order != tab.vars:
        tab = tab.transpose_to(order)
    if stats is not None:
        stats.ct_cells += tab.size
    return tab


class Executor:
    """Backend interface: evaluate plans against a database."""

    name = "base"

    def __init__(self, dtype=jnp.float32, mobius_fn=None,
                 use_pallas_mobius: bool = False):
        self.dtype = dtype
        if mobius_fn is None and use_pallas_mobius:
            from ..kernels.ops import mobius_nd
            mobius_fn = mobius_nd
        self._mobius_fn = mobius_fn

    # -- negative phase -----------------------------------------------------
    def mobius(self, stack: jnp.ndarray, k: int) -> jnp.ndarray:
        """Superset Möbius transform over the leading ``k`` binary axes —
        the Möbius join's butterfly step."""
        if self._mobius_fn is not None:
            return self._mobius_fn(stack, k)
        from .mobius import superset_mobius
        return superset_mobius(stack, k)

    # -- positive phase -----------------------------------------------------
    def positive(self, db: RelationalDB, plan: ContractionPlan,
                 stats: Optional[CostStats] = None) -> CtTable:
        """Evaluate a compiled plan: one message per root hop, then the
        root combine.  Backends only implement the two primitives."""
        factors = [self.hop_message(db, hop, stats) for hop in plan.root.hops]
        return self.root_reduce(db, plan.root.own, factors, plan.keep, stats)

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Full message matrix ``(n_parent, D)`` of one root-adjacent hop,
        including the child's entire subtree."""
        raise NotImplementedError

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        raise NotImplementedError

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Message matrix ``(n_parent, D)`` a bare child variable sends
        through one relationship — the tuple-ID precompute primitive."""
        raise NotImplementedError

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        """Combine the root variable's own attributes with entity-indexed
        factor matrices ``(n_root, D_i)`` into a ct-table."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# shared edge-list bookkeeping
# ---------------------------------------------------------------------------

def _hop_indices(db: RelationalDB, atom: Atom, child: Var, parent: Var):
    rt = db.relations[atom.rel]
    if child == atom.src and parent == atom.dst:
        return rt, rt.src, rt.dst, db.entities[atom.dst.etype].size
    if child == atom.dst and parent == atom.src:
        return rt, rt.dst, rt.src, db.entities[atom.src.etype].size
    raise AssertionError("atom does not connect child/parent")


# ---------------------------------------------------------------------------
# dense executor (one-hot contraction)
# ---------------------------------------------------------------------------

class DenseExecutor(Executor):
    name = "dense"

    def _entity_factor(self, db: RelationalDB, fs: FactorSpec
                       ) -> Tuple[jnp.ndarray, List[CtVar]]:
        tab = db.entities[fs.var.etype]
        msg = jnp.ones((tab.size, 1), dtype=self.dtype)
        mvars: List[CtVar] = []
        for cv in fs.attrs:
            hot = _onehot(jnp.asarray(tab.attrs[cv.owner[1]]), cv.card,
                          self.dtype)
            n, d = msg.shape
            msg = (msg[:, :, None] * hot[:, None, :]).reshape(n, d * cv.card)
            mvars.append(cv)
        return msg, mvars

    def _hop(self, db: RelationalDB, hop: HopSpec, child_msg: jnp.ndarray,
             child_vars: List[CtVar], stats: Optional[CostStats]
             ) -> Tuple[jnp.ndarray, List[CtVar]]:
        rt, gather_idx, scatter_idx, n_parent = _hop_indices(
            db, hop.atom, hop.child, hop.parent)
        m = child_msg[jnp.asarray(gather_idx)]            # (edges, D)
        mvars = list(child_vars)
        for cv in hop.edge_attrs:
            hot = _onehot(jnp.asarray(rt.attrs[cv.owner[1]]), cv.card,
                          self.dtype)                     # card+1, NA empty
            n, d = m.shape
            m = (m[:, :, None] * hot[:, None, :]).reshape(n, d * cv.card)
            mvars.append(cv)
        out = jax.ops.segment_sum(m, jnp.asarray(scatter_idx),
                                  num_segments=n_parent)
        if stats is not None:
            stats.joins += 1
            stats.rows_scanned += int(gather_idx.shape[0])
        return out, mvars

    def _node_message(self, db: RelationalDB, node: NodeSpec,
                      stats: Optional[CostStats]
                      ) -> Tuple[jnp.ndarray, List[CtVar]]:
        msg, mvars = self._entity_factor(db, node.own)
        for hop in node.hops:
            child_msg, child_vars = self._node_message(db, hop.child_node,
                                                       stats)
            h, hvars = self._hop(db, hop, child_msg, child_vars, stats)
            n, d = msg.shape
            msg = (msg[:, :, None] * h[:, None, :]).reshape(n, d * h.shape[1])
            mvars = mvars + hvars
        return msg, mvars

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        child_msg, child_vars = self._node_message(db, hop.child_node, stats)
        m, mvars = self._hop(db, hop, child_msg, child_vars, stats)
        return m, tuple(mvars)

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        msg, mvars = self._entity_factor(db, FactorSpec(var, tuple(attrs)))
        flat = jnp.sum(msg, axis=0)
        counts = flat.reshape(tuple(v.card for v in mvars)) if mvars \
            else flat[0]
        return CtTable(tuple(mvars), counts)

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        fs = FactorSpec(child, tuple(child_attrs))
        leaf = NodeSpec(fs, (), fs.attrs)
        hop = HopSpec(atom, child, parent, tuple(edge_attrs), leaf,
                      fs.attrs + tuple(edge_attrs))
        return self.hop_message(db, hop, stats)

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        fs: List[Tuple[jnp.ndarray, List[CtVar]]] = [
            self._entity_factor(db, own)]
        fs.extend((m, list(vs)) for m, vs in factors)
        flat, mvars = _khatri_rao_reduce(fs)
        return _finalise(flat, mvars, keep, stats)


# ---------------------------------------------------------------------------
# sparse executor (int32 codes + segment_sum over edge lists)
# ---------------------------------------------------------------------------

class _SparseMsg:
    """Per-entity message: a mixed-radix scalar code over ``svars`` (one
    value per entity — exact, no one-hot) plus an optional dense block over
    ``dvars`` (present only after an aggregation made the distribution
    genuinely multi-valued)."""

    __slots__ = ("code", "ds", "svars", "dense", "dvars")

    def __init__(self, code, ds, svars, dense, dvars):
        self.code, self.ds, self.svars = code, ds, svars
        self.dense, self.dvars = dense, dvars


def _np_codes(cols: List[np.ndarray], cards: List[int]) -> np.ndarray:
    code = np.zeros(len(cols[0]) if cols else 0, dtype=np.int64)
    for col, card in zip(cols, cards):
        code = code * card + col.astype(np.int64)
    return code


class SparseExecutor(Executor):
    name = "sparse"

    def _entity_code(self, db: RelationalDB, fs: FactorSpec
                     ) -> Tuple[Optional[np.ndarray], int]:
        """Mixed-radix host-side code per entity.  Kept as numpy: codes are
        consumed by host index arithmetic in ``_hop``; only the final
        segment-id array ever moves to the device."""
        if not fs.attrs:
            return None, 1
        tab = db.entities[fs.var.etype]
        cols = [np.asarray(tab.attrs[cv.owner[1]]) for cv in fs.attrs]
        code = _np_codes(cols, [cv.card for cv in fs.attrs])
        return code.astype(np.int32), fs.card

    def _hop(self, db: RelationalDB, hop: HopSpec, msg: _SparseMsg,
             stats: Optional[CostStats]
             ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Push a child message through one relationship.  Scalar-coded axes
        travel as index arithmetic inside the segment ids; only genuinely
        dense axes (from deeper aggregations) are carried as row vectors."""
        rt, gather_idx, scatter_idx, n_parent = _hop_indices(
            db, hop.atom, hop.child, hop.parent)
        gather_np = np.asarray(gather_idx)
        n_edges = int(gather_np.shape[0])

        # per-edge scalar code: child code gathered at the child end of the
        # edge, extended with this relationship's kept edge attributes
        ds = msg.ds
        if msg.code is not None:
            ecode = msg.code[gather_np].astype(np.int64)
        else:
            ecode = np.zeros(n_edges, dtype=np.int64)
        svars = tuple(msg.svars)
        for cv in hop.edge_attrs:
            ecode = ecode * cv.card + np.asarray(
                rt.attrs[cv.owner[1]]).astype(np.int64)
            ds *= cv.card
            svars = svars + (cv,)

        total = n_parent * ds
        if total > _INT32_LIMIT:
            raise OverflowError(
                f"sparse hop segment space {total} exceeds int32; use the "
                f"dense executor or reduce kept axes")
        seg = jnp.asarray((np.asarray(scatter_idx).astype(np.int64) * ds
                           + ecode).astype(np.int32))
        if msg.dense is None:
            flat = jax.ops.segment_sum(
                jnp.ones((n_edges,), dtype=self.dtype), seg,
                num_segments=total)
            out = flat.reshape(n_parent, ds)
            out_vars = svars
        else:
            rows = msg.dense[jnp.asarray(gather_np)]       # (edges, Dd)
            agg = jax.ops.segment_sum(rows, seg, num_segments=total)
            out = agg.reshape(n_parent, ds * msg.dense.shape[1])
            out_vars = svars + tuple(msg.dvars)
        if stats is not None:
            stats.joins += 1
            stats.rows_scanned += n_edges
        return out, out_vars

    def _node_message(self, db: RelationalDB, node: NodeSpec,
                      stats: Optional[CostStats]) -> _SparseMsg:
        code, ds = self._entity_code(db, node.own)
        dense: Optional[jnp.ndarray] = None
        dvars: Tuple[CtVar, ...] = ()
        for hop in node.hops:
            child = self._node_message(db, hop.child_node, stats)
            h, hvars = self._hop(db, hop, child, stats)
            if dense is None:
                dense, dvars = h, hvars
            else:
                n, d = dense.shape
                dense = (dense[:, :, None] * h[:, None, :]).reshape(
                    n, d * h.shape[1])
                dvars = dvars + hvars
        return _SparseMsg(code, ds, tuple(node.own.attrs), dense, dvars)

    def _reduce_by_code(self, code: Optional[jnp.ndarray], ds: int, n: int,
                        factors: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """``out[c, :] = sum_{i: code[i]=c} ⊗_f factors[f][i, :]`` —
        the root combine as one segment-sum (chunked when the Khatri-Rao
        expansion would not fit)."""
        if code is None:
            code = jnp.zeros((n,), dtype=jnp.int32)
        if not factors:
            return jax.ops.segment_sum(
                jnp.ones((n,), dtype=self.dtype), code, num_segments=ds)
        if len(factors) == 1:
            return jax.ops.segment_sum(factors[0], code,
                                       num_segments=ds).reshape(-1)
        d_prod = int(np.prod([f.shape[1] for f in factors], dtype=np.int64))
        chunk = max(64, min(n, _MAX_CHUNK_CELLS // max(d_prod, 1)))
        out = jnp.zeros((ds, d_prod), dtype=self.dtype)
        for s in range(0, n, chunk):
            kr = factors[0][s:s + chunk]
            for f in factors[1:]:
                blk = f[s:s + chunk]
                kr = (kr[:, :, None] * blk[:, None, :]).reshape(
                    kr.shape[0], -1)
            out = out + jax.ops.segment_sum(kr, code[s:s + chunk],
                                            num_segments=ds)
        return out.reshape(-1)

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        child = self._node_message(db, hop.child_node, stats)
        return self._hop(db, hop, child, stats)

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        fs = FactorSpec(var, tuple(attrs))
        code, ds = self._entity_code(db, fs)
        n = db.entities[var.etype].size
        flat = self._reduce_by_code(code, ds, n, ())
        if not fs.attrs:
            return CtTable((), flat[0])
        return CtTable(fs.attrs, flat.reshape(tuple(v.card for v in fs.attrs)))

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        fs = FactorSpec(child, tuple(child_attrs))
        leaf = NodeSpec(fs, (), fs.attrs)
        hop = HopSpec(atom, child, parent, tuple(edge_attrs), leaf,
                      fs.attrs + tuple(edge_attrs))
        return self.hop_message(db, hop, stats)

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        code, ds = self._entity_code(db, own)
        n = db.entities[own.var.etype].size
        mvars: List[CtVar] = list(own.attrs)
        mats: List[jnp.ndarray] = []
        for m, vs in factors:
            mats.append(m)
            mvars.extend(vs)
        flat = self._reduce_by_code(code, ds, n, mats)
        return _finalise(flat, mvars, keep, stats)


EXECUTORS = {"dense": DenseExecutor, "sparse": SparseExecutor}


def make_executor(name, **kw) -> Executor:
    """Resolve an executor by name (or pass an instance through)."""
    if isinstance(name, Executor):
        return name
    return EXECUTORS[name.lower()](**kw)
