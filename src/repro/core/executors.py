"""Executors: pluggable backends that evaluate contraction plans.

The planner (:mod:`repro.core.plan`) fixes the traversal; an executor picks
the message representation:

* :class:`DenseExecutor` — the one-hot path: per-variable one-hot attribute
  encodings, per-relationship ``gather → (outer) multiply → segment_sum``
  hops, chunked Khatri-Rao reduction at the root.  Every hop costs
  O(edges × D) multiply-accumulates and materialises (n, D) messages — MXU
  friendly, but the Eq. (3) blowup is paid in *entities × D*.

* :class:`SparseExecutor` — the code path: attribute combinations are
  mixed-radix ``int32`` codes, never one-hot.  A leaf hop is a single
  ``jax.ops.segment_sum`` of ones over flattened ``(parent, code)`` keys —
  O(nnz) scatter-adds over the raw edge list with no per-entity one-hot
  materialisation — and the root combine segment-sums child messages by the
  root's own code.  Positive ct-tables therefore scale in ``nnz`` rather
  than ``entities × D``, which is what makes the paper's
  VisualGenome-scale configuration reachable.

Both executors expose the same interface (``positive`` / ``hist`` /
``leaf_hop`` / ``root_reduce`` / ``mobius``) so strategies, the Möbius join
and the tuple-ID variant are executor-agnostic.  The negative-phase step
(``mobius``) defaults to the pure-jnp superset transform and can be wired
to the Pallas kernel (``kernels/mobius_kernel.py``) with
``use_pallas_mobius=True``.
"""

from __future__ import annotations

from contextlib import nullcontext
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.profile import annotate
from ..obs.trace import NULL_TRACER
from .contract import CostStats, _khatri_rao_reduce, _onehot
from .ct import CtTable
from .database import RelationalDB
from .plan import ContractionPlan, FactorSpec, HopSpec, NodeSpec
from .variables import Atom, CtVar, Var

_MAX_CHUNK_CELLS = 32_000_000
_INT32_LIMIT = 2 ** 31 - 1


def project_columns(m: jnp.ndarray, mvars: Tuple[CtVar, ...],
                    keep: Sequence[CtVar]
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
    """Marginalise the column axes of an entity-indexed message matrix
    ``(n, prod cards(mvars))`` onto the vars present in ``keep``."""
    want = tuple(v for v in mvars if v in keep)
    if want == tuple(mvars):
        return m, tuple(mvars)
    wide = m.reshape((m.shape[0],) + tuple(v.card for v in mvars))
    dropped = tuple(i + 1 for i, v in enumerate(mvars) if v not in keep)
    if dropped:
        wide = jnp.sum(wide, axis=dropped)
    return wide.reshape(m.shape[0], -1), want


def _finalise_layout(plan: "ContractionPlan", fvars: Sequence[CtVar]
                     ) -> Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
    """The static ``(table shape, transpose perm)`` that :func:`_finalise`
    would apply to a plan's flat result — precomputable, so stacked
    execution can fuse reshape+transpose into the jitted dispatch.
    ``None`` when the request order is not a permutation of the flat vars
    (then the host-side :func:`_finalise` must handle it)."""
    fvars = tuple(fvars)
    order = tuple(v for v in plan.keep if v in fvars)
    if set(order) != set(fvars) or len(order) != len(fvars):
        return None
    shape = tuple(v.card for v in fvars)
    perm = tuple(fvars.index(v) for v in order)
    return shape, perm


def _finalise(flat: jnp.ndarray, mvars: Sequence[CtVar],
              keep: Sequence[CtVar], stats: Optional[CostStats]) -> CtTable:
    mvars = tuple(mvars)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars \
        else flat.reshape(())
    tab = CtTable(mvars, counts)
    order = tuple(v for v in keep if v in tab.vars)
    if order != tab.vars:
        tab = tab.transpose_to(order)
    if stats is not None:
        stats.ct_cells += tab.size
    return tab


class Executor:
    """Backend interface: evaluate plans against a database."""

    name = "base"

    def __init__(self, dtype=jnp.float32, mobius_fn=None,
                 use_pallas_mobius: bool = False):
        self.dtype = dtype
        if mobius_fn is None and use_pallas_mobius:
            from ..kernels.ops import mobius_nd
            mobius_fn = mobius_nd
        self._mobius_fn = mobius_fn
        # (stack key, padded batch) -> (db, jitted vmapped evaluator)
        self._batch_cache: dict = {}
        # request tracer for jit-dispatch spans (NULL_TRACER is free); a
        # real one is wired in by CountingService.set_tracer
        self.tracer = NULL_TRACER

    # -- negative phase -----------------------------------------------------
    def mobius(self, stack: jnp.ndarray, k: int) -> jnp.ndarray:
        """Superset Möbius transform over the leading ``k`` binary axes —
        the Möbius join's butterfly step."""
        if self._mobius_fn is not None:
            return self._mobius_fn(stack, k)
        from .mobius import superset_mobius
        return superset_mobius(stack, k)

    def mobius_batch(self, stacks: Sequence[jnp.ndarray],
                     k: int) -> List[jnp.ndarray]:
        """Batched negative phase: one jitted transform over MANY same-shape
        butterfly stacks.

        The stacks are stacked along a new batch axis which is then moved
        to the trailing (attribute) side, so the single-stack step
        (:meth:`mobius` — the Pallas kernel under ``use_pallas_mobius``,
        the pure-jnp mirror otherwise) runs once over the widened
        attribute space; one dispatch replaces ``len(stacks)``.  The batch
        axis is padded to the next power of two (padding replays the first
        stack) so the jit cache is keyed by a handful of sizes, and the
        traced evaluator is kept in ``_batch_cache`` like the stacked
        positive path.  Results are bit-identical to per-stack
        :meth:`mobius` (the transform is elementwise across the batch
        axis).

        Args:
            stacks: same-shape arrays, each ``(2,)*k + attr_shape``.
            k: number of leading indicator axes.

        Returns:
            One transformed array per input, in input order.

        Usage::

            outs = executor.mobius_batch(stacks, k)
        """
        stacks = list(stacks)
        if not stacks:
            return []
        if len(stacks) == 1:
            return [self.mobius(stacks[0], k)]
        shape = tuple(stacks[0].shape)
        b = len(stacks)
        b_pad = 1 << max(b - 1, 0).bit_length()
        key = ("mobius_batch", shape, k, b_pad)
        fn = self._batch_cache.get(key)
        if fn is None:
            from .mobius import trailing_batch_transform

            def run(batch):
                return trailing_batch_transform(batch, k, self.mobius)

            fn = self._batch_cache[key] = jax.jit(run)
        batch = jnp.stack(stacks + [stacks[0]] * (b_pad - b))
        with self.tracer.span("exec.mobius_batch", stacks=b, k=k,
                              b_pad=b_pad), \
                annotate("exec.mobius_batch"):
            out = fn(batch)
        return [out[i] for i in range(b)]

    def mobius_batch_fused(self, block_lists: Sequence[Sequence[jnp.ndarray]],
                           k: int, perm: Tuple[int, ...]
                           ) -> List[jnp.ndarray]:
        """FULLY fused batched negative phase: butterfly-stack assembly,
        superset transform and the finalise transpose for many same-shape
        queries in ONE jitted dispatch per ``(shape, perm)`` group.

        :meth:`mobius_batch` still paid per-query eager glue — a
        ``jnp.stack`` + reshape to assemble each query's butterfly stack
        and a ``jnp.transpose`` to the request layout afterwards.  Here
        the raw aligned blocks go straight into the jitted evaluator: it
        stacks ALL queries' blocks, runs the transform with the batch
        axis moved to the trailing (elementwise) side, applies the shared
        final transpose, and returns one array per query — per-query
        results are sliced *inside* the jit, so the whole group is a
        single dispatch.  Padding (batch axis to the next power of two,
        replaying the first query) keeps the jit cache keyed by a handful
        of sizes.  Results are bit-identical to the unfused path (the
        transform is elementwise across the batch axis; no op reordering
        occurs).

        Args:
            block_lists: one sequence of ``2**k`` aligned blocks per
                query, each of the same attr shape, in the
                ``itertools.product((0, 1), repeat=k)`` order the
                butterfly stack is built in.
            k: number of leading indicator axes.
            perm: the finalise transpose from transform layout
                (``(2,)*k`` + attr axes) to request layout — shared by
                the whole group.

        Returns:
            One complete-table array per query (request layout), in input
            order.

        Usage::

            outs = executor.mobius_batch_fused(blocks, k, bp.perm)
        """
        block_lists = [list(bs) for bs in block_lists]
        if not block_lists:
            return []
        attr_shape = tuple(block_lists[0][0].shape)
        b = len(block_lists)
        b_pad = 1 << max(b - 1, 0).bit_length()
        perm = tuple(perm)
        key = ("mobius_fused", attr_shape, k, perm, b_pad)
        fn = self._batch_cache.get(key)
        if fn is None:
            tperm = (0,) + tuple(p + 1 for p in perm)

            def run(*blks):
                x = jnp.stack(blks).reshape(
                    (b_pad,) + (2,) * k + attr_shape)
                moved = jnp.moveaxis(x, 0, -1)           # batch -> trailing
                y = jnp.moveaxis(self.mobius(moved, k), -1, 0)
                if tperm != tuple(range(len(tperm))):
                    y = jnp.transpose(y, tperm)
                return tuple(y[i] for i in range(b_pad))

            fn = self._batch_cache[key] = jax.jit(run)
        flat = [blk for bs in block_lists for blk in bs]
        for bs in [block_lists[0]] * (b_pad - b):        # pad: replay query 0
            flat.extend(bs)
        with self.tracer.span("exec.mobius_batch_fused", stacks=b, k=k,
                              b_pad=b_pad), \
                annotate("exec.mobius_batch_fused"):
            outs = fn(*flat)
        return list(outs[:b])

    def local_mode(self):
        """Context for tiny side computations — the engine's delta count
        maintenance runs its delta-edge contractions inside it.  The
        single-device executors are already local (no-op); mesh-sharded
        backends drop to their single-device primitives so a handful of
        delta edges never pays padding + collectives (see
        :meth:`repro.core.distributed.ShardedSparseExecutor.local_mode`).
        """
        return nullcontext()

    # -- positive phase -----------------------------------------------------
    def positive(self, db: RelationalDB, plan: ContractionPlan,
                 stats: Optional[CostStats] = None) -> CtTable:
        """Evaluate a compiled plan: one message per root hop, then the
        root combine.  Backends only implement the two primitives."""
        factors = [self.hop_message(db, hop, stats) for hop in plan.root.hops]
        return self.root_reduce(db, plan.root.own, factors, plan.keep, stats)

    # -- batched positive phase (serve-layer entry point) -------------------
    def positive_batch(self, db: RelationalDB,
                       plans: Sequence[ContractionPlan],
                       stats: Optional[CostStats] = None,
                       min_stack: int = 2) -> List[CtTable]:
        """Evaluate many compiled plans at once.

        Plans whose computations are structurally identical (equal
        :func:`plan_stack_key` — same hop-tree topology, array sizes and
        axis cards) have their input arrays stacked along a new batch axis
        and run through ONE jitted+vmapped evaluation; groups smaller than
        ``min_stack`` (and backends without a traced evaluator) fall back
        to :meth:`positive` per plan.

        Args:
            db: the database the plans were compiled against.
            plans: compiled :class:`~repro.core.plan.ContractionPlan`
                sequence (any mix of signatures).
            stats: optional :class:`~repro.core.contract.CostStats`; join
                and row accounting matches the unbatched path exactly.
            min_stack: smallest group worth tracing a stacked evaluator
                for.

        Returns:
            One :class:`~repro.core.ct.CtTable` per plan, positionally
            aligned with ``plans`` and numerically identical to the
            unbatched path (counts are integer-valued, so the op
            reordering is exact).

        Usage::

            tabs = executor.positive_batch(db, plans)
        """
        results: List[Optional[CtTable]] = [None] * len(plans)
        groups: "dict" = {}
        for i, plan in enumerate(plans):
            groups.setdefault(plan_stack_key(db, plan), []).append(i)
        for idxs in groups.values():
            members = [plans[i] for i in idxs]
            tabs = None
            if len(members) >= min_stack:
                try:
                    tabs = self._positive_stacked(db, members, stats)
                except NotImplementedError:
                    tabs = None
            if tabs is None:
                tabs = [self.positive(db, p, stats) for p in members]
            for i, t in zip(idxs, tabs):
                results[i] = t
        return results

    def _positive_stacked(self, db: RelationalDB,
                          plans: Sequence[ContractionPlan],
                          stats: Optional[CostStats]) -> List[CtTable]:
        """One vmapped execution of stack-compatible plans.  The batch axis
        is padded to the next power of two (padding replays the first plan)
        so the jit cache is keyed by a handful of sizes, not every flood
        length seen.  The stacked device inputs are cached per (store
        version, plan list): a repeated flood over an unchanged store
        re-dispatches without re-staging a single host byte — any write
        bumps ``db.version`` and naturally misses."""
        template = plans[0]
        b = len(plans)
        b_pad = 1 << max(b - 1, 0).bit_length()
        stacked = self._staged_inputs(db, plans, b_pad)
        # finalise (reshape to table shape + transpose to request order) is
        # fused INTO the jitted dispatch when every plan in the group
        # shares the template's layout — the flood case — killing two
        # eager dispatches per plan per shard; mixed-layout groups fall
        # back to host-side finalise
        t_layout = _finalise_layout(template, self._flat_vars(template))
        fused = t_layout is not None and all(
            _finalise_layout(p, self._flat_vars(p)) == t_layout
            for p in plans[1:])
        fn = self._stacked_fn(db, template, b_pad,
                              t_layout if fused else None)
        with self.tracer.span("exec.positive_batch", plans=b, b_pad=b_pad,
                              fused=fused), \
                annotate("exec.positive_batch"):
            rows = fn(*stacked)                   # drops the pad rows
        out: List[CtTable] = []
        for plan, row in zip(plans, rows):
            if fused:
                fvars = self._flat_vars(plan)
                out_vars = tuple(fvars[i] for i in t_layout[1])
                out.append(CtTable(out_vars, row))
                if stats is not None:
                    stats.ct_cells += int(np.prod(t_layout[0],
                                                  dtype=np.int64))
            else:
                out.append(_finalise(row, self._flat_vars(plan), plan.keep,
                                     stats))
            if stats is not None:
                _count_plan_joins(db, plan, stats)
        return out

    def _staged_inputs(self, db: RelationalDB,
                       plans: Sequence[ContractionPlan],
                       b_pad: int) -> Tuple[jnp.ndarray, ...]:
        """The plans' input packs stacked on device, batch axis padded to
        ``b_pad`` by replaying plan 0 — cached per (db, store version,
        plan list).  Plans come out of ``compile_plan_cached``, so
        identical queries hand back the SAME plan objects — id() keys
        hash as plain ints (the structural plan key costs more to hash
        than the staging saves) and the cached entry pins the plan list
        so no id is ever reused while its key is live.  ``id(db)`` is in
        the key because shard databases SHARE plan objects (one schema,
        one compile cache) and may share version counters."""
        in_key = ("stacked_inputs", id(db), db.version,
                  tuple(id(p) for p in plans), b_pad)
        hit = self._batch_cache.get(in_key)
        if hit is not None and hit[0] is db:
            return hit[2]
        packs = [plan_input_arrays(db, p) for p in plans]
        packs = packs + [packs[0]] * (b_pad - len(plans))
        stacked = tuple(jnp.asarray(np.stack([p[j] for p in packs]))
                        for j in range(len(packs[0])))
        self._trim_input_cache()
        self._batch_cache[in_key] = (db, list(plans), stacked)
        return stacked

    _MAX_INPUT_CACHE = 128

    def _trim_input_cache(self) -> None:
        """Bound the staged-input entries in ``_batch_cache`` (jitted fns
        are tiny and stay; staged input stacks hold device memory)."""
        staged = [k for k in self._batch_cache
                  if isinstance(k, tuple) and k
                  and k[0] in ("stacked_inputs", "fanout_inputs",
                               "multi_inputs")]
        while len(staged) >= self._MAX_INPUT_CACHE:
            self._batch_cache.pop(staged.pop(0), None)

    def _stacked_fn(self, db: RelationalDB, template: ContractionPlan,
                    b_pad: int, layout=None):
        key = (plan_stack_key(db, template), b_pad, layout)
        hit = self._batch_cache.get(key)
        if hit is not None and hit[0] is db:
            return hit[1]

        def one(*arrays):
            cur = _ArrayCursor(arrays)
            flat = self._flat_from_arrays(db, template, cur)
            assert cur.exhausted, "plan evaluator out of sync with inputs"
            if layout is None:
                return flat
            shape, perm = layout          # fused finalise (see caller)
            y = flat.reshape(shape)
            if perm != tuple(range(len(perm))):
                y = jnp.transpose(y, perm)
            return y

        vm = jax.vmap(one)

        def run(*arrays):
            y = vm(*arrays)
            # per-plan results sliced INSIDE the jit: callers get a tuple
            # of ready tables, not b eager gather dispatches
            return tuple(y[i] for i in range(b_pad))

        fn = jax.jit(run)
        self._batch_cache[key] = (db, fn)
        return fn

    # -- cross-database stacked evaluation (multi-tenant serve path) --------
    def positive_batch_multi(self, dbs: Sequence[RelationalDB],
                             plans: Sequence[ContractionPlan],
                             stats_list: Optional[Sequence[
                                 Optional[CostStats]]] = None,
                             min_stack: int = 2) -> List[CtTable]:
        """:meth:`positive_batch` across MANY databases: item ``i`` is
        ``plans[i]`` evaluated against ``dbs[i]``.

        The traced evaluator reads only the plan's input arrays — the
        database supplies static metadata (sizes, cards) that
        :func:`plan_stack_key` captures — so rows from *different*
        databases with equal stack keys stack into the SAME jitted
        dispatch.  This is what makes a shared multi-tenant fleet faster
        than N isolated services: same-shape plans from different tenants
        ride one trace.

        Args:
            dbs: one database per plan (repeats allowed and common).
            plans: compiled plans, positionally paired with ``dbs``.
            stats_list: optional per-item
                :class:`~repro.core.contract.CostStats` (typically each
                tenant engine's); accounting matches each database
                running its own plans.
            min_stack: smallest group worth tracing a stacked evaluator
                for.

        Returns:
            One :class:`~repro.core.ct.CtTable` per item, positionally
            aligned and numerically identical to evaluating each
            ``(db, plan)`` pair alone.

        Usage::

            tabs = executor.positive_batch_multi(dbs, plans)
        """
        results: List[Optional[CtTable]] = [None] * len(plans)
        groups: "dict" = {}
        for i, (db, plan) in enumerate(zip(dbs, plans)):
            groups.setdefault(plan_stack_key(db, plan), []).append(i)
        for idxs in groups.values():
            g_dbs = [dbs[i] for i in idxs]
            g_plans = [plans[i] for i in idxs]
            g_stats = [stats_list[i] if stats_list is not None else None
                       for i in idxs]
            tabs = None
            if len(idxs) >= min_stack:
                try:
                    tabs = self._positive_stacked_multi(g_dbs, g_plans,
                                                        g_stats)
                except NotImplementedError:
                    tabs = None
            if tabs is None:
                tabs = [self.positive(d, p, s)
                        for d, p, s in zip(g_dbs, g_plans, g_stats)]
            for i, t in zip(idxs, tabs):
                results[i] = t
        return results

    def _positive_stacked_multi(self, dbs: Sequence[RelationalDB],
                                plans: Sequence[ContractionPlan],
                                stats_list: Sequence[Optional[CostStats]]
                                ) -> List[CtTable]:
        """One vmapped execution of stack-compatible ``(db, plan)`` rows.
        The jitted evaluator is the same one :meth:`_positive_stacked`
        uses (traced against the group's first database — valid for every
        member because equal stack keys pin all static metadata); only the
        input staging differs, pulling each row's arrays from its own
        database."""
        template = plans[0]
        b = len(plans)
        b_pad = 1 << max(b - 1, 0).bit_length()
        stacked = self._staged_inputs_multi(dbs, plans, b_pad)
        t_layout = _finalise_layout(template, self._flat_vars(template))
        fused = t_layout is not None and all(
            _finalise_layout(p, self._flat_vars(p)) == t_layout
            for p in plans[1:])
        fn = self._stacked_fn(dbs[0], template, b_pad,
                              t_layout if fused else None)
        with self.tracer.span("exec.positive_batch_multi", plans=b,
                              b_pad=b_pad, fused=fused,
                              dbs=len({id(d) for d in dbs})), \
                annotate("exec.positive_batch_multi"):
            rows = fn(*stacked)
        out: List[CtTable] = []
        for db, plan, row, stats in zip(dbs, plans, rows, stats_list):
            if fused:
                fvars = self._flat_vars(plan)
                out_vars = tuple(fvars[i] for i in t_layout[1])
                out.append(CtTable(out_vars, row))
                if stats is not None:
                    stats.ct_cells += int(np.prod(t_layout[0],
                                                  dtype=np.int64))
            else:
                out.append(_finalise(row, self._flat_vars(plan), plan.keep,
                                     stats))
            if stats is not None:
                _count_plan_joins(db, plan, stats)
        return out

    def _staged_inputs_multi(self, dbs: Sequence[RelationalDB],
                             plans: Sequence[ContractionPlan],
                             b_pad: int) -> Tuple[jnp.ndarray, ...]:
        """Per-row input packs stacked on device, each row staged from its
        own database — cached per (db ids, store versions, plan list) like
        the fan-out path, so a repeated multi-tenant flood over unchanged
        stores re-dispatches without re-staging a host byte."""
        in_key = ("multi_inputs", tuple(id(db) for db in dbs),
                  tuple(db.version for db in dbs),
                  tuple(id(p) for p in plans), b_pad)
        hit = self._batch_cache.get(in_key)
        if hit is not None and all(a is b for a, b in zip(hit[0], dbs)):
            return hit[2]
        packs = [plan_input_arrays(db, p) for db, p in zip(dbs, plans)]
        packs = packs + [packs[0]] * (b_pad - len(plans))
        stacked = tuple(jnp.asarray(np.stack([p[j] for p in packs]))
                        for j in range(len(packs[0])))
        self._trim_input_cache()
        self._batch_cache[in_key] = (list(dbs), list(plans), stacked)
        return stacked

    # -- cross-shard fused evaluation (router flood path) -------------------
    def stacked_layout(self, plan: ContractionPlan):
        """Fused finalise layout of one plan — ``(shape, perm)`` when the
        flat counts can be reshaped + transposed to the request order
        inside the jit, ``None`` otherwise (see :func:`_finalise_layout`).
        Raises ``NotImplementedError`` for backends without a traced
        evaluator."""
        return _finalise_layout(plan, self._flat_vars(plan))

    def positive_stacked_merged(self, dbs: Sequence[RelationalDB],
                                executors: Sequence["Executor"],
                                plans: Sequence[ContractionPlan],
                                stats_list: Optional[Sequence[
                                    Optional[CostStats]]] = None
                                ) -> Tuple[List[List[CtTable]],
                                           List[CtTable]]:
        """ONE jitted dispatch for a whole cross-shard flood group: every
        shard's stacked input pack is evaluated under the same trace and
        the per-plan tables are summed over the shard axis inside the jit
        — the per-shard tables (for the shard services' caches) and the
        merged tables (for the router) come back from the same call, so a
        2-shard flood costs one dispatch instead of two shard dispatches
        plus a merge dispatch.

        The caller (``CountingRouter._flush_fused``) must pre-check
        feasibility: the SAME plan objects on every shard, equal
        :func:`plan_stack_key` per plan across all shard databases (entity
        tables are replicated and edge arrays pad to shared pow2 buckets,
        so this is the common case), and one shared non-``None``
        :meth:`stacked_layout` across the group's plans.

        Args:
            dbs: one shard database per shard.
            executors: the shard executors (staging caches stay per
                shard); ``self`` compiles and owns the fused function.
            plans: the group's plans (identical objects on every shard).
            stats_list: per-shard :class:`~repro.core.contract.CostStats`;
                accounting matches each shard running the plans itself.

        Returns:
            ``(per_shard, merged)`` — ``per_shard[s][q]`` is shard ``s``'s
            table for plan ``q``; ``merged[q]`` is their exact sum.
        """
        template = plans[0]
        m = len(plans)
        b_pad = 1 << max(m - 1, 0).bit_length()
        layout = self.stacked_layout(template)
        staged = [ex._staged_inputs(db, plans, b_pad)
                  for ex, db in zip(executors, dbs)]
        k = len(staged[0])
        fn = self._fused_stacked_fn(dbs[0], template, b_pad, len(dbs), k,
                                    layout)
        flat = fn(*(a for pack in staged for a in pack))
        cells = int(np.prod(layout[0], dtype=np.int64))
        out_vars: List[Tuple[CtVar, ...]] = []
        for p in plans:
            fvars = self._flat_vars(p)
            out_vars.append(tuple(fvars[i] for i in layout[1]))
        merged = [CtTable(out_vars[q], flat[q]) for q in range(m)]
        per_shard: List[List[CtTable]] = []
        for s in range(len(dbs)):
            rows = flat[b_pad + s * b_pad:b_pad + (s + 1) * b_pad]
            per_shard.append([CtTable(out_vars[q], rows[q])
                              for q in range(m)])
            stats = stats_list[s] if stats_list is not None else None
            if stats is not None:
                stats.ct_cells += cells * m
                for p in plans:
                    _count_plan_joins(dbs[s], p, stats)
        return per_shard, merged

    def positive_fanout_merged(self, dbs: Sequence[RelationalDB],
                               plans: Sequence[ContractionPlan],
                               partitioned: frozenset,
                               stats_list: Optional[Sequence[
                                   Optional[CostStats]]] = None
                               ) -> List[CtTable]:
        """Merged fan-out tables at SINGLE-DATABASE cost: instead of
        evaluating every shard separately and summing tables (which
        materialises ``n_shards`` full segment spaces), the shards' input
        arrays are reassembled into the unsharded database's arrays
        (:func:`fanout_input_arrays`) and evaluated once — the answer IS
        the merged table, by the same argument that makes the fan-out sum
        exact (every partitioned edge lives on exactly one shard;
        replicated tables are identical everywhere).

        The caller must pre-check: a routable fan-out plan group with one
        shared non-``None`` :meth:`stacked_layout` and equal
        :func:`fanout_stack_key`.  ``self`` is the front-end's compiling
        executor (shard 0's); reassembled input stacks are cached per
        (shard dbs, store versions, plan list) so a repeated flood
        re-dispatches without touching a host byte.

        Returns one merged :class:`~repro.core.ct.CtTable` per plan.
        """
        template = plans[0]
        m = len(plans)
        b_pad = 1 << max(m - 1, 0).bit_length()
        layout = self.stacked_layout(template)
        in_key = ("fanout_inputs", tuple(id(db) for db in dbs),
                  tuple(db.version for db in dbs),
                  tuple(id(p) for p in plans), b_pad)
        hit = self._batch_cache.get(in_key)
        if hit is not None and all(a is b for a, b in zip(hit[0], dbs)):
            stacked = hit[3]
        else:
            packs = [fanout_input_arrays(dbs, p, partitioned)
                     for p in plans]
            packs = packs + [packs[0]] * (b_pad - m)
            stacked = tuple(jnp.asarray(np.stack([p[j] for p in packs]))
                            for j in range(len(packs[0])))
            self._trim_input_cache()
            self._batch_cache[in_key] = (tuple(dbs), None, list(plans),
                                         stacked)
        # the single-db stacked evaluator retraces on the reassembled
        # array shapes and is correct as-is: its only database inputs are
        # replicated static metadata (entity sizes, cards)
        fn = self._stacked_fn(dbs[0], template, b_pad, layout)
        rows = fn(*stacked)
        out: List[CtTable] = []
        for q, p in enumerate(plans):
            fvars = self._flat_vars(p)
            out.append(CtTable(tuple(fvars[i] for i in layout[1]),
                               rows[q]))
        if stats_list:
            for db, stats in zip(dbs, stats_list):
                if stats is not None:
                    for p in plans:
                        _count_plan_joins(db, p, stats)
            if stats_list[0] is not None:
                stats_list[0].ct_cells += m * int(
                    np.prod(layout[0], dtype=np.int64))
        return out

    def _fused_stacked_fn(self, db0: RelationalDB,
                          template: ContractionPlan, b_pad: int,
                          n_shards: int, k: int, layout):
        """The jitted cross-shard evaluator behind
        :meth:`positive_stacked_merged`: args are shard-major input packs
        (``k`` arrays per shard); returns ``b_pad`` merged rows followed
        by ``n_shards * b_pad`` per-shard rows, all sliced inside the
        jit.  Traced against shard 0's database — equal stack keys
        guarantee the static metadata (entity sizes, cards, bucketed edge
        lengths) matches every shard."""
        key = ("fused_stacked", plan_stack_key(db0, template), b_pad,
               n_shards, k, layout)
        hit = self._batch_cache.get(key)
        if hit is not None and hit[0] is db0:
            return hit[1]
        shape, perm = layout

        def one(*arrays):
            cur = _ArrayCursor(arrays)
            flat = self._flat_from_arrays(db0, template, cur)
            assert cur.exhausted, "plan evaluator out of sync with inputs"
            y = flat.reshape(shape)
            if perm != tuple(range(len(perm))):
                y = jnp.transpose(y, perm)
            return y

        vm = jax.vmap(one)

        def run(*all_arrays):
            outs = [vm(*all_arrays[s * k:(s + 1) * k])
                    for s in range(n_shards)]
            merged = outs[0]
            for o in outs[1:]:
                merged = merged + o
            rows = [merged[q] for q in range(b_pad)]
            for s in range(n_shards):
                rows.extend(outs[s][q] for q in range(b_pad))
            return tuple(rows)

        fn = jax.jit(run)
        self._batch_cache[key] = (db0, fn)
        return fn

    def _flat_from_arrays(self, db: RelationalDB, plan: ContractionPlan,
                          cur: "_ArrayCursor") -> jnp.ndarray:
        """Traced single-plan evaluation over an input-array pack (see
        :func:`plan_input_arrays`); returns the flat counts in
        ``_flat_vars(plan)`` axis order.  Backends that implement this get
        stacked execution for free."""
        raise NotImplementedError

    def _flat_vars(self, plan: ContractionPlan) -> Tuple[CtVar, ...]:
        """Axis order of :meth:`_flat_from_arrays` output."""
        raise NotImplementedError

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Full message matrix ``(n_parent, D)`` of one root-adjacent hop,
        including the child's entire subtree."""
        raise NotImplementedError

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        raise NotImplementedError

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Message matrix ``(n_parent, D)`` a bare child variable sends
        through one relationship — the tuple-ID precompute primitive."""
        raise NotImplementedError

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        """Combine the root variable's own attributes with entity-indexed
        factor matrices ``(n_root, D_i)`` into a ct-table."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# shared edge-list bookkeeping
# ---------------------------------------------------------------------------

def _hop_indices(db: RelationalDB, atom: Atom, child: Var, parent: Var):
    rt = db.relations[atom.rel]
    if child == atom.src and parent == atom.dst:
        return rt, rt.src, rt.dst, db.entities[atom.dst.etype].size
    if child == atom.dst and parent == atom.src:
        return rt, rt.dst, rt.src, db.entities[atom.src.etype].size
    raise AssertionError("atom does not connect child/parent")


# ---------------------------------------------------------------------------
# batched execution plumbing: plans as (static structure, input-array pack)
# ---------------------------------------------------------------------------

class _ArrayCursor:
    """Sequential reader over a plan's flattened input-array pack.  The
    collection (:func:`plan_input_arrays`) and consumption
    (``_flat_from_arrays``) sides share one traversal order: per node its
    kept attribute columns, then per hop the child subtree (recursively),
    the gather index, the scatter index, and the kept edge-attr columns."""

    __slots__ = ("arrays", "i")

    def __init__(self, arrays: Sequence):
        self.arrays, self.i = arrays, 0

    def take(self):
        a = self.arrays[self.i]
        self.i += 1
        return a

    @property
    def exhausted(self) -> bool:
        return self.i == len(self.arrays)


def _edge_bucket(n: int) -> int:
    """Bucketed edge-array length: the next power of two at or above
    ``n`` (floor 16).  Hash-partitioned shards have *ragged*
    per-relationship edge counts, so keying stacked execution on exact
    counts would put every shard plan in its own group and fall back to
    per-plan eager dispatch; bucketing restores stacking at the cost of
    masked pad rows.  Power-of-two buckets make one group per shard the
    common case (per-dispatch overhead dominates the extra pad rows —
    the segment-sum is linear and memory-bound)."""
    if n <= 0:
        return 0
    return max(16, 1 << max(n - 1, 0).bit_length())


def plan_stack_key(db: RelationalDB, plan: ContractionPlan) -> Tuple:
    """Stacked-execution key: plans with equal keys against the same
    database run the exact same operation sequence on same-shape arrays
    (hop-tree topology + entity sizes + bucketed edge counts + axis
    cards), so their input packs can be stacked and evaluated under one
    ``vmap``.  Edge counts are bucketed (:func:`_edge_bucket`) and the
    packs padded to match — padded edges scatter to segment ``n_parent``,
    one past the last real segment, which ``segment_sum`` drops — so
    plans with nearby edge counts stack exactly."""
    def node(n: NodeSpec) -> Tuple:
        hops = []
        for h in n.hops:
            _, g, _, n_parent = _hop_indices(db, h.atom, h.child, h.parent)
            hops.append((_edge_bucket(int(np.asarray(g).shape[0])), n_parent,
                         tuple(cv.card for cv in h.edge_attrs),
                         node(h.child_node)))
        return (db.entities[n.var.etype].size,
                tuple(cv.card for cv in n.own.attrs), tuple(hops))
    return node(plan.root)


def plan_input_arrays(db: RelationalDB, plan: ContractionPlan
                      ) -> List[np.ndarray]:
    """The plan's data inputs as a flat host-array list in cursor order
    (see :class:`_ArrayCursor`) — everything an executor reads from the
    database, ready to be ``np.stack``-ed across stack-compatible plans.

    Edge arrays are padded to :func:`_edge_bucket` length to match
    :func:`plan_stack_key`: pad gathers read row 0 (any valid row), pad
    scatters target segment ``n_parent`` — out of range, so XLA's scatter
    drops them — and pad edge-attr entries are 0.  The padded evaluation
    is therefore numerically identical to the exact-length one."""
    arrs: List[np.ndarray] = []

    def node(n: NodeSpec) -> None:
        tab = db.entities[n.var.etype]
        for cv in n.own.attrs:
            arrs.append(np.asarray(tab.attrs[cv.owner[1]]))
        for h in n.hops:
            node(h.child_node)
            rt, g, s, n_parent = _hop_indices(db, h.atom, h.child, h.parent)
            g_np, s_np = np.asarray(g), np.asarray(s)
            n_edges = int(g_np.shape[0])
            pad = _edge_bucket(n_edges) - n_edges
            if pad > 0:
                g_np = np.concatenate(
                    [g_np, np.zeros(pad, dtype=g_np.dtype)])
                s_np = np.concatenate(
                    [s_np, np.full(pad, n_parent, dtype=s_np.dtype)])
            arrs.append(g_np)
            arrs.append(s_np)
            for cv in h.edge_attrs:
                col = np.asarray(rt.attrs[cv.owner[1]])
                if pad > 0:
                    col = np.concatenate(
                        [col, np.zeros(pad, dtype=col.dtype)])
                arrs.append(col)

    node(plan.root)
    return arrs


def _plan_input_roles(plan: ContractionPlan,
                      partitioned: frozenset) -> List[bool]:
    """Per input-pack slot (cursor order of :func:`plan_input_arrays`):
    ``True`` when the array belongs to a partitioned relationship's edge
    table, ``False`` for entity-attribute columns and replicated
    relationships' arrays."""
    roles: List[bool] = []

    def node(n: NodeSpec) -> None:
        roles.extend(False for _ in n.own.attrs)
        for h in n.hops:
            node(h.child_node)
            part = h.atom.rel in partitioned
            roles.append(part)             # gather index
            roles.append(part)             # scatter index
            roles.extend(part for _ in h.edge_attrs)

    node(plan.root)
    return roles


def fanout_input_arrays(dbs: Sequence[RelationalDB], plan: ContractionPlan,
                        partitioned: frozenset) -> List[np.ndarray]:
    """The UNSHARDED database's input pack, reassembled from its shards:
    entity-attribute columns and replicated relationship arrays come from
    shard 0 (replicas are identical on every shard), partitioned
    relationship arrays are the shards' arrays concatenated (every edge
    lives on exactly one shard, so the concatenation is the full edge
    table; per-shard pad rows scatter out of range and stay inert).
    Evaluating a routable fan-out plan on this pack therefore yields the
    MERGED table directly — same correctness argument as the fan-out sum,
    one segment space instead of ``n_shards``."""
    packs = [plan_input_arrays(db, plan) for db in dbs]
    roles = _plan_input_roles(plan, partitioned)
    return [np.concatenate(arrs) if part else arrs[0]
            for part, arrs in zip(roles, zip(*packs))]


def fanout_stack_key(dbs: Sequence[RelationalDB], plan: ContractionPlan,
                     partitioned: frozenset) -> Tuple:
    """Stacking key of the reassembled fan-out evaluation
    (:func:`fanout_input_arrays`): like :func:`plan_stack_key` but with
    each partitioned relationship's edge length equal to the SUM of the
    shards' bucketed lengths.  Plans with equal keys share one stacked
    dispatch."""
    def node(n: NodeSpec) -> Tuple:
        hops = []
        for h in n.hops:
            lens = []
            for db in dbs:
                _, g, _, n_parent = _hop_indices(db, h.atom, h.child,
                                                 h.parent)
                lens.append(_edge_bucket(int(np.asarray(g).shape[0])))
            length = sum(lens) if h.atom.rel in partitioned else lens[0]
            hops.append((length, n_parent,
                         tuple(cv.card for cv in h.edge_attrs),
                         node(h.child_node)))
        return (dbs[0].entities[n.var.etype].size,
                tuple(cv.card for cv in n.own.attrs), tuple(hops))
    return node(plan.root)


def _count_plan_joins(db: RelationalDB, plan: ContractionPlan,
                      stats: CostStats) -> None:
    """Mirror the per-hop join accounting of the unbatched path."""
    def node(n: NodeSpec) -> None:
        for h in n.hops:
            node(h.child_node)
            _, g, _, _ = _hop_indices(db, h.atom, h.child, h.parent)
            stats.joins += 1
            stats.rows_scanned += int(np.asarray(g).shape[0])
    node(plan.root)


# ---------------------------------------------------------------------------
# dense executor (one-hot contraction)
# ---------------------------------------------------------------------------

class DenseExecutor(Executor):
    name = "dense"

    def _entity_factor(self, db: RelationalDB, fs: FactorSpec
                       ) -> Tuple[jnp.ndarray, List[CtVar]]:
        tab = db.entities[fs.var.etype]
        msg = jnp.ones((tab.size, 1), dtype=self.dtype)
        mvars: List[CtVar] = []
        for cv in fs.attrs:
            hot = _onehot(jnp.asarray(tab.attrs[cv.owner[1]]), cv.card,
                          self.dtype)
            n, d = msg.shape
            msg = (msg[:, :, None] * hot[:, None, :]).reshape(n, d * cv.card)
            mvars.append(cv)
        return msg, mvars

    def _hop(self, db: RelationalDB, hop: HopSpec, child_msg: jnp.ndarray,
             child_vars: List[CtVar], stats: Optional[CostStats]
             ) -> Tuple[jnp.ndarray, List[CtVar]]:
        rt, gather_idx, scatter_idx, n_parent = _hop_indices(
            db, hop.atom, hop.child, hop.parent)
        m = child_msg[jnp.asarray(gather_idx)]            # (edges, D)
        mvars = list(child_vars)
        for cv in hop.edge_attrs:
            hot = _onehot(jnp.asarray(rt.attrs[cv.owner[1]]), cv.card,
                          self.dtype)                     # card+1, NA empty
            n, d = m.shape
            m = (m[:, :, None] * hot[:, None, :]).reshape(n, d * cv.card)
            mvars.append(cv)
        out = jax.ops.segment_sum(m, jnp.asarray(scatter_idx),
                                  num_segments=n_parent)
        if stats is not None:
            stats.joins += 1
            stats.rows_scanned += int(gather_idx.shape[0])
        return out, mvars

    def _node_message(self, db: RelationalDB, node: NodeSpec,
                      stats: Optional[CostStats]
                      ) -> Tuple[jnp.ndarray, List[CtVar]]:
        msg, mvars = self._entity_factor(db, node.own)
        for hop in node.hops:
            child_msg, child_vars = self._node_message(db, hop.child_node,
                                                       stats)
            h, hvars = self._hop(db, hop, child_msg, child_vars, stats)
            n, d = msg.shape
            msg = (msg[:, :, None] * h[:, None, :]).reshape(n, d * h.shape[1])
            mvars = mvars + hvars
        return msg, mvars

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        child_msg, child_vars = self._node_message(db, hop.child_node, stats)
        m, mvars = self._hop(db, hop, child_msg, child_vars, stats)
        return m, tuple(mvars)

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        msg, mvars = self._entity_factor(db, FactorSpec(var, tuple(attrs)))
        flat = jnp.sum(msg, axis=0)
        counts = flat.reshape(tuple(v.card for v in mvars)) if mvars \
            else flat[0]
        return CtTable(tuple(mvars), counts)

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        fs = FactorSpec(child, tuple(child_attrs))
        leaf = NodeSpec(fs, (), fs.attrs)
        hop = HopSpec(atom, child, parent, tuple(edge_attrs), leaf,
                      fs.attrs + tuple(edge_attrs))
        return self.hop_message(db, hop, stats)

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        fs: List[Tuple[jnp.ndarray, List[CtVar]]] = [
            self._entity_factor(db, own)]
        fs.extend((m, list(vs)) for m, vs in factors)
        flat, mvars = _khatri_rao_reduce(fs)
        return _finalise(flat, mvars, keep, stats)

    # -- traced batched evaluation ------------------------------------------
    def _flat_from_arrays(self, db: RelationalDB, plan: ContractionPlan,
                          cur: _ArrayCursor) -> jnp.ndarray:
        """Mirror of ``_entity_factor``/``_hop``/``_node_message`` +
        ``root_reduce`` reading from an array pack — same op sequence, so
        batched results match the unbatched path exactly."""
        def entity_factor(fs: FactorSpec) -> jnp.ndarray:
            n = db.entities[fs.var.etype].size
            msg = jnp.ones((n, 1), dtype=self.dtype)
            for cv in fs.attrs:
                hot = _onehot(cur.take(), cv.card, self.dtype)
                nn, d = msg.shape
                msg = (msg[:, :, None] * hot[:, None, :]).reshape(
                    nn, d * cv.card)
            return msg

        def hop_from(hop: HopSpec, child_msg: jnp.ndarray) -> jnp.ndarray:
            g, s = cur.take(), cur.take()
            n_parent = db.entities[hop.parent.etype].size
            m = child_msg[g]
            for cv in hop.edge_attrs:
                hot = _onehot(cur.take(), cv.card, self.dtype)
                nn, d = m.shape
                m = (m[:, :, None] * hot[:, None, :]).reshape(nn, d * cv.card)
            return jax.ops.segment_sum(m, s, num_segments=n_parent)

        def node_msg(node: NodeSpec) -> jnp.ndarray:
            msg = entity_factor(node.own)
            for hop in node.hops:
                h = hop_from(hop, node_msg(hop.child_node))
                nn, d = msg.shape
                msg = (msg[:, :, None] * h[:, None, :]).reshape(
                    nn, d * h.shape[1])
            return msg

        factors: List[Tuple[jnp.ndarray, List[CtVar]]] = [
            (entity_factor(plan.root.own), [])]
        for hop in plan.root.hops:
            factors.append((hop_from(hop, node_msg(hop.child_node)), []))
        flat, _ = _khatri_rao_reduce(factors)
        return flat

    def _flat_vars(self, plan: ContractionPlan) -> Tuple[CtVar, ...]:
        # replicate _khatri_rao_reduce's widest-last reorder on var metadata
        fvars = [tuple(plan.root.own.attrs)] + [tuple(h.out_vars)
                                                for h in plan.root.hops]
        widths = [int(np.prod([v.card for v in vs], dtype=np.int64))
                  for vs in fvars]
        widest = max(range(len(fvars)), key=widths.__getitem__)
        order = [i for i in range(len(fvars)) if i != widest] + [widest]
        out: List[CtVar] = []
        for i in order:
            out.extend(fvars[i])
        return tuple(out)


# ---------------------------------------------------------------------------
# sparse executor (int32 codes + segment_sum over edge lists)
# ---------------------------------------------------------------------------

class _SparseMsg:
    """Per-entity message: a mixed-radix scalar code over ``svars`` (one
    value per entity — exact, no one-hot) plus an optional dense block over
    ``dvars`` (present only after an aggregation made the distribution
    genuinely multi-valued)."""

    __slots__ = ("code", "ds", "svars", "dense", "dvars")

    def __init__(self, code, ds, svars, dense, dvars):
        self.code, self.ds, self.svars = code, ds, svars
        self.dense, self.dvars = dense, dvars


def _np_codes(cols: List[np.ndarray], cards: List[int]) -> np.ndarray:
    code = np.zeros(len(cols[0]) if cols else 0, dtype=np.int64)
    for col, card in zip(cols, cards):
        code = code * card + col.astype(np.int64)
    return code


def _kr_segment_sum(code, mats: Sequence[jnp.ndarray], ds: int,
                    dtype) -> jnp.ndarray:
    """Chunked Khatri-Rao expansion + segment-sum accumulation:
    ``out[c, :] = sum_{i: code[i]=c} ⊗_m mats[m][i, :]`` as a ``(ds,
    prod_D)`` table, chunking rows so the expansion never materialises
    more than ``_MAX_CHUNK_CELLS`` cells.  Pure jnp — also traced inside
    the sharded executor's ``shard_map`` body."""
    d_prod = int(np.prod([m.shape[1] for m in mats], dtype=np.int64))
    n = int(mats[0].shape[0])
    chunk = max(64, min(max(n, 1), _MAX_CHUNK_CELLS // max(d_prod, 1)))
    out = jnp.zeros((ds, d_prod), dtype=dtype)
    for s in range(0, n, chunk):
        kr = mats[0][s:s + chunk]
        for m in mats[1:]:
            blk = m[s:s + chunk]
            kr = (kr[:, :, None] * blk[:, None, :]).reshape(kr.shape[0], -1)
        out = out + jax.ops.segment_sum(kr, code[s:s + chunk],
                                        num_segments=ds)
    return out


def _segsum_kernel_enabled(num_segments: int) -> bool:
    """Route this scatter-add through the Pallas segment-sum kernel?
    Thin lazy alias of :func:`repro.kernels.ops.segsum_kernel_enabled`
    so the kernels package (and its Pallas import) stays off the core
    import path until a sparse hop actually consults it."""
    from ..kernels import ops as kernel_ops
    return kernel_ops.segsum_kernel_enabled(num_segments)


class SparseExecutor(Executor):
    name = "sparse"

    def _entity_code(self, db: RelationalDB, fs: FactorSpec
                     ) -> Tuple[Optional[np.ndarray], int]:
        """Mixed-radix host-side code per entity.  Kept as numpy: codes are
        consumed by host index arithmetic in ``_hop``; only the final
        segment-id array ever moves to the device."""
        if not fs.attrs:
            return None, 1
        tab = db.entities[fs.var.etype]
        cols = [np.asarray(tab.attrs[cv.owner[1]]) for cv in fs.attrs]
        code = _np_codes(cols, [cv.card for cv in fs.attrs])
        return code.astype(np.int32), fs.card

    def _hop(self, db: RelationalDB, hop: HopSpec, msg: _SparseMsg,
             stats: Optional[CostStats]
             ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        """Push a child message through one relationship.  Scalar-coded axes
        travel as index arithmetic inside the segment ids; only genuinely
        dense axes (from deeper aggregations) are carried as row vectors."""
        rt, gather_idx, scatter_idx, n_parent = _hop_indices(
            db, hop.atom, hop.child, hop.parent)
        gather_np = np.asarray(gather_idx)
        n_edges = int(gather_np.shape[0])

        # per-edge scalar code: child code gathered at the child end of the
        # edge, extended with this relationship's kept edge attributes
        ds = msg.ds
        if msg.code is not None:
            ecode = msg.code[gather_np].astype(np.int64)
        else:
            ecode = np.zeros(n_edges, dtype=np.int64)
        svars = tuple(msg.svars)
        for cv in hop.edge_attrs:
            ecode = ecode * cv.card + np.asarray(
                rt.attrs[cv.owner[1]]).astype(np.int64)
            ds *= cv.card
            svars = svars + (cv,)

        total = n_parent * ds
        if total > _INT32_LIMIT:
            raise OverflowError(
                f"sparse hop segment space {total} exceeds int32; use the "
                f"dense executor or reduce kept axes")
        seg_np = (np.asarray(scatter_idx).astype(np.int64) * ds
                  + ecode).astype(np.int32)
        if msg.dense is None:
            flat = self._edge_segment_sum(seg_np, None, total)
            out = flat.reshape(n_parent, ds)
            out_vars = svars
        else:
            rows = msg.dense[jnp.asarray(gather_np)]       # (edges, Dd)
            agg = self._edge_segment_sum(seg_np, rows, total)
            out = agg.reshape(n_parent, ds * msg.dense.shape[1])
            out_vars = svars + tuple(msg.dvars)
        if stats is not None:
            stats.joins += 1
            stats.rows_scanned += n_edges
        return out, out_vars

    def _edge_segment_sum(self, seg_np: np.ndarray,
                          rows: Optional[jnp.ndarray],
                          total: int) -> jnp.ndarray:
        """Device step of one sparse hop: scatter-add per-edge contributions
        into the flattened ``(parent, code)`` segment space.  ``rows`` is
        ``None`` for a leaf hop (each edge contributes 1) or the gathered
        dense block ``(edges, Dd)``.  The single-device base runs one
        ``jax.ops.segment_sum``; :class:`~repro.core.distributed
        .ShardedSparseExecutor` overrides this with an edge-sharded
        ``shard_map`` + ``psum``.

        Backend routing: when :func:`repro.kernels.ops
        .segsum_kernel_enabled` says so (accelerator present, or
        ``REPRO_SEGSUM_PALLAS=1`` on CPU CI, and the segment space is
        small enough for the one-hot sweep) the scatter-add runs through
        the Pallas kernel (:mod:`repro.kernels.segsum_kernel`) with
        ``interpret`` resolved by the same backend probe — Mosaic on
        TPU, Triton on GPU, the interpreter on CPU."""
        seg = jnp.asarray(seg_np)
        if _segsum_kernel_enabled(total):
            from ..kernels import ops as kernel_ops
            if rows is None:
                out = kernel_ops.ones_segment_sum(
                    seg, jnp.ones((seg_np.shape[0],), dtype=jnp.float32),
                    total)
            else:
                out = kernel_ops.edge_segment_sum(seg, rows, total)
            return out.astype(self.dtype)
        if rows is None:
            return jax.ops.segment_sum(
                jnp.ones((seg_np.shape[0],), dtype=self.dtype), seg,
                num_segments=total)
        return jax.ops.segment_sum(rows, seg, num_segments=total)

    def _node_message(self, db: RelationalDB, node: NodeSpec,
                      stats: Optional[CostStats]) -> _SparseMsg:
        code, ds = self._entity_code(db, node.own)
        dense: Optional[jnp.ndarray] = None
        dvars: Tuple[CtVar, ...] = ()
        for hop in node.hops:
            child = self._node_message(db, hop.child_node, stats)
            h, hvars = self._hop(db, hop, child, stats)
            if dense is None:
                dense, dvars = h, hvars
            else:
                n, d = dense.shape
                dense = (dense[:, :, None] * h[:, None, :]).reshape(
                    n, d * h.shape[1])
                dvars = dvars + hvars
        return _SparseMsg(code, ds, tuple(node.own.attrs), dense, dvars)

    def _ones_segment_sum(self, code: jnp.ndarray, ds: int) -> jnp.ndarray:
        """Jitted ``segment_sum`` of ones — the histogram primitive.  An
        eager scatter dispatch costs milliseconds on CPU and histograms
        are recomputed on every cache miss, so the compiled kernel is
        cached per ``(n, ds)`` in ``_batch_cache``."""
        n = int(code.shape[0])
        if _segsum_kernel_enabled(ds):
            from ..kernels import ops as kernel_ops
            return kernel_ops.ones_segment_sum(
                code, jnp.ones((n,), dtype=jnp.float32), ds
            ).astype(self.dtype)
        key = ("ones_seg", n, ds)
        fn = self._batch_cache.get(key)
        if fn is None:
            def run(c):
                return jax.ops.segment_sum(
                    jnp.ones((n,), dtype=self.dtype), c, num_segments=ds)

            fn = self._batch_cache[key] = jax.jit(run)
        return fn(code)

    def _reduce_by_code(self, code: Optional[jnp.ndarray], ds: int, n: int,
                        factors: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """``out[c, :] = sum_{i: code[i]=c} ⊗_f factors[f][i, :]`` —
        the root combine as one segment-sum (chunked when the Khatri-Rao
        expansion would not fit)."""
        if code is None:
            code = jnp.zeros((n,), dtype=jnp.int32)
        if not factors:
            return self._ones_segment_sum(jnp.asarray(code), ds)
        if len(factors) == 1:
            return jax.ops.segment_sum(factors[0], code,
                                       num_segments=ds).reshape(-1)
        return _kr_segment_sum(code, factors, ds, self.dtype).reshape(-1)

    def hop_message(self, db: RelationalDB, hop: HopSpec,
                    stats: Optional[CostStats] = None
                    ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        child = self._node_message(db, hop.child_node, stats)
        return self._hop(db, hop, child, stats)

    def hist(self, db: RelationalDB, var: Var, attrs: Tuple[CtVar, ...],
             stats: Optional[CostStats] = None) -> CtTable:
        fs = FactorSpec(var, tuple(attrs))
        code, ds = self._entity_code(db, fs)
        n = db.entities[var.etype].size
        flat = self._reduce_by_code(code, ds, n, ())
        if not fs.attrs:
            return CtTable((), flat[0])
        return CtTable(fs.attrs, flat.reshape(tuple(v.card for v in fs.attrs)))

    def leaf_hop(self, db: RelationalDB, atom: Atom, child: Var, parent: Var,
                 child_attrs: Tuple[CtVar, ...],
                 edge_attrs: Tuple[CtVar, ...],
                 stats: Optional[CostStats] = None
                 ) -> Tuple[jnp.ndarray, Tuple[CtVar, ...]]:
        fs = FactorSpec(child, tuple(child_attrs))
        leaf = NodeSpec(fs, (), fs.attrs)
        hop = HopSpec(atom, child, parent, tuple(edge_attrs), leaf,
                      fs.attrs + tuple(edge_attrs))
        return self.hop_message(db, hop, stats)

    def root_reduce(self, db: RelationalDB, own: FactorSpec,
                    factors: Sequence[Tuple[jnp.ndarray, Tuple[CtVar, ...]]],
                    keep: Sequence[CtVar],
                    stats: Optional[CostStats] = None) -> CtTable:
        code, ds = self._entity_code(db, own)
        n = db.entities[own.var.etype].size
        mvars: List[CtVar] = list(own.attrs)
        mats: List[jnp.ndarray] = []
        for m, vs in factors:
            mats.append(m)
            mvars.extend(vs)
        flat = self._reduce_by_code(code, ds, n, mats)
        return _finalise(flat, mvars, keep, stats)

    # -- traced batched evaluation ------------------------------------------
    def _flat_from_arrays(self, db: RelationalDB, plan: ContractionPlan,
                          cur: _ArrayCursor) -> jnp.ndarray:
        """Device-side mirror of ``_entity_code``/``_hop``/``_node_message``
        + ``root_reduce``: the host numpy code arithmetic becomes jnp int32
        arithmetic so the whole evaluation traces under ``vmap``.  The
        int32 segment-space guard is static, so it still raises at trace
        time."""
        def entity_code(fs: FactorSpec):
            if not fs.attrs:
                return None, 1
            code = None
            for cv in fs.attrs:
                col = cur.take().astype(jnp.int32)
                code = col if code is None else code * cv.card + col
            return code, fs.card

        def hop_from(hop: HopSpec, msg: _SparseMsg) -> jnp.ndarray:
            g, s = cur.take(), cur.take()
            n_parent = db.entities[hop.parent.etype].size
            n_edges = int(g.shape[0])
            ds = msg.ds
            ecode = (msg.code[g] if msg.code is not None
                     else jnp.zeros((n_edges,), dtype=jnp.int32))
            for cv in hop.edge_attrs:
                ecode = ecode * cv.card + cur.take().astype(jnp.int32)
                ds *= cv.card
            total = n_parent * ds
            if total > _INT32_LIMIT:
                raise OverflowError(
                    f"sparse hop segment space {total} exceeds int32; use "
                    f"the dense executor or reduce kept axes")
            seg = s.astype(jnp.int32) * ds + ecode
            if msg.dense is None:
                flat = jax.ops.segment_sum(
                    jnp.ones((n_edges,), dtype=self.dtype), seg,
                    num_segments=total)
                return flat.reshape(n_parent, ds)
            agg = jax.ops.segment_sum(msg.dense[g], seg, num_segments=total)
            return agg.reshape(n_parent, ds * msg.dense.shape[1])

        def node_msg(node: NodeSpec) -> _SparseMsg:
            code, ds = entity_code(node.own)
            dense: Optional[jnp.ndarray] = None
            for hop in node.hops:
                h = hop_from(hop, node_msg(hop.child_node))
                if dense is None:
                    dense = h
                else:
                    nn, d = dense.shape
                    dense = (dense[:, :, None] * h[:, None, :]).reshape(
                        nn, d * h.shape[1])
            return _SparseMsg(code, ds, (), dense, ())

        code, ds = entity_code(plan.root.own)
        n = db.entities[plan.root.var.etype].size
        mats = [hop_from(hop, node_msg(hop.child_node))
                for hop in plan.root.hops]
        return self._reduce_by_code(code, ds, n, mats)

    def _flat_vars(self, plan: ContractionPlan) -> Tuple[CtVar, ...]:
        # the sparse recursion emits (child own attrs, edge attrs) scalar-
        # coded first, then the child's aggregated dense axes — NOT the
        # planner's out_vars order; mirror it structurally
        def hop_vars(hop: HopSpec) -> List[CtVar]:
            child = hop.child_node
            out = list(child.own.attrs) + list(hop.edge_attrs)
            for h in child.hops:
                out.extend(hop_vars(h))
            return out

        out: List[CtVar] = list(plan.root.own.attrs)
        for hop in plan.root.hops:
            out.extend(hop_vars(hop))
        return tuple(out)


EXECUTORS = {"dense": DenseExecutor, "sparse": SparseExecutor}


def make_executor(name, **kw) -> Executor:
    """Resolve an executor by name (or pass an instance through)."""
    if isinstance(name, Executor):
        return name
    return EXECUTORS[name.lower()](**kw)
