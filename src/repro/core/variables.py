"""First-order variables, relationship atoms, lattice points, ct-table axes.

Language bias (per the paper's Related Work): patterns mention only types of
individuals.  We use FACTORBASE population variables — one first-order
variable per entity type, with a second *copy* for the far side of a
self-relationship (``Friend(U0, U1)``).

A **lattice point** is a connected, tree-structured conjunction of distinct
relationship atoms (Figure 2 of the paper).  Tree structure is what makes the
positive count a single-sweep tensor contraction; the benchmark schemas (and
FACTORBASE's own chains) are trees.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Sequence, Set, Tuple

from .schema import Relationship, Schema, _CachedHash


@dataclass(frozen=True, order=True)
class Var(_CachedHash):
    etype: str
    copy: int = 0

    __hash_seed__ = "Var"
    __hash__ = _CachedHash.__hash__

    def __str__(self) -> str:  # e.g. "student0"
        return f"{self.etype}{self.copy}"


@dataclass(frozen=True, order=True)
class Atom(_CachedHash):
    rel: str
    src: Var
    dst: Var

    __hash_seed__ = "Atom"
    __hash__ = _CachedHash.__hash__

    @property
    def vars(self) -> Tuple[Var, Var]:
        return (self.src, self.dst)


def canonical_atom(rel: Relationship) -> Atom:
    dst_copy = 1 if rel.is_self else 0
    return Atom(rel.name, Var(rel.src, 0), Var(rel.dst, dst_copy))


# --------------------------------------------------------------------------
# ct-table axis descriptors
# --------------------------------------------------------------------------

@dataclass(frozen=True, order=True)
class CtVar(_CachedHash):
    """One axis of a contingency table.

    kind:
      * ``attr`` — entity attribute; owner=(var, attr name); axis size = card.
      * ``edge`` — edge attribute; owner=(rel name, attr name); axis size =
        card + 1, last slot is N/A (used when the indicator is F).
      * ``rind`` — relationship indicator; owner=(rel name,); axis size 2 with
        F=0, T=1.
    """
    kind: str
    owner: Tuple
    card: int

    __hash_seed__ = "CtVar"
    __hash__ = _CachedHash.__hash__

    def __str__(self) -> str:
        if self.kind == "attr":
            var, name = self.owner
            return f"{name}({var})"
        if self.kind == "edge":
            rel, name = self.owner
            return f"{name}[{rel}]"
        return f"{self.owner[0]}?"


def attr_var(var: Var, name: str, card: int) -> CtVar:
    return CtVar("attr", (var, name), card)


def edge_var(rel: str, name: str, card: int) -> CtVar:
    return CtVar("edge", (rel, name), card + 1)   # +1 for N/A


def rind_var(rel: str) -> CtVar:
    return CtVar("rind", (rel,), 2)


# --------------------------------------------------------------------------
# Lattice points
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LatticePoint(_CachedHash):
    atoms: Tuple[Atom, ...]          # sorted by relationship name

    __hash_seed__ = "LatticePoint"
    __hash__ = _CachedHash.__hash__

    @property
    def rels(self) -> FrozenSet[str]:
        return frozenset(a.rel for a in self.atoms)

    @property
    def vars(self) -> Tuple[Var, ...]:
        vs: Set[Var] = set()
        for a in self.atoms:
            vs.update(a.vars)
        return tuple(sorted(vs))

    @property
    def length(self) -> int:
        return len(self.atoms)

    def __str__(self) -> str:
        return "&".join(f"{a.rel}({a.src},{a.dst})" for a in self.atoms) or "<empty>"

    def all_ct_vars(self, schema: Schema, include_rind: bool = True) -> Tuple[CtVar, ...]:
        """Every ct-table axis associated with this lattice point: all entity
        attributes of its variables, all edge attributes, all indicators."""
        out: List[CtVar] = []
        for v in self.vars:
            for a in schema.entity(v.etype).attrs:
                out.append(attr_var(v, a.name, a.card))
        for atom in self.atoms:
            rel = schema.relationship(atom.rel)
            for a in rel.attrs:
                out.append(edge_var(rel.name, a.name, a.card))
            if include_rind:
                out.append(rind_var(rel.name))
        return tuple(out)


def point_from_rels(schema: Schema, rels: Sequence[str]) -> LatticePoint:
    atoms = tuple(sorted((canonical_atom(schema.relationship(r)) for r in rels)))
    return LatticePoint(atoms)


def _is_connected_tree(atoms: Sequence[Atom]) -> Tuple[bool, bool]:
    """(connected, acyclic) of the var/atom incidence graph."""
    if not atoms:
        return True, True
    vs = sorted({v for a in atoms for v in a.vars})
    idx = {v: i for i, v in enumerate(vs)}
    parent = list(range(len(vs)))

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]
            i = parent[i]
        return i

    acyclic = True
    for a in atoms:
        ri, rj = find(idx[a.src]), find(idx[a.dst])
        if ri == rj:
            acyclic = False
        else:
            parent[ri] = rj
    roots = {find(i) for i in range(len(vs))}
    return len(roots) == 1, acyclic


def connected_components(atoms: Sequence[Atom]) -> List[Tuple[Atom, ...]]:
    """Split a set of atoms into connected components (by shared vars)."""
    remaining = list(atoms)
    comps: List[Tuple[Atom, ...]] = []
    while remaining:
        comp = [remaining.pop()]
        vs = set(comp[0].vars)
        changed = True
        while changed:
            changed = False
            for a in list(remaining):
                if vs & set(a.vars):
                    comp.append(a)
                    vs.update(a.vars)
                    remaining.remove(a)
                    changed = True
        comps.append(tuple(sorted(comp)))
    return comps


def build_lattice(schema: Schema, max_length: int = 2) -> List[LatticePoint]:
    """All connected tree-structured relationship subsets up to ``max_length``,
    ordered bottom-up (shorter chains first) — the relationship lattice of
    Figure 2."""
    rels = [r.name for r in schema.relationships]
    points: List[LatticePoint] = []
    for L in range(1, max_length + 1):
        for combo in itertools.combinations(rels, L):
            atoms = tuple(sorted(canonical_atom(schema.relationship(r))
                                 for r in combo))
            connected, acyclic = _is_connected_tree(atoms)
            if connected and acyclic:
                points.append(LatticePoint(atoms))
    return points
