"""First-order Bayesian-network structure search (learn-and-join style).

Greedy hill-climbing over predicate dependencies per relationship lattice
point, bottom-up through the lattice with edge inheritance from sub-points
(Schulte & Khosravi 2012, simplified).  Every family evaluation goes through
the pluggable counting :class:`~repro.core.strategies.Strategy` — this module
is deliberately strategy-agnostic: it is the *workload generator* whose
pattern stream the pre/post/hybrid caches serve.

Family scores are memoised globally by (child, parents): the same family is
generated repeatedly during search (and across lattice points), which is
exactly what makes counts caching pay off.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .bdeu import family_score
from .database import RelationalDB
from .strategies import Strategy
from .variables import CtVar, LatticePoint, build_lattice


@dataclass
class BNModel:
    nodes: Tuple[CtVar, ...]
    parents: Dict[CtVar, FrozenSet[CtVar]]
    score: float

    def edges(self) -> List[Tuple[CtVar, CtVar]]:
        return [(p, c) for c, ps in self.parents.items() for p in ps]


class StructureSearch:
    def __init__(self, db: RelationalDB, strategy: Strategy,
                 max_parents: int = 3, ess: float = 1.0,
                 max_moves: int = 200):
        self.db = db
        self.strategy = strategy
        self.max_parents = max_parents
        self.ess = ess
        self.max_moves = max_moves
        self._score_cache: Dict[Tuple[CtVar, FrozenSet[CtVar]], float] = {}
        self.families_scored = 0

    # -- family scoring (through the counting strategy) ---------------------
    def local_score(self, point: LatticePoint, child: CtVar,
                    parents: FrozenSet[CtVar]) -> float:
        key = (child, parents)
        if key not in self._score_cache:
            keep = tuple(sorted(parents)) + (child,)
            tab = self.strategy.family_ct(point, keep)
            self._score_cache[key] = family_score(tab, child, self.ess)
            self.families_scored += 1
        return self._score_cache[key]

    # -- acyclicity ----------------------------------------------------------
    @staticmethod
    def _creates_cycle(parents: Dict[CtVar, Set[CtVar]],
                       src: CtVar, dst: CtVar) -> bool:
        """Would edge src->dst close a cycle? (is dst an ancestor of src?)"""
        stack, seen = [src], set()
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(parents[n])
        return False

    # -- hill climbing per lattice point -------------------------------------
    def climb_point(self, point: LatticePoint,
                    init_parents: Optional[Dict[CtVar, Set[CtVar]]] = None
                    ) -> BNModel:
        nodes = list(point.all_ct_vars(self.db.schema, include_rind=True))
        parents: Dict[CtVar, Set[CtVar]] = {n: set() for n in nodes}
        if init_parents:
            for c, ps in init_parents.items():
                if c in parents:
                    parents[c] = {p for p in ps if p in parents}

        def sc(child: CtVar) -> float:
            return self.local_score(point, child, frozenset(parents[child]))

        total = sum(sc(n) for n in nodes)
        for _ in range(self.max_moves):
            best_delta, best_apply = 0.0, None
            for src, dst in itertools.permutations(nodes, 2):
                if src in parents[dst]:
                    # removal
                    old = sc(dst)
                    new = self.local_score(point, dst,
                                           frozenset(parents[dst] - {src}))
                    if new - old > best_delta:
                        best_delta = new - old
                        best_apply = ("del", src, dst)
                else:
                    if len(parents[dst]) >= self.max_parents:
                        continue
                    if self._creates_cycle(parents, src, dst):
                        continue
                    old = sc(dst)
                    new = self.local_score(point, dst,
                                           frozenset(parents[dst] | {src}))
                    if new - old > best_delta:
                        best_delta = new - old
                        best_apply = ("add", src, dst)
            if best_apply is None:
                break
            op, src, dst = best_apply
            if op == "add":
                parents[dst].add(src)
            else:
                parents[dst].remove(src)
            total += best_delta
        return BNModel(tuple(nodes),
                       {n: frozenset(ps) for n, ps in parents.items()},
                       total)

    # -- learn-and-join over the lattice --------------------------------------
    def run(self, lattice: Sequence[LatticePoint]) -> Dict[LatticePoint, BNModel]:
        models: Dict[LatticePoint, BNModel] = {}
        for point in lattice:          # lattice is bottom-up ordered
            init: Dict[CtVar, Set[CtVar]] = {}
            for sub, m in models.items():
                if sub.rels < point.rels:      # inherit sub-point edges
                    for c, ps in m.parents.items():
                        init.setdefault(c, set()).update(ps)
            models[point] = self.climb_point(point, init)
        return models


def discover_model(db: RelationalDB, strategy: Strategy,
                   max_chain_length: int = 2, max_parents: int = 3,
                   ess: float = 1.0) -> Tuple[Dict[LatticePoint, BNModel], Strategy]:
    """End-to-end model discovery: build lattice, run the strategy's
    pre-search phase, hill-climb bottom-up.  Returns per-point models and the
    strategy (whose ``stats`` carry the paper's metrics)."""
    lattice = build_lattice(db.schema, max_chain_length)
    strategy.prepare(db, lattice)
    search = StructureSearch(db, strategy, max_parents=max_parents, ess=ess)
    models = search.run(lattice)
    return models, strategy
