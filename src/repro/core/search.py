"""First-order Bayesian-network structure search (learn-and-join style).

Greedy hill-climbing over predicate dependencies per relationship lattice
point, bottom-up through the lattice with edge inheritance from sub-points
(Schulte & Khosravi 2012, simplified).  Every family evaluation goes through
the pluggable counting :class:`~repro.core.strategies.Strategy` — this module
is deliberately strategy-agnostic: it is the *workload generator* whose
pattern stream the pre/post/hybrid caches serve.

Family scoring is **batched**: each hill-climbing round first enumerates
every candidate move, fetches the ct-tables of the not-yet-scored families
through the strategy (cache-served for PRECOUNT/HYBRID/TUPLEID), groups the
resulting ``N_ijk`` matrices by shape, and scores each group in ONE
jitted/vmapped BDeu call (:func:`~repro.core.bdeu.bdeu_score_batch`)
instead of one Python → XLA round-trip per family.  Scores are memoised
globally by (child, parents): the same family is generated repeatedly
during search (and across lattice points), which is exactly what makes
counts caching pay off.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from .bdeu import bdeu_score_batch, family_nijk, family_score
from .database import RelationalDB
from .strategies import Strategy
from .variables import CtVar, LatticePoint, build_lattice


@dataclass
class BNModel:
    nodes: Tuple[CtVar, ...]
    parents: Dict[CtVar, FrozenSet[CtVar]]
    score: float

    def edges(self) -> List[Tuple[CtVar, CtVar]]:
        return [(p, c) for c, ps in self.parents.items() for p in ps]


Family = Tuple[CtVar, FrozenSet[CtVar]]          # (child, parents)


class StructureSearch:
    def __init__(self, db: RelationalDB, strategy: Strategy,
                 max_parents: int = 3, ess: float = 1.0,
                 max_moves: int = 200, batch_scoring: bool = True):
        self.db = db
        self.strategy = strategy
        self.max_parents = max_parents
        self.ess = ess
        self.max_moves = max_moves
        self.batch_scoring = batch_scoring
        self._score_cache: Dict[Family, float] = {}
        self.families_scored = 0
        self.batch_calls = 0          # vmapped BDeu dispatches issued

    # -- family scoring (through the counting strategy) ---------------------
    def local_score(self, point: LatticePoint, child: CtVar,
                    parents: FrozenSet[CtVar]) -> float:
        key = (child, parents)
        if key not in self._score_cache:
            keep = tuple(sorted(parents)) + (child,)
            tab = self.strategy.family_ct(point, keep)
            self._score_cache[key] = family_score(tab, child, self.ess)
            self.families_scored += 1
        return self._score_cache[key]

    def batch_scores(self, point: LatticePoint,
                     fams: Iterable[Family]) -> None:
        """Score every not-yet-cached family of ``fams`` with one vmapped
        BDeu call per N_ijk shape group.  The ct-tables themselves are
        fetched through the strategy's batched entry point
        (:meth:`~repro.core.strategies.Strategy.family_ct_many`), which
        routes the round's positive contractions through the counting
        service in signature-bucketed stacked dispatches — hill-climbing
        is the service's first heavy client."""
        todo: List[Family] = []
        seen: Set[Family] = set()
        for fam in fams:
            if fam not in self._score_cache and fam not in seen:
                seen.add(fam)
                todo.append(fam)
        if not todo:
            return
        keeps = [tuple(sorted(parents)) + (child,)
                 for child, parents in todo]
        fetch_many = getattr(self.strategy, "family_ct_many", None)
        tabs = (fetch_many(point, keeps) if fetch_many is not None
                else [self.strategy.family_ct(point, k) for k in keeps])
        groups: Dict[Tuple[int, int], List[Tuple[Family, jnp.ndarray]]] = {}
        for (child, parents), tab in zip(todo, tabs):
            nijk = family_nijk(tab, child)
            groups.setdefault(tuple(nijk.shape), []).append(
                ((child, parents), nijk))
        for shape, members in groups.items():
            stack = jnp.stack([nijk for _, nijk in members])
            # pad the batch axis to the next power of two: the frontier
            # shrinks every round, and an exact-B jit would recompile per
            # round; all-zero rows score 0 and are sliced off below
            b = stack.shape[0]
            b_pad = 1 << max(b - 1, 0).bit_length()
            if b_pad != b:
                stack = jnp.pad(stack, ((0, b_pad - b), (0, 0), (0, 0)))
            scores = np.asarray(bdeu_score_batch(stack, ess=self.ess))[:b]
            self.batch_calls += 1
            for (fam, _), s in zip(members, scores):
                self._score_cache[fam] = float(s)
        self.families_scored += len(todo)

    # -- acyclicity ----------------------------------------------------------
    @staticmethod
    def _creates_cycle(parents: Dict[CtVar, Set[CtVar]],
                       src: CtVar, dst: CtVar) -> bool:
        """Would edge src->dst close a cycle? (is dst an ancestor of src?)"""
        stack, seen = [src], set()
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(parents[n])
        return False

    # -- hill climbing per lattice point -------------------------------------
    def _candidate_moves(self, nodes: Sequence[CtVar],
                         parents: Dict[CtVar, Set[CtVar]]
                         ) -> List[Tuple[str, CtVar, CtVar, FrozenSet[CtVar]]]:
        """All legal single-edge moves, in deterministic enumeration order."""
        moves = []
        for src, dst in itertools.permutations(nodes, 2):
            if src in parents[dst]:
                moves.append(("del", src, dst,
                              frozenset(parents[dst] - {src})))
            else:
                if len(parents[dst]) >= self.max_parents:
                    continue
                if self._creates_cycle(parents, src, dst):
                    continue
                moves.append(("add", src, dst,
                              frozenset(parents[dst] | {src})))
        return moves

    def climb_point(self, point: LatticePoint,
                    init_parents: Optional[Dict[CtVar, Set[CtVar]]] = None
                    ) -> BNModel:
        nodes = list(point.all_ct_vars(self.db.schema, include_rind=True))
        parents: Dict[CtVar, Set[CtVar]] = {n: set() for n in nodes}
        if init_parents:
            for c, ps in init_parents.items():
                if c in parents:
                    parents[c] = {p for p in ps if p in parents}

        def sc(child: CtVar) -> float:
            return self.local_score(point, child, frozenset(parents[child]))

        if self.batch_scoring:
            self.batch_scores(point, ((n, frozenset(parents[n]))
                                      for n in nodes))
        total = sum(sc(n) for n in nodes)
        for _ in range(self.max_moves):
            moves = self._candidate_moves(nodes, parents)
            if self.batch_scoring:
                # one vmapped scoring pass over the whole round's frontier
                self.batch_scores(point, ((dst, ps)
                                          for _, _, dst, ps in moves))
            best_delta, best_apply = 0.0, None
            for op, src, dst, new_ps in moves:
                delta = (self.local_score(point, dst, new_ps) - sc(dst))
                if delta > best_delta:
                    best_delta = delta
                    best_apply = (op, src, dst)
            if best_apply is None:
                break
            op, src, dst = best_apply
            if op == "add":
                parents[dst].add(src)
            else:
                parents[dst].remove(src)
            total += best_delta
        return BNModel(tuple(nodes),
                       {n: frozenset(ps) for n, ps in parents.items()},
                       total)

    # -- learn-and-join over the lattice --------------------------------------
    def run(self, lattice: Sequence[LatticePoint]) -> Dict[LatticePoint, BNModel]:
        models: Dict[LatticePoint, BNModel] = {}
        for point in lattice:          # lattice is bottom-up ordered
            init: Dict[CtVar, Set[CtVar]] = {}
            for sub, m in models.items():
                if sub.rels < point.rels:      # inherit sub-point edges
                    for c, ps in m.parents.items():
                        init.setdefault(c, set()).update(ps)
            models[point] = self.climb_point(point, init)
        return models


def discover_model(db: RelationalDB, strategy: Strategy,
                   max_chain_length: int = 2, max_parents: int = 3,
                   ess: float = 1.0, batch_scoring: bool = True
                   ) -> Tuple[Dict[LatticePoint, BNModel], Strategy]:
    """End-to-end model discovery: build lattice, run the strategy's
    pre-search phase, hill-climb bottom-up.  Returns per-point models and the
    strategy (whose ``stats`` carry the paper's metrics)."""
    lattice = build_lattice(db.schema, max_chain_length)
    strategy.prepare(db, lattice)
    search = StructureSearch(db, strategy, max_parents=max_parents, ess=ess,
                             batch_scoring=batch_scoring)
    models = search.run(lattice)
    return models, strategy
