"""First-order Bayesian-network structure search (learn-and-join style).

Greedy hill-climbing over predicate dependencies per relationship lattice
point, bottom-up through the lattice with edge inheritance from sub-points
(Schulte & Khosravi 2012, simplified).  Every family evaluation goes through
the pluggable counting :class:`~repro.core.strategies.Strategy` — this module
is deliberately strategy-agnostic: it is the *workload generator* whose
pattern stream the pre/post/hybrid caches serve.

Family scoring is **batched**: each hill-climbing round first enumerates
every candidate move, fetches the ct-tables of the not-yet-scored families
through the strategy (cache-served for PRECOUNT/HYBRID/TUPLEID), groups the
resulting ``N_ijk`` matrices by shape, and scores each group in ONE
jitted/vmapped BDeu call (:func:`~repro.core.bdeu.bdeu_score_batch`)
instead of one Python → XLA round-trip per family.  Scores are memoised
globally by (child, parents): the same family is generated repeatedly
during search (and across lattice points), which is exactly what makes
counts caching pay off.

The counting backend is **pluggable**: any object with the
``family_ct(point, keep)`` / ``family_ct_many(point, keeps)`` protocol can
serve the family tables — a bare :class:`~repro.core.strategies.Strategy`,
a :class:`~repro.serve.service.CountingService`, or a sharded
:class:`~repro.serve.router.CountingRouter` (see
:mod:`repro.discover.providers`) — so one search loop covers local,
served, and distributed execution, and parity between them is a table
equality, not a code-path equivalence.  Candidate moves are sorted into a
canonical order before the argmax, so exact score ties break identically
no matter which backend produced the tables.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import (Callable, Dict, FrozenSet, Iterable, List, MutableMapping,
                    Optional, Sequence, Set, Tuple)

import jax.numpy as jnp
import numpy as np

from .bdeu import bdeu_score_batch, family_nijk, family_score
from .database import RelationalDB
from .strategies import Strategy
from .variables import CtVar, LatticePoint, build_lattice


@dataclass
class BNModel:
    nodes: Tuple[CtVar, ...]
    parents: Dict[CtVar, FrozenSet[CtVar]]
    score: float

    def edges(self) -> List[Tuple[CtVar, CtVar]]:
        return [(p, c) for c, ps in self.parents.items() for p in ps]


Family = Tuple[CtVar, FrozenSet[CtVar]]          # (child, parents)

# round hook: (point, n_moves, families_scored_this_round, t0, t1)
RoundCallback = Callable[[LatticePoint, int, int, float, float], None]


class StructureSearch:
    """Greedy hill-climbing over one pluggable count provider.

    Args:
        db: the database (used for its schema; may be ``None`` when
            ``schema`` or a ``counts`` provider with a ``schema``
            attribute is given — the served/distributed deployments).
        strategy: the counting strategy; doubles as the default count
            provider (``family_ct`` / ``family_ct_many``).
        counts: count-provider override — any object with the strategy's
            family-table protocol (service- or router-backed, see
            :mod:`repro.discover.providers`).
        score_cache: external score memo (``in`` / ``[]`` protocol on
            ``(child, parents)`` keys).  :class:`~repro.discover.service
            .DiscoveryService` injects a version-scoped view here so
            concurrent searches share one memo that composes with store
            mutations; by default each search owns a private dict.
        round_cb: optional per-climbing-round hook
            ``(point, n_moves, n_scored, t0, t1)`` — the discovery
            service's search-round spans and histograms attach here.
    """

    def __init__(self, db: Optional[RelationalDB], strategy: Optional[Strategy],
                 max_parents: int = 3, ess: float = 1.0,
                 max_moves: int = 200, batch_scoring: bool = True,
                 counts: Optional[object] = None,
                 schema: Optional[object] = None,
                 score_cache: Optional[MutableMapping] = None,
                 round_cb: Optional[RoundCallback] = None):
        self.db = db
        self.strategy = strategy
        self.counts = counts if counts is not None else strategy
        if self.counts is None:
            raise ValueError("StructureSearch needs a strategy or a counts "
                             "provider")
        if schema is not None:
            self.schema = schema
        elif db is not None:
            self.schema = db.schema
        else:
            self.schema = self.counts.schema
        self.max_parents = max_parents
        self.ess = ess
        self.max_moves = max_moves
        self.batch_scoring = batch_scoring
        self.round_cb = round_cb
        self._score_cache: MutableMapping[Family, float] = (
            score_cache if score_cache is not None else {})
        # which relations each scored family's table depended on (the
        # point's relation set at scoring time) — the delta-refresh layer
        # uses this to carry forward scores a write cannot have changed
        self.family_deps: Dict[Family, FrozenSet[str]] = {}
        self.families_scored = 0
        self.batch_calls = 0          # vmapped BDeu dispatches issued

    # -- family scoring (through the counting strategy) ---------------------
    def local_score(self, point: LatticePoint, child: CtVar,
                    parents: FrozenSet[CtVar]) -> float:
        key = (child, parents)
        if key not in self._score_cache:
            keep = tuple(sorted(parents)) + (child,)
            tab = self.counts.family_ct(point, keep)
            self._score_cache[key] = family_score(tab, child, self.ess)
            self.family_deps[key] = point.rels
            self.families_scored += 1
        return self._score_cache[key]

    def batch_scores(self, point: LatticePoint,
                     fams: Iterable[Family]) -> None:
        """Score every not-yet-cached family of ``fams`` with one vmapped
        BDeu call per N_ijk shape group.  The ct-tables themselves are
        fetched through the strategy's batched entry point
        (:meth:`~repro.core.strategies.Strategy.family_ct_many`), which
        routes the round's positive contractions through the counting
        service in signature-bucketed stacked dispatches — hill-climbing
        is the service's first heavy client."""
        todo: List[Family] = []
        seen: Set[Family] = set()
        for fam in fams:
            if fam not in self._score_cache and fam not in seen:
                seen.add(fam)
                todo.append(fam)
        if not todo:
            return
        keeps = [tuple(sorted(parents)) + (child,)
                 for child, parents in todo]
        fetch_many = getattr(self.counts, "family_ct_many", None)
        tabs = (fetch_many(point, keeps) if fetch_many is not None
                else [self.counts.family_ct(point, k) for k in keeps])
        groups: Dict[Tuple[int, int], List[Tuple[Family, jnp.ndarray]]] = {}
        for (child, parents), tab in zip(todo, tabs):
            nijk = family_nijk(tab, child)
            groups.setdefault(tuple(nijk.shape), []).append(
                ((child, parents), nijk))
        for shape, members in groups.items():
            stack = jnp.stack([nijk for _, nijk in members])
            # pad the batch axis to the next power of two: the frontier
            # shrinks every round, and an exact-B jit would recompile per
            # round; all-zero rows score 0 and are sliced off below
            b = stack.shape[0]
            b_pad = 1 << max(b - 1, 0).bit_length()
            if b_pad != b:
                stack = jnp.pad(stack, ((0, b_pad - b), (0, 0), (0, 0)))
            scores = np.asarray(bdeu_score_batch(stack, ess=self.ess))[:b]
            self.batch_calls += 1
            for (fam, _), s in zip(members, scores):
                self._score_cache[fam] = float(s)
                self.family_deps[fam] = point.rels
        self.families_scored += len(todo)

    # -- acyclicity ----------------------------------------------------------
    @staticmethod
    def _creates_cycle(parents: Dict[CtVar, Set[CtVar]],
                       src: CtVar, dst: CtVar) -> bool:
        """Would edge src->dst close a cycle? (is dst an ancestor of src?)"""
        stack, seen = [src], set()
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(parents[n])
        return False

    # -- hill climbing per lattice point -------------------------------------
    def _candidate_moves(self, nodes: Sequence[CtVar],
                         parents: Dict[CtVar, Set[CtVar]]
                         ) -> List[Tuple[str, CtVar, CtVar, FrozenSet[CtVar]]]:
        """All legal single-edge moves, sorted into a canonical order
        (op, src, dst, parent set) — the argmax's strict ``>`` then breaks
        exact score ties on the SAME move regardless of enumeration order,
        which is what makes served/distributed discovery reproduce the
        local oracle edge-for-edge rather than only score-approximately."""
        moves = []
        for src, dst in itertools.permutations(nodes, 2):
            if src in parents[dst]:
                moves.append(("del", src, dst,
                              frozenset(parents[dst] - {src})))
            else:
                if len(parents[dst]) >= self.max_parents:
                    continue
                if self._creates_cycle(parents, src, dst):
                    continue
                moves.append(("add", src, dst,
                              frozenset(parents[dst] | {src})))
        moves.sort(key=lambda m: (m[0], m[1], m[2], tuple(sorted(m[3]))))
        return moves

    def climb_point(self, point: LatticePoint,
                    init_parents: Optional[Dict[CtVar, Set[CtVar]]] = None
                    ) -> BNModel:
        nodes = list(point.all_ct_vars(self.schema, include_rind=True))
        parents: Dict[CtVar, Set[CtVar]] = {n: set() for n in nodes}
        if init_parents:
            for c, ps in init_parents.items():
                if c in parents:
                    parents[c] = {p for p in ps if p in parents}

        def sc(child: CtVar) -> float:
            return self.local_score(point, child, frozenset(parents[child]))

        if self.batch_scoring:
            self.batch_scores(point, ((n, frozenset(parents[n]))
                                      for n in nodes))
        total = sum(sc(n) for n in nodes)
        for _ in range(self.max_moves):
            t0 = time.perf_counter()
            scored_before = self.families_scored
            moves = self._candidate_moves(nodes, parents)
            if self.batch_scoring:
                # one vmapped scoring pass over the whole round's frontier
                self.batch_scores(point, ((dst, ps)
                                          for _, _, dst, ps in moves))
            best_delta, best_apply = 0.0, None
            for op, src, dst, new_ps in moves:
                delta = (self.local_score(point, dst, new_ps) - sc(dst))
                if delta > best_delta:
                    best_delta = delta
                    best_apply = (op, src, dst)
            if self.round_cb is not None:
                self.round_cb(point, len(moves),
                              self.families_scored - scored_before,
                              t0, time.perf_counter())
            if best_apply is None:
                break
            op, src, dst = best_apply
            if op == "add":
                parents[dst].add(src)
            else:
                parents[dst].remove(src)
            total += best_delta
        return BNModel(tuple(nodes),
                       {n: frozenset(ps) for n, ps in parents.items()},
                       total)

    # -- learn-and-join over the lattice --------------------------------------
    def run(self, lattice: Sequence[LatticePoint],
            init_models: Optional[Dict[LatticePoint, BNModel]] = None
            ) -> Dict[LatticePoint, BNModel]:
        """Learn-and-join bottom-up over the lattice.

        Args:
            lattice: bottom-up ordered lattice points.
            init_models: warm-start models (the refresh hook) — each
                point's climb starts from its previous model's edges on
                top of the usual sub-point inheritance, so an online
                refresh hill-climbs locally from the current model
                instead of from scratch.
        """
        models: Dict[LatticePoint, BNModel] = {}
        for point in lattice:          # lattice is bottom-up ordered
            init: Dict[CtVar, Set[CtVar]] = {}
            for sub, m in models.items():
                if sub.rels < point.rels:      # inherit sub-point edges
                    for c, ps in m.parents.items():
                        init.setdefault(c, set()).update(ps)
            if init_models is not None and point in init_models:
                for c, ps in init_models[point].parents.items():
                    init.setdefault(c, set()).update(ps)
            models[point] = self.climb_point(point, init)
        return models


def discover_model(db: RelationalDB, strategy: Strategy,
                   max_chain_length: int = 2, max_parents: int = 3,
                   ess: float = 1.0, batch_scoring: bool = True
                   ) -> Tuple[Dict[LatticePoint, BNModel], Strategy]:
    """End-to-end model discovery: build lattice, run the strategy's
    pre-search phase, hill-climb bottom-up.  Returns per-point models and the
    strategy (whose ``stats`` carry the paper's metrics)."""
    lattice = build_lattice(db.schema, max_chain_length)
    strategy.prepare(db, lattice)
    search = StructureSearch(db, strategy, max_parents=max_parents, ess=ess,
                             batch_scoring=batch_scoring)
    models = search.run(lattice)
    return models, strategy
