"""Planner: compile ``(LatticePoint, keep)`` into a backend-agnostic
contraction plan.

The SQL ``INNER JOIN + GROUP BY + COUNT(*)`` of FACTORBASE is, for a
tree-structured lattice point, one message-passing sweep over the point's
variable tree.  The planner decides everything that does NOT depend on how
messages are represented:

* which variable roots the tree (the centre — max degree — so interior
  messages stay one hop wide and the root combine is deferred to a single
  multi-factor reduction);
* the traversal order (a tree of :class:`HopSpec` under each
  :class:`NodeSpec`);
* which attribute axes each factor carries (``keep`` filtered per
  variable / relationship, in canonical schema order);
* the flattened axis order every message will have, so executors agree on
  layout without communicating.

Executors (:mod:`repro.core.executors`) walk the plan and choose the
representation: dense one-hot matrices on the MXU, or raw ``int32`` code
arrays + ``segment_sum`` scatter-adds.  Plans are frozen/hashable — they
double as cache keys and as batching signatures (two plans with the same
:meth:`ContractionPlan.shape_signature` produce same-shape ct-tables, which
is what lets structure search score families in one vmapped call).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

from .schema import Schema
from .variables import Atom, CtVar, LatticePoint, Var, attr_var, edge_var


@dataclass(frozen=True)
class FactorSpec:
    """The 'own attributes' factor of one first-order variable: the kept
    attribute axes of ``var`` in canonical (schema) order."""
    var: Var
    attrs: Tuple[CtVar, ...]

    @property
    def card(self) -> int:
        out = 1
        for v in self.attrs:
            out *= v.card
        return out


@dataclass(frozen=True)
class HopSpec:
    """One join hop: the subtree message of ``child`` pushed through
    ``atom`` to ``parent`` — gather at the child end of the edge list,
    (outer-)multiply in kept edge-attribute axes, segment-sum at the parent
    end.  ``out_vars`` is the flattened axis order of the hop's output."""
    atom: Atom
    child: Var
    parent: Var
    edge_attrs: Tuple[CtVar, ...]
    child_node: "NodeSpec"
    out_vars: Tuple[CtVar, ...]

    @property
    def is_leaf_hop(self) -> bool:
        return not self.child_node.hops


@dataclass(frozen=True)
class NodeSpec:
    """Message spec for the subtree rooted at ``var``: the variable's own
    factor combined with the hop outputs of its children.  ``out_vars`` is
    the flattened axis order of the node's message (own attrs first, then
    each hop's axes in traversal order)."""
    own: FactorSpec
    hops: Tuple[HopSpec, ...]
    out_vars: Tuple[CtVar, ...]

    @property
    def var(self) -> Var:
        return self.own.var


@dataclass(frozen=True)
class ContractionPlan:
    """A compiled positive-count query: root node + requested output order.

    ``out_vars`` is the axis order of the raw contraction result;
    executors transpose to ``keep`` at the end (both orders cover the same
    var set — ``keep`` restricted to axes that exist on the point).
    """
    point: LatticePoint
    keep: Tuple[CtVar, ...]
    root: NodeSpec
    out_vars: Tuple[CtVar, ...]

    @property
    def out_shape(self) -> Tuple[int, ...]:
        return tuple(v.card for v in self.keep)

    def shape_signature(self) -> Tuple[Tuple[str, int], ...]:
        """Batching key: plans with equal signatures yield same-shape
        ct-tables (axis kinds + cards, in output order)."""
        return tuple((v.kind, v.card) for v in self.keep)

    def tree_signature(self) -> Tuple:
        """Structural batching key: the hop-tree topology with per-factor
        attribute cards and per-hop edge-attribute cards, independent of the
        concrete variables/relations involved.  Two plans with equal tree
        signatures run the *same* sequence of contraction operations; add the
        database-dependent array sizes (entity sizes, edge counts — see
        :func:`repro.core.executors.plan_stack_key`) and their inputs can be
        stacked and executed in one vmapped call."""
        def node(n: NodeSpec) -> Tuple:
            return (tuple(cv.card for cv in n.own.attrs),
                    tuple((tuple(cv.card for cv in h.edge_attrs),
                           node(h.child_node)) for h in n.hops))
        return (node(self.root), self.shape_signature())


def _kept_entity_attrs(schema: Schema, var: Var,
                       keep: Tuple[CtVar, ...]) -> Tuple[CtVar, ...]:
    out: List[CtVar] = []
    for a in schema.entity(var.etype).attrs:
        cv = attr_var(var, a.name, a.card)
        if cv in keep:
            out.append(cv)
    return tuple(out)


def _kept_edge_attrs(schema: Schema, rel: str,
                     keep: Tuple[CtVar, ...]) -> Tuple[CtVar, ...]:
    rt = schema.relationship(rel)
    out: List[CtVar] = []
    for a in rt.attrs:
        cv = edge_var(rel, a.name, a.card)
        if cv in keep:
            out.append(cv)
    return tuple(out)


def compile_plan(schema: Schema, point: LatticePoint,
                 keep: Optional[Sequence[CtVar]] = None) -> ContractionPlan:
    """Compile the positive-count query for ``point`` over ``keep``.

    ``keep`` may contain entity-attr and edge-attr CtVars of the point (rind
    axes are the Möbius join's job, not the contraction's); defaults to all
    of them.  Purely metadata-driven — no data access.
    """
    if keep is None:
        keep = point.all_ct_vars(schema, include_rind=False)
    keep = tuple(keep)
    if not point.atoms:
        raise ValueError("compile_plan needs at least one atom")

    adj: Dict[Var, List[Tuple[Atom, Var]]] = {}
    for a in point.atoms:
        adj.setdefault(a.src, []).append((a, a.dst))
        adj.setdefault(a.dst, []).append((a, a.src))
    root_var = max(point.vars, key=lambda v: len(adj.get(v, ())))

    def build_node(v: Var, parent_atom: Optional[Atom]) -> NodeSpec:
        own = FactorSpec(v, _kept_entity_attrs(schema, v, keep))
        hops: List[HopSpec] = []
        out_vars: List[CtVar] = list(own.attrs)
        for atom, u in adj.get(v, ()):
            if atom is parent_atom:
                continue
            child = build_node(u, atom)
            eattrs = _kept_edge_attrs(schema, atom.rel, keep)
            hop_vars = child.out_vars + eattrs
            hops.append(HopSpec(atom, u, v, eattrs, child, hop_vars))
            out_vars.extend(hop_vars)
        return NodeSpec(own, tuple(hops), tuple(out_vars))

    root = build_node(root_var, None)
    return ContractionPlan(point, keep, root, root.out_vars)


@lru_cache(maxsize=4096)
def _compile_cached(schema: Schema, atoms: Tuple[Atom, ...],
                    keep: Tuple[CtVar, ...]) -> ContractionPlan:
    return compile_plan(schema, LatticePoint(atoms), keep)


def compile_plan_cached(schema: Schema, point: LatticePoint,
                        keep: Tuple[CtVar, ...]) -> ContractionPlan:
    """Memoised :func:`compile_plan` (plans are pure metadata; search
    recompiles the same handful of queries thousands of times)."""
    try:
        return _compile_cached(schema, point.atoms, tuple(keep))
    except TypeError:            # unhashable schema: fall back, don't cache
        return compile_plan(schema, point, keep)


def group_by_signature(plans: Sequence[ContractionPlan],
                       key: str = "shape") -> Dict[Tuple, List[int]]:
    """Group plan *indices* by batching signature, preserving arrival order
    within each group.  ``key="shape"`` buckets by output shape (the
    scheduler's quota unit); ``key="tree"`` buckets by full structural
    signature (the stacked-execution precondition, minus array sizes)."""
    if key not in ("shape", "tree"):
        raise ValueError(f"unknown signature key {key!r}")
    groups: Dict[Tuple, List[int]] = {}
    for i, plan in enumerate(plans):
        sig = (plan.shape_signature() if key == "shape"
               else plan.tree_signature())
        groups.setdefault(sig, []).append(i)
    return groups
