"""BDeu scoring of families from contingency tables (paper Eq. 1).

The ct-table for (parents, child) is reshaped to ``N_ijk`` with ``j`` ranging
over parent configurations and ``k`` over child values; the score is the usual
Dirichlet-multinomial marginal likelihood with equivalent sample size ``N'``.
The lgamma-heavy reduction is the scoring hot spot — mirrored by the Pallas
kernel in ``kernels/bdeu_kernel.py``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from .ct import CtTable
from .variables import CtVar


def _bdeu_2d(nijk: jnp.ndarray, ess: float) -> jnp.ndarray:
    nijk = nijk.astype(jnp.float32)
    q, r = nijk.shape
    a_j = ess / q
    a_jk = ess / (q * r)
    nij = jnp.sum(nijk, axis=1)
    per_j = (gammaln(a_j) - gammaln(nij + a_j)
             + jnp.sum(gammaln(nijk + a_jk) - gammaln(a_jk), axis=1))
    return jnp.sum(per_j)


@partial(jax.jit, static_argnames=("ess",))
def bdeu_score_2d(nijk: jnp.ndarray, ess: float = 1.0) -> jnp.ndarray:
    """BDeu log marginal likelihood for N_ijk of shape (q, r)."""
    return _bdeu_2d(nijk, ess)


@partial(jax.jit, static_argnames=("ess",))
def bdeu_score_batch(nijk: jnp.ndarray, ess: float = 1.0) -> jnp.ndarray:
    """Batched BDeu: ``(B, q, r) -> (B,)`` in one vmapped call.

    Structure search groups same-shape families per hill-climbing round and
    scores each group here instead of one Python round-trip per family —
    one XLA dispatch amortises the lgamma-heavy reduction across the whole
    candidate set."""
    return jax.vmap(lambda t: _bdeu_2d(t, ess))(nijk)


def family_nijk(tab: CtTable, child: CtVar) -> jnp.ndarray:
    """Reshape a family's complete ct-table to ``N_ijk`` of shape (q, r):
    parent configurations × child values, child axis last."""
    order = tuple(v for v in tab.vars if v != child) + (child,)
    t = tab.transpose_to(order)
    return t.counts.reshape((-1, child.card))


def family_score(tab: CtTable, child: CtVar, ess: float = 1.0,
                 score_fn=None) -> float:
    """Score a family from its complete ct-table.  ``tab`` must contain the
    child axis and any number of parent axes."""
    nijk = family_nijk(tab, child)
    fn = score_fn or bdeu_score_2d
    return float(fn(nijk, ess=ess))
