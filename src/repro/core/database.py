"""Integer-coded relational database + synthetic generators.

A :class:`RelationalDB` is the TPU-native stand-in for the paper's MariaDB
input: every entity table is a dict of ``int32[n]`` attribute columns and every
relationship table is an edge list ``(src int32[m], dst int32[m])`` plus
``int32[m]`` edge-attribute columns.  All shapes are static *per version*;
counting never needs dynamic shapes.

The store is **versioned and mutable**: :meth:`RelationalDB.insert_facts` /
:meth:`RelationalDB.delete_facts` apply a batch of relationship-fact writes,
bump ``db.version`` and return a :class:`FactDelta` — the exact edge set that
changed, which downstream layers use for *delta count maintenance* (positive
ct-tables are multilinear in each relationship's edge multiset, so a cached
table is refreshed by counting just the delta edges; see
:meth:`repro.core.engine.CountingEngine.apply_delta`) and for fine-grained
cache invalidation (:meth:`repro.core.cache.CtCache.invalidate`).
Entity-attribute writes go through :meth:`RelationalDB.update_attrs`, which
returns an :class:`AttrDelta` carrying the exact ``(entity-type, attribute)``
dependency tags (:meth:`AttrDelta.dep_tags`) the cache layers key their
attribute dependency dimension on.

The synthetic generator plants real statistical dependencies (attribute values
correlated along edges) so that structure search has signal to find, and lets
benchmarks dial ``rows`` up to the paper's Visual Genome scale (15.8M rows).
"""

from __future__ import annotations

import warnings
import zlib
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .schema import Attribute, EntityType, Relationship, Schema


@dataclass
class EntityTable:
    type: EntityType
    attrs: Dict[str, np.ndarray]      # name -> int32[size]

    @property
    def size(self) -> int:
        return self.type.size


@dataclass
class RelationTable:
    type: Relationship
    src: np.ndarray                   # int32[m] indices into src entity table
    dst: np.ndarray                   # int32[m]
    attrs: Dict[str, np.ndarray]      # name -> int32[m]

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def nbytes(self) -> int:
        """Byte footprint of the edge list + attribute columns (the unit of
        the replication heuristic in :func:`shard_database`)."""
        return int(self.src.nbytes) + int(self.dst.nbytes) + sum(
            int(c.nbytes) for c in self.attrs.values())

    def pair_set(self) -> set:
        """The ``(src, dst)`` pairs as a python set — convenient for
        tests/benchmarks sampling fresh pairs.  The write paths use the
        vectorized :func:`_pair_codes` membership checks instead (a
        python set over millions of edges is not a per-write cost)."""
        return set(zip(self.src.tolist(), self.dst.tolist()))


def _pair_codes(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Pack (src, dst) index pairs into int64 codes — the vectorized
    membership structure the write paths validate against (entity ids
    are int32, so the pair fits a shifted int64 exactly)."""
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


@dataclass(frozen=True)
class FactDelta:
    """One batch of relationship-fact writes, as applied.

    ``op`` is ``"insert"`` or ``"delete"``; ``src``/``dst``/``attrs`` hold
    the exact edges that changed (for deletes, the attribute values are the
    ones the removed edges carried — delta count maintenance needs them to
    subtract the right cells).  ``old_version``/``new_version`` bracket the
    store's version bump, so cache layers can reject out-of-order
    application.
    """

    rel: str
    op: str                           # "insert" | "delete"
    src: np.ndarray
    dst: np.ndarray
    attrs: Dict[str, np.ndarray]
    old_version: int
    new_version: int

    @property
    def sign(self) -> int:
        """+1 for inserts, -1 for deletes — the coefficient a cached count
        table adds the delta-edge count with."""
        return 1 if self.op == "insert" else -1

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def as_db(self, db: "RelationalDB") -> "RelationalDB":
        """A *delta view* of ``db``: the same schema/entity tables (shared,
        zero copy) with ``rel``'s table replaced by JUST the delta edges.
        Counting a pattern on this view yields exactly the delta's
        contribution to the pattern's count (positive counts are linear in
        each relationship's edge multiset), which is what the engine's
        delta path adds/subtracts onto cached tables."""
        tab = db.relations[self.rel]
        relations = dict(db.relations)
        relations[self.rel] = RelationTable(tab.type, self.src, self.dst,
                                            dict(self.attrs))
        return RelationalDB(db.schema, db.entities, relations,
                            version=db.version)


@dataclass(frozen=True)
class AttrDelta:
    """One batch of entity-attribute writes, as applied.

    ``rows`` are the entity ids whose attribute columns changed;
    ``old``/``new`` hold the per-attribute value columns before and after
    the write (aligned with ``rows``), so cache layers can reason about
    exactly which ``(entity-type, attribute)`` pairs moved and rollback /
    oracle tests can reconstruct either side.  Like :class:`FactDelta`,
    ``old_version``/``new_version`` bracket the store's version bump so
    stale deltas are rejected instead of silently misapplied.
    """

    etype: str
    rows: np.ndarray                  # int32[k] entity ids
    old: Dict[str, np.ndarray]        # attr name -> int32[k] previous values
    new: Dict[str, np.ndarray]        # attr name -> int32[k] written values
    old_version: int
    new_version: int

    @property
    def num_rows(self) -> int:
        return int(self.rows.shape[0])

    @property
    def attrs(self) -> Tuple[str, ...]:
        return tuple(sorted(self.new))

    def dep_tags(self) -> frozenset:
        """Dependency tags this delta touches, in the cache's mixed
        dependency vocabulary: one precise ``("attr", etype, name)`` tag
        per written attribute plus the ``("attr*", etype)`` wildcard that
        keys which cannot enumerate their attribute names depend on (see
        :func:`repro.core.engine.key_deps`)."""
        tags = {("attr", self.etype, name) for name in self.new}
        tags.add(("attr*", self.etype))
        return frozenset(tags)


@dataclass
class RelationalDB:
    schema: Schema
    entities: Dict[str, EntityTable]
    relations: Dict[str, RelationTable]
    version: int = 0                  # bumped by every applied Fact/AttrDelta

    @property
    def total_rows(self) -> int:
        """Total data facts, comparable to the paper's Table 4 row counts."""
        n = sum(t.size for t in self.entities.values())
        n += sum(t.num_edges for t in self.relations.values())
        return n

    # -- mutable store ------------------------------------------------------
    def _check_new_edges(self, rel: str, src: np.ndarray, dst: np.ndarray,
                         attrs: Dict[str, np.ndarray]) -> None:
        tab = self.relations[rel]
        rt = tab.type
        ns, nd = self.entities[rt.src].size, self.entities[rt.dst].size
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError("src/dst must be aligned 1-D index arrays")
        if src.size:
            if src.min() < 0 or src.max() >= ns:
                raise ValueError(f"src index out of range for {rt.src!r}")
            if dst.min() < 0 or dst.max() >= nd:
                raise ValueError(f"dst index out of range for {rt.dst!r}")
        want = {a.name for a in rt.attrs}
        if set(attrs) != want:
            raise ValueError(f"attrs for {rel!r} must provide exactly "
                             f"{sorted(want)}, got {sorted(attrs)}")
        for a in rt.attrs:
            col = attrs[a.name]
            if col.shape != src.shape:
                raise ValueError(f"attr {a.name!r} not aligned with edges")
            if col.size and (col.min() < 0 or col.max() >= a.card):
                raise ValueError(f"attr {a.name!r} value out of range")
        codes = _pair_codes(src, dst)
        if np.unique(codes).size != codes.size:
            raise ValueError(f"duplicate (src, dst) pairs within the batch "
                             f"for {rel!r}")
        dup = np.isin(codes, _pair_codes(tab.src, tab.dst))
        if dup.any():
            existing = sorted(zip(src[dup].tolist(), dst[dup].tolist()))
            raise ValueError(f"edges already present in {rel!r}: "
                             f"{existing[:5]}")

    def insert_facts(self, rel: str, src, dst,
                     attrs: Optional[Mapping[str, np.ndarray]] = None
                     ) -> Optional[FactDelta]:
        """Append a batch of edges to relationship ``rel``; bumps
        ``version`` and returns the applied :class:`FactDelta` (``None``
        for an empty batch — no version bump, nothing to reconcile).

        Args:
            rel: relationship name.
            src / dst: aligned ``int`` index arrays into the endpoint
                entity tables.  ``(src, dst)`` pairs must be new — tables
                are keyed by the pair.
            attrs: one aligned value column per edge attribute of ``rel``
                (required iff the relationship has edge attributes).

        Raises:
            KeyError: unknown relationship.
            ValueError: misaligned/out-of-range arrays, missing or extra
                attribute columns, or duplicate pairs.

        Usage::

            delta = db.insert_facts("Rated", [3, 7], [1, 1],
                                    {"rating": [2, 0]})
        """
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        attrs = {k: np.asarray(v, dtype=np.int32)
                 for k, v in (attrs or {}).items()}
        if src.size == 0:
            return None
        self._check_new_edges(rel, src, dst, attrs)
        tab = self.relations[rel]
        tab.src = np.concatenate([tab.src, src])
        tab.dst = np.concatenate([tab.dst, dst])
        for name in tab.attrs:
            tab.attrs[name] = np.concatenate([tab.attrs[name], attrs[name]])
        old, self.version = self.version, self.version + 1
        return FactDelta(rel, "insert", src, dst, attrs, old, self.version)

    def delete_facts(self, rel: str, src, dst) -> Optional[FactDelta]:
        """Remove a batch of edges (matched by ``(src, dst)`` pair) from
        relationship ``rel``; bumps ``version`` and returns the applied
        :class:`FactDelta`, whose ``attrs`` capture the attribute values
        the removed edges carried (``None`` for an empty batch).

        Raises:
            KeyError: unknown relationship.
            ValueError: a requested pair is not present (or is requested
                twice).

        Usage::

            delta = db.delete_facts("Rated", [3], [1])
        """
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError("src/dst must be aligned 1-D index arrays")
        if src.size == 0:
            return None
        tab = self.relations[rel]
        want = _pair_codes(src, dst)
        if np.unique(want).size != want.size:
            raise ValueError(f"duplicate (src, dst) pairs in delete batch "
                             f"for {rel!r}")
        codes = _pair_codes(tab.src, tab.dst)
        mask = np.isin(codes, want)
        if int(mask.sum()) != want.size:
            gone = ~np.isin(want, codes)
            missing = sorted(zip(src[gone].tolist(), dst[gone].tolist()))
            raise ValueError(f"edges not present in {rel!r}: "
                             f"{missing[:5]}")
        removed_attrs = {name: col[mask] for name, col in tab.attrs.items()}
        removed_src, removed_dst = tab.src[mask], tab.dst[mask]
        tab.src, tab.dst = tab.src[~mask], tab.dst[~mask]
        for name in tab.attrs:
            tab.attrs[name] = tab.attrs[name][~mask]
        old, self.version = self.version, self.version + 1
        return FactDelta(rel, "delete", removed_src, removed_dst,
                         removed_attrs, old, self.version)

    def update_attrs(self, etype: str, rows,
                     attrs: Mapping[str, np.ndarray]
                     ) -> Optional[AttrDelta]:
        """Overwrite attribute values for a batch of entities of type
        ``etype``; bumps ``version`` and returns the applied
        :class:`AttrDelta` (``None`` for an empty batch — no version bump).

        Args:
            etype: entity-type name.
            rows: entity ids (row indices) to write; duplicates within the
                batch are rejected (the old-value capture would be
                ambiguous).
            attrs: one aligned value column per attribute to write — a
                subset of the type's attributes is fine, untouched columns
                keep their values.

        Raises:
            KeyError: unknown entity type.
            ValueError: empty ``attrs``, unknown attribute, misaligned or
                out-of-range arrays, or duplicate rows in the batch.

        Usage::

            delta = db.update_attrs("user", [3, 7], {"age": [1, 2]})
        """
        tab = self.entities[etype]
        rows = np.asarray(rows, dtype=np.int32)
        attrs = {k: np.asarray(v, dtype=np.int32) for k, v in attrs.items()}
        if rows.ndim != 1:
            raise ValueError("rows must be a 1-D index array")
        if rows.size == 0:
            return None
        if not attrs:
            raise ValueError("update_attrs needs at least one attribute "
                             "column")
        if rows.min() < 0 or rows.max() >= tab.size:
            raise ValueError(f"row index out of range for {etype!r}")
        if np.unique(rows).size != rows.size:
            raise ValueError(f"duplicate rows in update batch for {etype!r}")
        cards = {a.name: a.card for a in tab.type.attrs}
        for name, col in attrs.items():
            if name not in cards:
                raise ValueError(f"unknown attribute {name!r} for {etype!r}")
            if col.shape != rows.shape:
                raise ValueError(f"attr {name!r} not aligned with rows")
            if col.min() < 0 or col.max() >= cards[name]:
                raise ValueError(f"attr {name!r} value out of range")
        old_vals = {name: tab.attrs[name][rows].copy() for name in attrs}
        for name, col in attrs.items():
            tab.attrs[name][rows] = col
        old, self.version = self.version, self.version + 1
        return AttrDelta(etype, rows, old_vals, attrs, old, self.version)

    def validate(self) -> None:
        self.schema.validate()
        for name, tab in self.entities.items():
            et = tab.type
            for a in et.attrs:
                col = tab.attrs[a.name]
                assert col.shape == (et.size,), (name, a.name)
                assert col.min() >= 0 and col.max() < a.card
        for name, tab in self.relations.items():
            rt = tab.type
            ns, nd = self.entities[rt.src].size, self.entities[rt.dst].size
            if tab.num_edges:       # empty relationship tables are legal
                assert tab.src.min() >= 0 and tab.src.max() < ns
                assert tab.dst.min() >= 0 and tab.dst.max() < nd
            for a in rt.attrs:
                col = tab.attrs[a.name]
                assert col.shape == tab.src.shape
                if col.size:
                    assert col.min() >= 0 and col.max() < a.card


def synth_db(schema: Schema,
             edges_per_rel: Mapping[str, int],
             seed: int = 0,
             correlation: float = 0.7) -> RelationalDB:
    """Generate a database with planted dependencies.

    ``correlation`` controls how strongly edge attributes depend on the
    endpoint entity attributes (0 = independent, 1 = deterministic), giving
    structure search a recoverable ground truth.
    """
    rng = np.random.default_rng(seed)
    entities: Dict[str, EntityTable] = {}
    for et in schema.entities:
        cols = {a.name: rng.integers(0, a.card, size=et.size, dtype=np.int32)
                for a in et.attrs}
        entities[et.name] = EntityTable(et, cols)

    relations: Dict[str, RelationTable] = {}
    for rt in schema.relationships:
        m = int(edges_per_rel[rt.name])
        ns = schema.entity(rt.src).size
        nd = schema.entity(rt.dst).size
        # unique (src, dst) pairs: relationship tables are keyed by the pair,
        # so the indicator R(x, y) is well defined (see mobius.py).
        over = rng.integers(0, ns * nd, size=min(int(m * 1.3) + 8, ns * nd),
                            dtype=np.int64)
        over = np.unique(over)
        rng.shuffle(over)
        over = over[:m]
        src = (over // nd).astype(np.int32)
        dst = (over % nd).astype(np.int32)
        if rt.is_self:
            # avoid self loops for realism
            keep = src != dst
            src, dst = src[keep], dst[keep]
        m = src.shape[0]
        cols: Dict[str, np.ndarray] = {}
        # plant: edge attr correlates with (src attr0 + dst attr0) mod card
        s_anchor = (entities[rt.src].attrs[schema.entity(rt.src).attrs[0].name][src]
                    if schema.entity(rt.src).attrs else np.zeros(m, np.int32))
        d_anchor = (entities[rt.dst].attrs[schema.entity(rt.dst).attrs[0].name][dst]
                    if schema.entity(rt.dst).attrs else np.zeros(m, np.int32))
        for a in rt.attrs:
            noise = rng.integers(0, a.card, size=m, dtype=np.int32)
            signal = ((s_anchor + d_anchor) % a.card).astype(np.int32)
            pick = rng.random(m) < correlation
            cols[a.name] = np.where(pick, signal, noise).astype(np.int32)
        relations[rt.name] = RelationTable(rt, src, dst, cols)

    db = RelationalDB(schema, entities, relations)
    db.validate()
    return db


# ---------------------------------------------------------------------------
# Horizontal partitioning: ShardedDatabase
# ---------------------------------------------------------------------------

class NotRoutableError(ValueError):
    """A counting query cannot be answered by fan-out + count addition over
    the shards of a :class:`ShardedDatabase` (see
    :meth:`ShardedDatabase.route` for the exact condition)."""


def _shard_hash(ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Deterministic multiplicative hash of entity ids onto shard indices
    (Knuth's 2654435761 mod 2^32) — stable across processes and platforms,
    unlike Python's salted ``hash``."""
    h = (ids.astype(np.int64) * 2654435761) & 0xFFFFFFFF
    return (h % n_shards).astype(np.int64)


def _route_key(point) -> int:
    """Stable small hash of a lattice point, used only to spread
    replicated-only queries across shards."""
    return zlib.crc32(str(point).encode())


@dataclass
class ShardedDatabase:
    """A horizontally partitioned :class:`RelationalDB`.

    Every shard is itself a complete, valid ``RelationalDB`` over the SAME
    schema and the SAME entity-id space:

    * **entity tables are replicated** on every shard (they are the small
      attribute tables — ``n_entities`` rows each — and replication keeps
      every edge index valid everywhere);
    * **relationship tables incident to ``root_etype``** are
      hash-partitioned by the ``root_etype`` endpoint of each edge
      (``src`` for self-relationships): every edge lives on exactly one
      shard, and all edges touching the same root entity live together;
    * **other relationship tables are replicated** (every shard sees every
      edge), subject to the size heuristic in :func:`shard_database`.

    Partition assignment goes through a level of indirection: root-entity
    ids hash onto ``n_buckets`` fixed **buckets** and ``bucket_map`` sends
    each bucket to a shard.  The bucket space never changes, so
    :meth:`split_shard` rebalances a hot shard by *moving buckets* — only
    that shard's rows move, every other shard's data (and caches) stay
    untouched.

    Positive-count queries are answered by running the ordinary counting
    stack per shard and merging tables at a front-end
    (:class:`repro.serve.router.CountingRouter`); :meth:`route` decides,
    per query, whether the merge is a fan-out **sum** or a **single-shard**
    lookup.  Use :func:`shard_database` to build one.

    Usage::

        sdb = shard_database(db, n_shards=4)
        assert sdb.route(point)[0] in ("fanout", "single")
    """

    schema: Schema
    shards: Tuple[RelationalDB, ...]
    root_etype: str
    partitioned: frozenset = field(default_factory=frozenset)  # rel names
    n_buckets: int = 0                 # 0 = legacy 1-bucket-per-shard
    bucket_map: Tuple[int, ...] = ()   # bucket -> shard index

    def __post_init__(self) -> None:
        if not self.bucket_map:        # direct construction: identity map
            self.n_buckets = self.n_buckets or len(self.shards)
            self.bucket_map = tuple(b % len(self.shards)
                                    for b in range(self.n_buckets))

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of_ids(self, ids: np.ndarray) -> np.ndarray:
        """Shard index of each root-entity id (hash -> bucket -> shard)."""
        buckets = _shard_hash(np.asarray(ids), self.n_buckets)
        return np.asarray(self.bucket_map, dtype=np.int64)[buckets]

    def partitioned_rows(self, shard_id: int) -> int:
        """Rows of partitioned relationship tables living on one shard —
        the size the rebalancing threshold watches (replicated tables are
        everywhere, so they don't distinguish shards)."""
        shard = self.shards[shard_id]
        return sum(shard.relations[r].num_edges for r in self.partitioned)

    # -- writes --------------------------------------------------------------
    def _key_ids(self, rel: str, src: np.ndarray,
                 dst: np.ndarray) -> np.ndarray:
        rt = self.schema.relationship(rel)
        return src if rt.src == self.root_etype else dst

    def insert_facts(self, rel: str, src, dst,
                     attrs: Optional[Mapping[str, np.ndarray]] = None
                     ) -> List[Optional[FactDelta]]:
        """Apply one insert batch across the shards.

        Partitioned relationships: each edge goes to the shard its
        root-entity endpoint hashes to (same assignment as
        :func:`shard_database`).  Replicated relationships: the shared
        table is mutated ONCE and every shard's version bumps.

        Returns:
            One entry per shard, aligned with ``shards``: the
            :class:`FactDelta` that shard must reconcile, or ``None`` when
            the shard received no edges (its data — and caches — are
            untouched).

        Usage::

            deltas = sdb.insert_facts("Rated", src, dst, {"rating": vals})
        """
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        attrs = {k: np.asarray(v, dtype=np.int32)
                 for k, v in (attrs or {}).items()}
        if rel not in self.partitioned:
            return self._apply_replicated(rel, "insert", src, dst, attrs)
        assign = self.shard_of_ids(self._key_ids(rel, src, dst))
        out: List[Optional[FactDelta]] = []
        for s, shard in enumerate(self.shards):
            m = assign == s
            if not m.any():
                out.append(None)
                continue
            out.append(shard.insert_facts(
                rel, src[m], dst[m], {k: v[m] for k, v in attrs.items()}))
        return out

    def delete_facts(self, rel: str, src, dst) -> List[Optional[FactDelta]]:
        """Apply one delete batch across the shards (edges matched by
        ``(src, dst)`` pair; see :meth:`insert_facts` for the routing and
        return convention)."""
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        if rel not in self.partitioned:
            return self._apply_replicated(rel, "delete", src, dst, {})
        assign = self.shard_of_ids(self._key_ids(rel, src, dst))
        out: List[Optional[FactDelta]] = []
        for s, shard in enumerate(self.shards):
            m = assign == s
            out.append(shard.delete_facts(rel, src[m], dst[m])
                       if m.any() else None)
        return out

    def update_attrs(self, etype: str, rows,
                     attrs: Mapping[str, np.ndarray]
                     ) -> List[Optional[AttrDelta]]:
        """Apply one entity-attribute write batch across the shards.

        Entity tables are SHARED objects replicated to every shard, so the
        columns are mutated ONCE (through shard 0) and every shard's
        version bumps; each shard gets an equivalent :class:`AttrDelta`
        with its own version bracket (same convention as replicated
        relationship writes)."""
        first = self.shards[0].update_attrs(etype, rows, attrs)
        if first is None:
            return [None] * self.n_shards
        out: List[Optional[AttrDelta]] = [first]
        for shard in self.shards[1:]:
            old, shard.version = shard.version, shard.version + 1
            out.append(_dc_replace(first, old_version=old,
                                   new_version=shard.version))
        return out

    def _apply_replicated(self, rel: str, op: str, src: np.ndarray,
                          dst: np.ndarray, attrs: Dict[str, np.ndarray]
                          ) -> List[Optional[FactDelta]]:
        """Replicated tables are SHARED objects: mutate through shard 0,
        then bump the other shards' versions and hand each an equivalent
        delta (same edges, that shard's version bracket)."""
        first = (self.shards[0].insert_facts(rel, src, dst, attrs)
                 if op == "insert"
                 else self.shards[0].delete_facts(rel, src, dst))
        if first is None:
            return [None] * self.n_shards
        out: List[Optional[FactDelta]] = [first]
        for shard in self.shards[1:]:
            old, shard.version = shard.version, shard.version + 1
            out.append(_dc_replace(first, old_version=old,
                                   new_version=shard.version))
        return out

    # -- online rebalancing --------------------------------------------------
    def split_shard(self, shard_id: int) -> "ShardedDatabase":
        """Split one shard by moving half of its hash buckets to a NEW
        shard (index ``n_shards``), re-partitioning only that shard's
        relationship tables.

        The receiver (``self``) is left untouched — in-flight queries
        against the old shard set stay consistent; callers swap to the
        returned :class:`ShardedDatabase` atomically (see
        :meth:`repro.serve.router.CountingRouter.rebalance`).  Entity
        tables and replicated relationship tables are shared with the old
        generation, so a split moves only the partitioned rows of the one
        shard being split.

        Raises:
            IndexError: ``shard_id`` out of range.
            ValueError: the shard owns fewer than two buckets (nothing
                left to split; re-shard with a larger ``n_buckets``).

        Usage::

            sdb2 = sdb.split_shard(0)
            assert sdb2.n_shards == sdb.n_shards + 1
        """
        if not 0 <= shard_id < self.n_shards:
            raise IndexError(f"shard {shard_id} out of range")
        owned = [b for b, s in enumerate(self.bucket_map) if s == shard_id]
        if len(owned) < 2:
            raise ValueError(
                f"shard {shard_id} owns {len(owned)} bucket(s); cannot "
                f"split further (re-shard with a larger n_buckets)")
        new_idx = self.n_shards
        moving = set(owned[len(owned) // 2:])
        new_map = list(self.bucket_map)
        for b in moving:
            new_map[b] = new_idx
        old = self.shards[shard_id]
        keep_rels: Dict[str, RelationTable] = {}
        move_rels: Dict[str, RelationTable] = {}
        for name, tab in old.relations.items():
            if name not in self.partitioned:
                keep_rels[name] = tab          # replicated: shared reference
                move_rels[name] = tab
                continue
            key_ids = tab.src if tab.type.src == self.root_etype else tab.dst
            buckets = _shard_hash(np.asarray(key_ids), self.n_buckets)
            mv = np.isin(buckets, list(moving))
            move_rels[name] = RelationTable(
                tab.type, tab.src[mv], tab.dst[mv],
                {a: col[mv] for a, col in tab.attrs.items()})
            keep_rels[name] = RelationTable(
                tab.type, tab.src[~mv], tab.dst[~mv],
                {a: col[~mv] for a, col in tab.attrs.items()})
        shrunk = RelationalDB(self.schema, old.entities, keep_rels,
                              version=old.version)
        fresh = RelationalDB(self.schema, old.entities, move_rels,
                             version=old.version)
        shards = (self.shards[:shard_id] + (shrunk,)
                  + self.shards[shard_id + 1:] + (fresh,))
        return ShardedDatabase(self.schema, shards, self.root_etype,
                               self.partitioned, self.n_buckets,
                               tuple(new_map))

    def _partition_side_var(self, atom) -> "object":
        """The variable at the partition-key endpoint of a partitioned
        atom: the ``root_etype`` end of the relationship (``src`` wins for
        self-relationships, matching :func:`shard_database`)."""
        rel = self.schema.relationship(atom.rel)
        return atom.src if rel.src == self.root_etype else atom.dst

    def route(self, point) -> Tuple[str, Optional[int]]:
        """Decide how a positive-count query over ``point`` is answered.

        Per-shard counts sum to the true count exactly when every satisfied
        grounding finds ALL of its partitioned edges on one shard.  That
        holds in exactly two cases:

        * no atom of the point uses a partitioned relationship — every
          shard holds the full (replicated) data, so the query is answered
          by ONE shard (summing would over-count ``n_shards``-fold);
        * every partitioned atom touches the *same* first-order variable at
          its partition-key endpoint — that grounding value hashes all the
          edges of the grounding onto one shard, so fan-out + sum is exact.

        Args:
            point: a :class:`~repro.core.variables.LatticePoint`.

        Returns:
            ``("fanout", None)`` — query every shard, add the tables; or
            ``("single", shard_index)`` — query that one shard.

        Raises:
            NotRoutableError: partitioned atoms disagree on the
                partition-key variable (e.g. a chain entering the root
                entity type at two different variables); no additive
                merge over this partitioning exists.
        """
        part_atoms = [a for a in point.atoms if a.rel in self.partitioned]
        if not part_atoms:
            return ("single", _route_key(point) % self.n_shards)
        side_vars = {self._partition_side_var(a) for a in part_atoms}
        if len(side_vars) > 1:
            raise NotRoutableError(
                f"point {point} joins partitioned relationships "
                f"{sorted(a.rel for a in part_atoms)} at different "
                f"{self.root_etype!r} variables {sorted(map(str, side_vars))}; "
                f"per-shard counts are not additive under this partitioning "
                f"(re-shard with a different root_etype or replicate one "
                f"of the relationships)")
        return ("fanout", None)


def _replicated_bytes(db: RelationalDB, root_etype: str) -> int:
    """Bytes of relationship tables that would be REPLICATED to every
    shard under ``root_etype`` — the footprint the partition-side
    heuristic minimises."""
    return sum(tab.nbytes for name, tab in db.relations.items()
               if root_etype not in (tab.type.src, tab.type.dst))


def shard_database(db: RelationalDB, n_shards: int,
                   root_etype: Optional[str] = None,
                   n_buckets: Optional[int] = None,
                   max_replicated_bytes: int = 64 << 20,
                   on_oversized_replicated: str = "warn") -> ShardedDatabase:
    """Hash-partition ``db`` into ``n_shards`` complete sub-databases.

    Relationship tables incident to ``root_etype`` are split by the hash of
    their ``root_etype`` endpoint (the *root entity* of a counting query);
    entity tables and the remaining relationship tables are replicated —
    see :class:`ShardedDatabase` for the exact layout and the merge
    semantics it buys.  Assignment goes through ``n_buckets`` fixed hash
    buckets so :meth:`ShardedDatabase.split_shard` can later rebalance a
    hot shard by moving buckets instead of re-hashing the world.

    Args:
        db: the database to partition (left untouched; shards share its
            entity/replicated arrays and hold views of partitioned ones).
        n_shards: number of shards (>= 1).
        root_etype: entity type whose ids are the partition key.  Defaults
            to the **smaller-footprint partition side**: the incident type
            whose choice replicates the fewest relationship-table bytes
            (ties broken by incident-relationship count, entity size, then
            name).
        n_buckets: size of the fixed bucket space (defaults to
            ``max(64, 8 * n_shards)``); must be >= ``n_shards``.
        max_replicated_bytes: replication heuristic — a relationship table
            larger than this that would be replicated to every shard
            triggers ``on_oversized_replicated``.
        on_oversized_replicated: ``"warn"`` (default) emits a
            ``ResourceWarning``; ``"error"`` refuses with ``ValueError``
            (re-shard with a root type incident to that relationship);
            ``"ignore"`` replicates silently.

    Returns:
        A :class:`ShardedDatabase` whose shards each pass
        :meth:`RelationalDB.validate`.

    Raises:
        ValueError: ``n_shards < 1``, ``n_buckets < n_shards``,
            ``root_etype`` names no entity type / touches no relationship,
            or an oversized replicated table under ``"error"``.

    Usage::

        sdb = shard_database(paper_benchmark_db("UW"), n_shards=2)
        assert sum(s.relations["Registered"].num_edges
                   for s in sdb.shards) == db.relations["Registered"].num_edges
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n_buckets is None:
        n_buckets = max(64, 8 * n_shards)
    if n_buckets < n_shards:
        raise ValueError(f"n_buckets={n_buckets} < n_shards={n_shards}")
    incident: Dict[str, int] = {et.name: 0 for et in db.schema.entities}
    for rt in db.schema.relationships:
        incident[rt.src] += 1
        if rt.dst != rt.src:
            incident[rt.dst] += 1
    if root_etype is None:
        candidates = [n for n in incident if incident[n] > 0]
        if not candidates:
            raise ValueError("schema has no relationships to partition")
        root_etype = min(
            candidates,
            key=lambda n: (_replicated_bytes(db, n), -incident[n],
                           -db.schema.entity(n).size, n))
    elif root_etype not in incident:
        raise ValueError(f"unknown entity type {root_etype!r}")
    if incident[root_etype] == 0:
        raise ValueError(f"root_etype {root_etype!r} touches no relationship; "
                         f"nothing would be partitioned")

    partitioned = frozenset(rt.name for rt in db.schema.relationships
                            if root_etype in (rt.src, rt.dst))
    for name, tab in db.relations.items():
        if name in partitioned or tab.nbytes <= max_replicated_bytes:
            continue
        msg = (f"relationship {name!r} ({tab.nbytes} bytes) would be "
               f"replicated to every shard under root_etype="
               f"{root_etype!r} and exceeds max_replicated_bytes="
               f"{max_replicated_bytes}; re-shard with a root type "
               f"incident to it")
        if on_oversized_replicated == "error":
            raise ValueError(msg)
        if on_oversized_replicated == "warn":
            warnings.warn(msg, ResourceWarning, stacklevel=2)

    bucket_map = tuple(b % n_shards for b in range(n_buckets))
    bmap = np.asarray(bucket_map, dtype=np.int64)
    assign: Dict[str, np.ndarray] = {}         # hash each edge list once
    for name in partitioned:
        tab = db.relations[name]
        key_ids = tab.src if tab.type.src == root_etype else tab.dst
        assign[name] = bmap[_shard_hash(np.asarray(key_ids), n_buckets)]
    shards: List[RelationalDB] = []
    for s in range(n_shards):
        relations: Dict[str, RelationTable] = {}
        for name, tab in db.relations.items():
            if name not in partitioned:
                relations[name] = tab          # replicated: shared reference
                continue
            mask = assign[name] == s
            relations[name] = RelationTable(
                tab.type, tab.src[mask], tab.dst[mask],
                {a: col[mask] for a, col in tab.attrs.items()})
        shard = RelationalDB(db.schema, db.entities, relations)
        shard.validate()
        shards.append(shard)
    return ShardedDatabase(db.schema, tuple(shards), root_etype, partitioned,
                           n_buckets, bucket_map)


# ---------------------------------------------------------------------------
# Paper-benchmark synthetic stand-ins (Table 4 of the paper).
# Row counts mirror the published datasets; schema complexity (number of
# relationships / attribute counts) mirrors the published relationship counts.
# ---------------------------------------------------------------------------

def _uni_schema(n_students: int, n_courses: int, n_profs: int,
                a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("student", n_students, (att("intelligence"), att("ranking"))),
            EntityType("course", n_courses, (att("difficulty"), att("rating"))),
            EntityType("prof", n_profs, (att("popularity"), att("teachingability"))),
        ),
        relationships=(
            Relationship("Registered", "student", "course", (att("grade"), att("satisfaction"))),
            Relationship("RA", "prof", "student", (att("salary"), att("capability"))),
        ),
    )


def _movie_schema(n_users: int, n_movies: int, a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("user", n_users, (att("age"), att("gender"), att("occupation"))),
            EntityType("movie", n_movies, (att("year"), att("genre"))),
        ),
        relationships=(
            Relationship("Rated", "user", "movie", (att("rating"),)),
        ),
    )


def _generic_schema(name: str, n_rel: int, n_ent: int, ent_size: int,
                    n_attr: int = 2, a_card: int = 3) -> Schema:
    """A connected schema with ``n_rel`` relationships over ``n_ent`` types."""
    att = lambda n: Attribute(n, a_card)
    ents = tuple(
        EntityType(f"{name}_e{i}", ent_size,
                   tuple(att(f"a{i}_{j}") for j in range(n_attr)))
        for i in range(n_ent)
    )
    rels = []
    for r in range(n_rel):
        s = r % n_ent
        d = (r + 1) % n_ent
        if s == d:
            d = (d + 1) % n_ent
        rels.append(Relationship(f"{name}_R{r}", ents[s].name, ents[d].name,
                                 (att(f"r{r}_a0"),)))
    return Schema(ents, tuple(rels))


# (name, builder) — row counts approximate the paper's Table 4.
def paper_benchmark_db(name: str, seed: int = 0, scale: float = 1.0) -> RelationalDB:
    """Synthetic stand-ins for the paper's 8 databases, matched on total rows
    and relationship count (Table 4).  ``scale`` shrinks them for tests."""
    s = lambda n: max(8, int(n * scale))
    if name == "UW":              # 712 rows, 2 rels
        sch = _uni_schema(s(180), s(140), s(40))
        edges = {"Registered": s(250), "RA": s(100)}
    elif name == "Mondial":       # 870 rows, 2 rels
        sch = _generic_schema("mon", 2, 3, s(120), n_attr=4, a_card=4)
        edges = {"mon_R0": s(300), "mon_R1": s(200)}
    elif name == "Hepatitis":     # 12,927 rows, 3 rels
        sch = _generic_schema("hep", 3, 3, s(1500), n_attr=3, a_card=4)
        edges = {"hep_R0": s(3000), "hep_R1": s(3000), "hep_R2": s(2400)}
    elif name == "Mutagenesis":   # 14,540 rows, 2 rels
        sch = _generic_schema("mut", 2, 2, s(2500), n_attr=2, a_card=3)
        edges = {"mut_R0": s(6000), "mut_R1": s(3500)}
    elif name == "MovieLens":     # 74,402 rows, 1 rel
        sch = _movie_schema(s(941), s(1682))
        edges = {"Rated": s(71779)}
    elif name == "Financial":     # 225,887 rows, 3 rels
        sch = _generic_schema("fin", 3, 3, s(15000), n_attr=3, a_card=4)
        edges = {"fin_R0": s(80000), "fin_R1": s(60000), "fin_R2": s(40000)}
    elif name == "IMDb":          # 1,063,559 rows, 3 rels
        sch = _generic_schema("imdb", 3, 3, s(100000), n_attr=3, a_card=3)
        edges = {"imdb_R0": s(400000), "imdb_R1": s(250000), "imdb_R2": s(113000)}
    elif name == "VisualGenome":  # 15,833,273 rows, 8 rels
        sch = _generic_schema("vg", 8, 4, s(200000), n_attr=1, a_card=3)
        edges = {f"vg_R{i}": s(1900000) for i in range(8)}
    else:
        raise KeyError(name)
    return synth_db(sch, edges, seed=seed)


PAPER_DATASETS = ("UW", "Mondial", "Hepatitis", "Mutagenesis", "MovieLens",
                  "Financial", "IMDb", "VisualGenome")
