"""Integer-coded relational database + synthetic generators.

A :class:`RelationalDB` is the TPU-native stand-in for the paper's MariaDB
input: every entity table is a dict of ``int32[n]`` attribute columns and every
relationship table is an edge list ``(src int32[m], dst int32[m])`` plus
``int32[m]`` edge-attribute columns.  All shapes are static; counting never
needs dynamic shapes.

The synthetic generator plants real statistical dependencies (attribute values
correlated along edges) so that structure search has signal to find, and lets
benchmarks dial ``rows`` up to the paper's Visual Genome scale (15.8M rows).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Tuple

import numpy as np

from .schema import Attribute, EntityType, Relationship, Schema


@dataclass
class EntityTable:
    type: EntityType
    attrs: Dict[str, np.ndarray]      # name -> int32[size]

    @property
    def size(self) -> int:
        return self.type.size


@dataclass
class RelationTable:
    type: Relationship
    src: np.ndarray                   # int32[m] indices into src entity table
    dst: np.ndarray                   # int32[m]
    attrs: Dict[str, np.ndarray]      # name -> int32[m]

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])


@dataclass
class RelationalDB:
    schema: Schema
    entities: Dict[str, EntityTable]
    relations: Dict[str, RelationTable]

    @property
    def total_rows(self) -> int:
        """Total data facts, comparable to the paper's Table 4 row counts."""
        n = sum(t.size for t in self.entities.values())
        n += sum(t.num_edges for t in self.relations.values())
        return n

    def validate(self) -> None:
        self.schema.validate()
        for name, tab in self.entities.items():
            et = tab.type
            for a in et.attrs:
                col = tab.attrs[a.name]
                assert col.shape == (et.size,), (name, a.name)
                assert col.min() >= 0 and col.max() < a.card
        for name, tab in self.relations.items():
            rt = tab.type
            ns, nd = self.entities[rt.src].size, self.entities[rt.dst].size
            if tab.num_edges:       # empty relationship tables are legal
                assert tab.src.min() >= 0 and tab.src.max() < ns
                assert tab.dst.min() >= 0 and tab.dst.max() < nd
            for a in rt.attrs:
                col = tab.attrs[a.name]
                assert col.shape == tab.src.shape
                if col.size:
                    assert col.min() >= 0 and col.max() < a.card


def synth_db(schema: Schema,
             edges_per_rel: Mapping[str, int],
             seed: int = 0,
             correlation: float = 0.7) -> RelationalDB:
    """Generate a database with planted dependencies.

    ``correlation`` controls how strongly edge attributes depend on the
    endpoint entity attributes (0 = independent, 1 = deterministic), giving
    structure search a recoverable ground truth.
    """
    rng = np.random.default_rng(seed)
    entities: Dict[str, EntityTable] = {}
    for et in schema.entities:
        cols = {a.name: rng.integers(0, a.card, size=et.size, dtype=np.int32)
                for a in et.attrs}
        entities[et.name] = EntityTable(et, cols)

    relations: Dict[str, RelationTable] = {}
    for rt in schema.relationships:
        m = int(edges_per_rel[rt.name])
        ns = schema.entity(rt.src).size
        nd = schema.entity(rt.dst).size
        # unique (src, dst) pairs: relationship tables are keyed by the pair,
        # so the indicator R(x, y) is well defined (see mobius.py).
        over = rng.integers(0, ns * nd, size=min(int(m * 1.3) + 8, ns * nd),
                            dtype=np.int64)
        over = np.unique(over)
        rng.shuffle(over)
        over = over[:m]
        src = (over // nd).astype(np.int32)
        dst = (over % nd).astype(np.int32)
        if rt.is_self:
            # avoid self loops for realism
            keep = src != dst
            src, dst = src[keep], dst[keep]
        m = src.shape[0]
        cols: Dict[str, np.ndarray] = {}
        # plant: edge attr correlates with (src attr0 + dst attr0) mod card
        s_anchor = (entities[rt.src].attrs[schema.entity(rt.src).attrs[0].name][src]
                    if schema.entity(rt.src).attrs else np.zeros(m, np.int32))
        d_anchor = (entities[rt.dst].attrs[schema.entity(rt.dst).attrs[0].name][dst]
                    if schema.entity(rt.dst).attrs else np.zeros(m, np.int32))
        for a in rt.attrs:
            noise = rng.integers(0, a.card, size=m, dtype=np.int32)
            signal = ((s_anchor + d_anchor) % a.card).astype(np.int32)
            pick = rng.random(m) < correlation
            cols[a.name] = np.where(pick, signal, noise).astype(np.int32)
        relations[rt.name] = RelationTable(rt, src, dst, cols)

    db = RelationalDB(schema, entities, relations)
    db.validate()
    return db


# ---------------------------------------------------------------------------
# Paper-benchmark synthetic stand-ins (Table 4 of the paper).
# Row counts mirror the published datasets; schema complexity (number of
# relationships / attribute counts) mirrors the published relationship counts.
# ---------------------------------------------------------------------------

def _uni_schema(n_students: int, n_courses: int, n_profs: int,
                a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("student", n_students, (att("intelligence"), att("ranking"))),
            EntityType("course", n_courses, (att("difficulty"), att("rating"))),
            EntityType("prof", n_profs, (att("popularity"), att("teachingability"))),
        ),
        relationships=(
            Relationship("Registered", "student", "course", (att("grade"), att("satisfaction"))),
            Relationship("RA", "prof", "student", (att("salary"), att("capability"))),
        ),
    )


def _movie_schema(n_users: int, n_movies: int, a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("user", n_users, (att("age"), att("gender"), att("occupation"))),
            EntityType("movie", n_movies, (att("year"), att("genre"))),
        ),
        relationships=(
            Relationship("Rated", "user", "movie", (att("rating"),)),
        ),
    )


def _generic_schema(name: str, n_rel: int, n_ent: int, ent_size: int,
                    n_attr: int = 2, a_card: int = 3) -> Schema:
    """A connected schema with ``n_rel`` relationships over ``n_ent`` types."""
    att = lambda n: Attribute(n, a_card)
    ents = tuple(
        EntityType(f"{name}_e{i}", ent_size,
                   tuple(att(f"a{i}_{j}") for j in range(n_attr)))
        for i in range(n_ent)
    )
    rels = []
    for r in range(n_rel):
        s = r % n_ent
        d = (r + 1) % n_ent
        if s == d:
            d = (d + 1) % n_ent
        rels.append(Relationship(f"{name}_R{r}", ents[s].name, ents[d].name,
                                 (att(f"r{r}_a0"),)))
    return Schema(ents, tuple(rels))


# (name, builder) — row counts approximate the paper's Table 4.
def paper_benchmark_db(name: str, seed: int = 0, scale: float = 1.0) -> RelationalDB:
    """Synthetic stand-ins for the paper's 8 databases, matched on total rows
    and relationship count (Table 4).  ``scale`` shrinks them for tests."""
    s = lambda n: max(8, int(n * scale))
    if name == "UW":              # 712 rows, 2 rels
        sch = _uni_schema(s(180), s(140), s(40))
        edges = {"Registered": s(250), "RA": s(100)}
    elif name == "Mondial":       # 870 rows, 2 rels
        sch = _generic_schema("mon", 2, 3, s(120), n_attr=4, a_card=4)
        edges = {"mon_R0": s(300), "mon_R1": s(200)}
    elif name == "Hepatitis":     # 12,927 rows, 3 rels
        sch = _generic_schema("hep", 3, 3, s(1500), n_attr=3, a_card=4)
        edges = {"hep_R0": s(3000), "hep_R1": s(3000), "hep_R2": s(2400)}
    elif name == "Mutagenesis":   # 14,540 rows, 2 rels
        sch = _generic_schema("mut", 2, 2, s(2500), n_attr=2, a_card=3)
        edges = {"mut_R0": s(6000), "mut_R1": s(3500)}
    elif name == "MovieLens":     # 74,402 rows, 1 rel
        sch = _movie_schema(s(941), s(1682))
        edges = {"Rated": s(71779)}
    elif name == "Financial":     # 225,887 rows, 3 rels
        sch = _generic_schema("fin", 3, 3, s(15000), n_attr=3, a_card=4)
        edges = {"fin_R0": s(80000), "fin_R1": s(60000), "fin_R2": s(40000)}
    elif name == "IMDb":          # 1,063,559 rows, 3 rels
        sch = _generic_schema("imdb", 3, 3, s(100000), n_attr=3, a_card=3)
        edges = {"imdb_R0": s(400000), "imdb_R1": s(250000), "imdb_R2": s(113000)}
    elif name == "VisualGenome":  # 15,833,273 rows, 8 rels
        sch = _generic_schema("vg", 8, 4, s(200000), n_attr=1, a_card=3)
        edges = {f"vg_R{i}": s(1900000) for i in range(8)}
    else:
        raise KeyError(name)
    return synth_db(sch, edges, seed=seed)


PAPER_DATASETS = ("UW", "Mondial", "Hepatitis", "Mutagenesis", "MovieLens",
                  "Financial", "IMDb", "VisualGenome")
