"""Integer-coded relational database + synthetic generators.

A :class:`RelationalDB` is the TPU-native stand-in for the paper's MariaDB
input: every entity table is a dict of ``int32[n]`` attribute columns and every
relationship table is an edge list ``(src int32[m], dst int32[m])`` plus
``int32[m]`` edge-attribute columns.  All shapes are static; counting never
needs dynamic shapes.

The synthetic generator plants real statistical dependencies (attribute values
correlated along edges) so that structure search has signal to find, and lets
benchmarks dial ``rows`` up to the paper's Visual Genome scale (15.8M rows).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .schema import Attribute, EntityType, Relationship, Schema


@dataclass
class EntityTable:
    type: EntityType
    attrs: Dict[str, np.ndarray]      # name -> int32[size]

    @property
    def size(self) -> int:
        return self.type.size


@dataclass
class RelationTable:
    type: Relationship
    src: np.ndarray                   # int32[m] indices into src entity table
    dst: np.ndarray                   # int32[m]
    attrs: Dict[str, np.ndarray]      # name -> int32[m]

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])


@dataclass
class RelationalDB:
    schema: Schema
    entities: Dict[str, EntityTable]
    relations: Dict[str, RelationTable]

    @property
    def total_rows(self) -> int:
        """Total data facts, comparable to the paper's Table 4 row counts."""
        n = sum(t.size for t in self.entities.values())
        n += sum(t.num_edges for t in self.relations.values())
        return n

    def validate(self) -> None:
        self.schema.validate()
        for name, tab in self.entities.items():
            et = tab.type
            for a in et.attrs:
                col = tab.attrs[a.name]
                assert col.shape == (et.size,), (name, a.name)
                assert col.min() >= 0 and col.max() < a.card
        for name, tab in self.relations.items():
            rt = tab.type
            ns, nd = self.entities[rt.src].size, self.entities[rt.dst].size
            if tab.num_edges:       # empty relationship tables are legal
                assert tab.src.min() >= 0 and tab.src.max() < ns
                assert tab.dst.min() >= 0 and tab.dst.max() < nd
            for a in rt.attrs:
                col = tab.attrs[a.name]
                assert col.shape == tab.src.shape
                if col.size:
                    assert col.min() >= 0 and col.max() < a.card


def synth_db(schema: Schema,
             edges_per_rel: Mapping[str, int],
             seed: int = 0,
             correlation: float = 0.7) -> RelationalDB:
    """Generate a database with planted dependencies.

    ``correlation`` controls how strongly edge attributes depend on the
    endpoint entity attributes (0 = independent, 1 = deterministic), giving
    structure search a recoverable ground truth.
    """
    rng = np.random.default_rng(seed)
    entities: Dict[str, EntityTable] = {}
    for et in schema.entities:
        cols = {a.name: rng.integers(0, a.card, size=et.size, dtype=np.int32)
                for a in et.attrs}
        entities[et.name] = EntityTable(et, cols)

    relations: Dict[str, RelationTable] = {}
    for rt in schema.relationships:
        m = int(edges_per_rel[rt.name])
        ns = schema.entity(rt.src).size
        nd = schema.entity(rt.dst).size
        # unique (src, dst) pairs: relationship tables are keyed by the pair,
        # so the indicator R(x, y) is well defined (see mobius.py).
        over = rng.integers(0, ns * nd, size=min(int(m * 1.3) + 8, ns * nd),
                            dtype=np.int64)
        over = np.unique(over)
        rng.shuffle(over)
        over = over[:m]
        src = (over // nd).astype(np.int32)
        dst = (over % nd).astype(np.int32)
        if rt.is_self:
            # avoid self loops for realism
            keep = src != dst
            src, dst = src[keep], dst[keep]
        m = src.shape[0]
        cols: Dict[str, np.ndarray] = {}
        # plant: edge attr correlates with (src attr0 + dst attr0) mod card
        s_anchor = (entities[rt.src].attrs[schema.entity(rt.src).attrs[0].name][src]
                    if schema.entity(rt.src).attrs else np.zeros(m, np.int32))
        d_anchor = (entities[rt.dst].attrs[schema.entity(rt.dst).attrs[0].name][dst]
                    if schema.entity(rt.dst).attrs else np.zeros(m, np.int32))
        for a in rt.attrs:
            noise = rng.integers(0, a.card, size=m, dtype=np.int32)
            signal = ((s_anchor + d_anchor) % a.card).astype(np.int32)
            pick = rng.random(m) < correlation
            cols[a.name] = np.where(pick, signal, noise).astype(np.int32)
        relations[rt.name] = RelationTable(rt, src, dst, cols)

    db = RelationalDB(schema, entities, relations)
    db.validate()
    return db


# ---------------------------------------------------------------------------
# Horizontal partitioning: ShardedDatabase
# ---------------------------------------------------------------------------

class NotRoutableError(ValueError):
    """A counting query cannot be answered by fan-out + count addition over
    the shards of a :class:`ShardedDatabase` (see
    :meth:`ShardedDatabase.route` for the exact condition)."""


def _shard_hash(ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Deterministic multiplicative hash of entity ids onto shard indices
    (Knuth's 2654435761 mod 2^32) — stable across processes and platforms,
    unlike Python's salted ``hash``."""
    h = (ids.astype(np.int64) * 2654435761) & 0xFFFFFFFF
    return (h % n_shards).astype(np.int64)


def _route_key(point) -> int:
    """Stable small hash of a lattice point, used only to spread
    replicated-only queries across shards."""
    return zlib.crc32(str(point).encode())


@dataclass
class ShardedDatabase:
    """A horizontally partitioned :class:`RelationalDB`.

    Every shard is itself a complete, valid ``RelationalDB`` over the SAME
    schema and the SAME entity-id space:

    * **entity tables are replicated** on every shard (they are the small
      attribute tables — ``n_entities`` rows each — and replication keeps
      every edge index valid everywhere);
    * **relationship tables incident to ``root_etype``** are
      hash-partitioned by the ``root_etype`` endpoint of each edge
      (``src`` for self-relationships): every edge lives on exactly one
      shard, and all edges touching the same root entity live together;
    * **other relationship tables are replicated** (every shard sees every
      edge).

    Positive-count queries are answered by running the ordinary counting
    stack per shard and merging tables at a front-end
    (:class:`repro.serve.router.CountingRouter`); :meth:`route` decides,
    per query, whether the merge is a fan-out **sum** or a **single-shard**
    lookup.  Use :func:`shard_database` to build one.

    Usage::

        sdb = shard_database(db, n_shards=4)
        assert sdb.route(point)[0] in ("fanout", "single")
    """

    schema: Schema
    shards: Tuple[RelationalDB, ...]
    root_etype: str
    partitioned: frozenset = field(default_factory=frozenset)  # rel names

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def _partition_side_var(self, atom) -> "object":
        """The variable at the partition-key endpoint of a partitioned
        atom: the ``root_etype`` end of the relationship (``src`` wins for
        self-relationships, matching :func:`shard_database`)."""
        rel = self.schema.relationship(atom.rel)
        return atom.src if rel.src == self.root_etype else atom.dst

    def route(self, point) -> Tuple[str, Optional[int]]:
        """Decide how a positive-count query over ``point`` is answered.

        Per-shard counts sum to the true count exactly when every satisfied
        grounding finds ALL of its partitioned edges on one shard.  That
        holds in exactly two cases:

        * no atom of the point uses a partitioned relationship — every
          shard holds the full (replicated) data, so the query is answered
          by ONE shard (summing would over-count ``n_shards``-fold);
        * every partitioned atom touches the *same* first-order variable at
          its partition-key endpoint — that grounding value hashes all the
          edges of the grounding onto one shard, so fan-out + sum is exact.

        Args:
            point: a :class:`~repro.core.variables.LatticePoint`.

        Returns:
            ``("fanout", None)`` — query every shard, add the tables; or
            ``("single", shard_index)`` — query that one shard.

        Raises:
            NotRoutableError: partitioned atoms disagree on the
                partition-key variable (e.g. a chain entering the root
                entity type at two different variables); no additive
                merge over this partitioning exists.
        """
        part_atoms = [a for a in point.atoms if a.rel in self.partitioned]
        if not part_atoms:
            return ("single", _route_key(point) % self.n_shards)
        side_vars = {self._partition_side_var(a) for a in part_atoms}
        if len(side_vars) > 1:
            raise NotRoutableError(
                f"point {point} joins partitioned relationships "
                f"{sorted(a.rel for a in part_atoms)} at different "
                f"{self.root_etype!r} variables {sorted(map(str, side_vars))}; "
                f"per-shard counts are not additive under this partitioning "
                f"(re-shard with a different root_etype or replicate one "
                f"of the relationships)")
        return ("fanout", None)


def shard_database(db: RelationalDB, n_shards: int,
                   root_etype: Optional[str] = None) -> ShardedDatabase:
    """Hash-partition ``db`` into ``n_shards`` complete sub-databases.

    Relationship tables incident to ``root_etype`` are split by the hash of
    their ``root_etype`` endpoint (the *root entity* of a counting query);
    entity tables and the remaining relationship tables are replicated —
    see :class:`ShardedDatabase` for the exact layout and the merge
    semantics it buys.

    Args:
        db: the database to partition (left untouched; shards share its
            entity/replicated arrays and hold views of partitioned ones).
        n_shards: number of shards (>= 1).
        root_etype: entity type whose ids are the partition key.  Defaults
            to the type incident to the most relationships (ties broken by
            larger table, then name) — the type most queries root at.

    Returns:
        A :class:`ShardedDatabase` whose shards each pass
        :meth:`RelationalDB.validate`.

    Raises:
        ValueError: ``n_shards < 1``, or ``root_etype`` names no entity
            type / touches no relationship.

    Usage::

        sdb = shard_database(paper_benchmark_db("UW"), n_shards=2)
        assert sum(s.relations["Registered"].num_edges
                   for s in sdb.shards) == db.relations["Registered"].num_edges
    """
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    incident: Dict[str, int] = {et.name: 0 for et in db.schema.entities}
    for rt in db.schema.relationships:
        incident[rt.src] += 1
        if rt.dst != rt.src:
            incident[rt.dst] += 1
    if root_etype is None:
        root_etype = max(incident,
                         key=lambda n: (incident[n],
                                        db.schema.entity(n).size, n))
    elif root_etype not in incident:
        raise ValueError(f"unknown entity type {root_etype!r}")
    if incident[root_etype] == 0:
        raise ValueError(f"root_etype {root_etype!r} touches no relationship; "
                         f"nothing would be partitioned")

    partitioned = frozenset(rt.name for rt in db.schema.relationships
                            if root_etype in (rt.src, rt.dst))
    assign: Dict[str, np.ndarray] = {}         # hash each edge list once
    for name in partitioned:
        tab = db.relations[name]
        key_ids = tab.src if tab.type.src == root_etype else tab.dst
        assign[name] = _shard_hash(np.asarray(key_ids), n_shards)
    shards: List[RelationalDB] = []
    for s in range(n_shards):
        relations: Dict[str, RelationTable] = {}
        for name, tab in db.relations.items():
            if name not in partitioned:
                relations[name] = tab          # replicated: shared reference
                continue
            mask = assign[name] == s
            relations[name] = RelationTable(
                tab.type, tab.src[mask], tab.dst[mask],
                {a: col[mask] for a, col in tab.attrs.items()})
        shard = RelationalDB(db.schema, db.entities, relations)
        shard.validate()
        shards.append(shard)
    return ShardedDatabase(db.schema, tuple(shards), root_etype, partitioned)


# ---------------------------------------------------------------------------
# Paper-benchmark synthetic stand-ins (Table 4 of the paper).
# Row counts mirror the published datasets; schema complexity (number of
# relationships / attribute counts) mirrors the published relationship counts.
# ---------------------------------------------------------------------------

def _uni_schema(n_students: int, n_courses: int, n_profs: int,
                a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("student", n_students, (att("intelligence"), att("ranking"))),
            EntityType("course", n_courses, (att("difficulty"), att("rating"))),
            EntityType("prof", n_profs, (att("popularity"), att("teachingability"))),
        ),
        relationships=(
            Relationship("Registered", "student", "course", (att("grade"), att("satisfaction"))),
            Relationship("RA", "prof", "student", (att("salary"), att("capability"))),
        ),
    )


def _movie_schema(n_users: int, n_movies: int, a_card: int = 3) -> Schema:
    att = lambda n: Attribute(n, a_card)
    return Schema(
        entities=(
            EntityType("user", n_users, (att("age"), att("gender"), att("occupation"))),
            EntityType("movie", n_movies, (att("year"), att("genre"))),
        ),
        relationships=(
            Relationship("Rated", "user", "movie", (att("rating"),)),
        ),
    )


def _generic_schema(name: str, n_rel: int, n_ent: int, ent_size: int,
                    n_attr: int = 2, a_card: int = 3) -> Schema:
    """A connected schema with ``n_rel`` relationships over ``n_ent`` types."""
    att = lambda n: Attribute(n, a_card)
    ents = tuple(
        EntityType(f"{name}_e{i}", ent_size,
                   tuple(att(f"a{i}_{j}") for j in range(n_attr)))
        for i in range(n_ent)
    )
    rels = []
    for r in range(n_rel):
        s = r % n_ent
        d = (r + 1) % n_ent
        if s == d:
            d = (d + 1) % n_ent
        rels.append(Relationship(f"{name}_R{r}", ents[s].name, ents[d].name,
                                 (att(f"r{r}_a0"),)))
    return Schema(ents, tuple(rels))


# (name, builder) — row counts approximate the paper's Table 4.
def paper_benchmark_db(name: str, seed: int = 0, scale: float = 1.0) -> RelationalDB:
    """Synthetic stand-ins for the paper's 8 databases, matched on total rows
    and relationship count (Table 4).  ``scale`` shrinks them for tests."""
    s = lambda n: max(8, int(n * scale))
    if name == "UW":              # 712 rows, 2 rels
        sch = _uni_schema(s(180), s(140), s(40))
        edges = {"Registered": s(250), "RA": s(100)}
    elif name == "Mondial":       # 870 rows, 2 rels
        sch = _generic_schema("mon", 2, 3, s(120), n_attr=4, a_card=4)
        edges = {"mon_R0": s(300), "mon_R1": s(200)}
    elif name == "Hepatitis":     # 12,927 rows, 3 rels
        sch = _generic_schema("hep", 3, 3, s(1500), n_attr=3, a_card=4)
        edges = {"hep_R0": s(3000), "hep_R1": s(3000), "hep_R2": s(2400)}
    elif name == "Mutagenesis":   # 14,540 rows, 2 rels
        sch = _generic_schema("mut", 2, 2, s(2500), n_attr=2, a_card=3)
        edges = {"mut_R0": s(6000), "mut_R1": s(3500)}
    elif name == "MovieLens":     # 74,402 rows, 1 rel
        sch = _movie_schema(s(941), s(1682))
        edges = {"Rated": s(71779)}
    elif name == "Financial":     # 225,887 rows, 3 rels
        sch = _generic_schema("fin", 3, 3, s(15000), n_attr=3, a_card=4)
        edges = {"fin_R0": s(80000), "fin_R1": s(60000), "fin_R2": s(40000)}
    elif name == "IMDb":          # 1,063,559 rows, 3 rels
        sch = _generic_schema("imdb", 3, 3, s(100000), n_attr=3, a_card=3)
        edges = {"imdb_R0": s(400000), "imdb_R1": s(250000), "imdb_R2": s(113000)}
    elif name == "VisualGenome":  # 15,833,273 rows, 8 rels
        sch = _generic_schema("vg", 8, 4, s(200000), n_attr=1, a_card=3)
        edges = {f"vg_R{i}": s(1900000) for i in range(8)}
    else:
        raise KeyError(name)
    return synth_db(sch, edges, seed=seed)


PAPER_DATASETS = ("UW", "Mondial", "Hepatitis", "Mutagenesis", "MovieLens",
                  "Financial", "IMDb", "VisualGenome")
