"""Relational schema description.

The language bias follows FACTORBASE (Schulte & Qian 2019): first-order
variables range over entity types (one *population variable* per entity type;
self-relationships use a second copy of the variable).  A schema declares

* entity types, each with categorical attributes of known cardinality, and
* binary relationship types between two entity types, each with categorical
  *edge attributes* of known cardinality.

Everything downstream is integer coded: attribute values live in
``[0, card)``.  Edge attributes additionally reserve the value ``card`` as the
``N/A`` slot used when the relationship indicator is false (paper Table 3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence, Tuple


class _CachedHash:
    """Memoised ``__hash__`` for the frozen descriptor dataclasses.

    Schema and query descriptors key every hot dict on the serve path —
    plan compilation, request coalescing, router/service/engine caches —
    and a recursive dataclass hash over nested tuples is recomputed on
    EVERY lookup (tuple hashes are not cached by CPython).  Computing it
    once per instance keeps a query flood's time in counting, not hashing.
    Hashing stays consistent with field equality: equal field values give
    equal hashes, memoised or not."""

    __hash_seed__: str = ""

    def __hash__(self) -> int:
        h = self.__dict__.get("_hash")
        if h is None:
            fields = tuple(v for k, v in self.__dict__.items()
                           if k != "_hash")
            h = hash((self.__hash_seed__,) + fields)
            object.__setattr__(self, "_hash", h)
        return h


@dataclass(frozen=True)
class Attribute:
    name: str
    card: int  # number of real values (excludes the N/A slot for edge attrs)

    def __post_init__(self) -> None:
        if self.card < 1:
            raise ValueError(f"attribute {self.name!r} needs card >= 1")


@dataclass(frozen=True)
class EntityType:
    name: str
    size: int                         # number of entities
    attrs: Tuple[Attribute, ...] = ()

    def attr(self, name: str) -> Attribute:
        for a in self.attrs:
            if a.name == name:
                return a
        raise KeyError(name)


@dataclass(frozen=True)
class Relationship:
    name: str
    src: str                          # entity type name
    dst: str                          # entity type name
    attrs: Tuple[Attribute, ...] = () # edge attributes

    @property
    def is_self(self) -> bool:
        return self.src == self.dst

    def attr(self, name: str) -> Attribute:
        for a in self.attrs:
            if a.name == name:
                return a
        raise KeyError(name)


@dataclass(frozen=True)
class Schema(_CachedHash):
    entities: Tuple[EntityType, ...]
    relationships: Tuple[Relationship, ...]

    __hash_seed__ = "Schema"
    __hash__ = _CachedHash.__hash__

    def entity(self, name: str) -> EntityType:
        for e in self.entities:
            if e.name == name:
                return e
        raise KeyError(name)

    def relationship(self, name: str) -> Relationship:
        for r in self.relationships:
            if r.name == name:
                return r
        raise KeyError(name)

    def validate(self) -> None:
        enames = [e.name for e in self.entities]
        if len(set(enames)) != len(enames):
            raise ValueError("duplicate entity type names")
        rnames = [r.name for r in self.relationships]
        if len(set(rnames)) != len(rnames):
            raise ValueError("duplicate relationship names")
        for r in self.relationships:
            self.entity(r.src)
            self.entity(r.dst)
