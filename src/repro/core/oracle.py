"""Brute-force counting oracle (pure numpy, exponential — tiny DBs only).

Enumerates every grounding (one entity per lattice-point variable) and tallies
the exact contingency table, including negative relationships and N/A edge
attributes.  This is the semantic ground truth that ``positive_ct`` and
``complete_ct`` are tested against.
"""

from __future__ import annotations

import itertools
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .ct import CtTable
from .database import RelationalDB
from .variables import CtVar, LatticePoint, Var


def oracle_ct(db: RelationalDB, point: LatticePoint,
              keep: Sequence[CtVar],
              require_positive: bool = False) -> np.ndarray:
    """Exact dense ct-table over ``keep`` by grounding enumeration.

    ``require_positive=True`` restricts to groundings where every relation of
    the point holds (the positive table, no indicator axes)."""
    keep = tuple(keep)
    vars_ = point.vars
    sizes = [db.entities[v.etype].size for v in vars_]
    # edge lookup: rel -> {(src, dst): {attr: value}}
    edge_maps: Dict[str, Dict[Tuple[int, int], Dict[str, int]]] = {}
    for a in point.atoms:
        rt = db.relations[a.rel]
        m: Dict[Tuple[int, int], Dict[str, int]] = {}
        for i in range(rt.num_edges):
            m[(int(rt.src[i]), int(rt.dst[i]))] = {
                name: int(col[i]) for name, col in rt.attrs.items()}
        edge_maps[a.rel] = m

    shape = tuple(v.card for v in keep)
    out = np.zeros(shape, dtype=np.int64)
    vidx = {v: i for i, v in enumerate(vars_)}

    for tup in itertools.product(*[range(s) for s in sizes]):
        truth: Dict[str, bool] = {}
        eattrs: Dict[str, Optional[Dict[str, int]]] = {}
        for a in point.atoms:
            key = (tup[vidx[a.src]], tup[vidx[a.dst]])
            hit = edge_maps[a.rel].get(key)
            truth[a.rel] = hit is not None
            eattrs[a.rel] = hit
        if require_positive and not all(truth.values()):
            continue
        idx = []
        for cv in keep:
            if cv.kind == "attr":
                var, aname = cv.owner
                ent = db.entities[var.etype]
                idx.append(int(ent.attrs[aname][tup[vidx[var]]]))
            elif cv.kind == "edge":
                rel, aname = cv.owner
                hit = eattrs[rel]
                idx.append(int(hit[aname]) if hit is not None else cv.card - 1)
            else:  # rind
                idx.append(1 if truth[cv.owner[0]] else 0)
        out[tuple(idx)] += 1
    return out
