"""CtCache: one budgeted LRU cache under every counting strategy.

The seed carried three ad-hoc dict caches (`_OnDemandProvider._cache`,
`_CachedPositiveProvider.full`, `_TupleIdProvider._msgs`) plus a per-strategy
family memo — none with a budget, and none that ever *decremented*
``CostStats.cache_bytes``, so the Fig. 4 memory proxy (``peak_bytes``) was
wrong the moment anything should have been dropped.  This module replaces
all of them:

* every entry is charged by byte size (``CtTable.nbytes``, array
  ``.nbytes``, or an explicit ``nbytes=``);
* a byte budget triggers LRU eviction, and evictions *decrement* the
  shared :class:`~repro.core.contract.CostStats` so ``cache_bytes`` is the
  live footprint and ``peak_bytes`` the true high-water mark;
* an entry larger than the whole budget is admitted transiently (so its
  residency shows up in ``peak_bytes``) and immediately dropped;
* eviction is safe by construction: every caller has a recompute path on
  miss (positives re-contract, messages re-propagate, family tables
  re-join).

**Freshness.**  Since the store became mutable
(:meth:`~repro.core.database.RelationalDB.insert_facts`), every entry also
records the ``(version, relation-dependency set)`` it was computed under —
``deps`` is the set of relationship names whose edge tables the cached
value was derived from, ``version`` the ``db.version`` at insert time.
Both default through pluggable hooks (``deps_fn``/``version_fn``, wired by
:class:`~repro.core.engine.CountingEngine` so existing call sites need no
changes).  :meth:`CtCache.invalidate` is then **fine-grained**: given a
delta's relation set it drops only the entries whose dependency set
intersects it (entries with unknown deps are dropped conservatively);
entries over untouched relations — and relation-independent entries like
entity histograms, ``deps == frozenset()`` — survive the write.

Keys are arbitrary hashable tuples; by convention the first element names
the namespace (``"pos"``, ``"full"``, ``"complete"``, ``"msg"``, ``"fam"``,
``"hist"``) so one cache instance can back every layer of a strategy.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import (Any, Callable, FrozenSet, Hashable, Iterable, List,
                    Optional, Tuple)

from ..obs.trace import NULL_TRACER
from .contract import CostStats


def _nbytes_of(value: Any) -> int:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, tuple):
        return sum(_nbytes_of(v) for v in value)
    return 0


class _Entry:
    __slots__ = ("value", "nbytes", "deps", "version")

    def __init__(self, value: Any, nbytes: int,
                 deps: Optional[FrozenSet[str]], version: Optional[int]):
        self.value, self.nbytes = value, nbytes
        self.deps, self.version = deps, version


class CtCache:
    """Byte-budgeted LRU cache for ct-tables and message matrices, with
    per-entry ``(version, relation-dependency set)`` freshness metadata.

    Args:
        budget_bytes: LRU byte budget (``None`` = unbounded).
        stats: optional :class:`~repro.core.contract.CostStats` whose
            ``cache_bytes``/``peak_bytes`` mirror the live footprint.
        deps_fn: ``key -> frozenset of relationship names | None`` used to
            stamp entries whose ``put`` did not pass ``deps`` explicitly
            (``None`` = unknown, dropped conservatively on invalidation).
        version_fn: ``() -> int`` store version used to stamp entries
            whose ``put`` did not pass ``version``.
    """

    def __init__(self, budget_bytes: Optional[int] = None,
                 stats: Optional[CostStats] = None,
                 deps_fn: Optional[Callable[[Hashable],
                                            Optional[FrozenSet[str]]]] = None,
                 version_fn: Optional[Callable[[], int]] = None):
        self.budget_bytes = budget_bytes
        self.stats = stats
        self.deps_fn = deps_fn
        self.version_fn = version_fn
        self._entries: "OrderedDict[Hashable, _Entry]" = OrderedDict()
        # get/put/evict are lock-guarded: the serve layer mutates one shared
        # cache from many client threads (OrderedDict reorder + byte
        # accounting are not atomic on their own)
        self._lock = threading.RLock()
        # request tracer for hit/miss/evict events; NULL_TRACER is free, a
        # real one is wired in by CountingService.set_tracer
        self.tracer = NULL_TRACER
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dropped = 0
        self.invalidated = 0
        self.delta_updated = 0        # entries refreshed in place by a delta

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get(self, key: Hashable, default=None):
        tr = self.tracer
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.misses += 1
                if tr.enabled:
                    tr.event("cache.miss", key=key)
                return default
            self._entries.move_to_end(key)
            self.hits += 1
            if tr.enabled:
                tr.event("cache.hit", key=key, nbytes=hit.nbytes)
            return hit.value

    def put(self, key: Hashable, value: Any,
            nbytes: Optional[int] = None,
            deps: Optional[FrozenSet[str]] = None,
            version: Optional[int] = None) -> Any:
        """Insert (or refresh) ``key``; returns ``value`` for chaining.

        ``deps``/``version`` default through the ``deps_fn``/``version_fn``
        hooks, so ordinary callers never pass them."""
        nb = _nbytes_of(value) if nbytes is None else int(nbytes)
        if deps is None and self.deps_fn is not None:
            deps = self.deps_fn(key)
        if version is None and self.version_fn is not None:
            version = self.version_fn()
        with self._lock:
            if key in self._entries:
                self._evict_one(key)
            self._entries[key] = _Entry(value, nb, deps, version)
            self.nbytes += nb
            if self.stats is not None:
                self.stats.bump_cache(nb)  # records the peak before any drop
            self._shrink_to_budget(just_added=key)
        return value

    def peek(self, key: Hashable, default=None):
        """Read a value WITHOUT hit/miss accounting or an LRU touch — the
        delta-maintenance walk reads entries it is about to refresh, which
        must not look like client traffic."""
        with self._lock:
            e = self._entries.get(key)
            return default if e is None else e.value

    def discard(self, key: Hashable) -> bool:
        """Drop one entry as *stale* (counted under ``invalidated``, not
        ``evictions``); returns whether it was resident."""
        with self._lock:
            if key not in self._entries:
                return False
            self._evict_one(key)
            self.invalidated += 1
            return True

    def entry_meta(self, key: Hashable
                   ) -> Optional[Tuple[Optional[FrozenSet[str]],
                                       Optional[int]]]:
        """The ``(deps, version)`` stamp of a resident entry (no LRU
        touch, no hit/miss accounting), or ``None`` when absent."""
        with self._lock:
            e = self._entries.get(key)
            return None if e is None else (e.deps, e.version)

    def keys_snapshot(self) -> List[Hashable]:
        """A stable snapshot of the resident keys (LRU -> MRU order) —
        what a delta-maintenance walk iterates while individual entries
        come and go underneath it."""
        with self._lock:
            return list(self._entries)

    # -- eviction -----------------------------------------------------------
    def _evict_one(self, key: Hashable) -> None:
        e = self._entries.pop(key)
        self.nbytes -= e.nbytes
        if self.stats is not None:
            self.stats.bump_cache(-e.nbytes)
        if self.tracer.enabled:
            self.tracer.event("cache.evict", key=key, nbytes=e.nbytes)

    def _shrink_to_budget(self, just_added: Optional[Hashable] = None) -> None:
        if self.budget_bytes is None:
            return
        while self.nbytes > self.budget_bytes and len(self._entries) > 1:
            # the just-added entry sits at the MRU end, so the LRU pop below
            # can only reach it once everything older is gone
            self._evict_one(next(iter(self._entries)))
            self.evictions += 1
        if self.nbytes > self.budget_bytes and just_added in self._entries:
            # the new entry alone exceeds the budget: admit-then-drop, so
            # peak_bytes reflects its transient residency
            self._evict_one(just_added)
            self.dropped += 1

    def evict_all(self) -> None:
        with self._lock:
            for key in list(self._entries):
                self._evict_one(key)
                self.evictions += 1

    def invalidate(self, rels: Optional[Iterable[str]] = None) -> int:
        """Drop entries made stale by a write to ``rels``.

        Fine-grained: only entries whose dependency set *intersects*
        ``rels`` are dropped — plus entries with unknown deps (``None``),
        conservatively.  Entries over untouched relations keep their
        residency AND their LRU position.  ``rels=None`` drops everything
        (a full refresh).

        Args:
            rels: relationship names touched by the delta, or ``None``.

        Returns:
            Number of entries dropped.

        Usage::

            dropped = cache.invalidate({delta.rel})
        """
        with self._lock:
            if rels is None:
                n = len(self._entries)
                for key in list(self._entries):
                    self._evict_one(key)
            else:
                rels = frozenset(rels)
                stale = [k for k, e in self._entries.items()
                         if e.deps is None or e.deps & rels]
                n = len(stale)
                for key in stale:
                    self._evict_one(key)
            self.invalidated += n
            return n

    def info(self) -> dict:
        return dict(entries=len(self._entries), nbytes=self.nbytes,
                    budget_bytes=self.budget_bytes, hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    dropped=self.dropped, invalidated=self.invalidated,
                    delta_updated=self.delta_updated)
