"""CtCache: one budgeted LRU cache under every counting strategy.

The seed carried three ad-hoc dict caches (`_OnDemandProvider._cache`,
`_CachedPositiveProvider.full`, `_TupleIdProvider._msgs`) plus a per-strategy
family memo — none with a budget, and none that ever *decremented*
``CostStats.cache_bytes``, so the Fig. 4 memory proxy (``peak_bytes``) was
wrong the moment anything should have been dropped.  This module replaces
all of them:

* every entry is charged by byte size (``CtTable.nbytes``, array
  ``.nbytes``, or an explicit ``nbytes=``);
* a byte budget triggers LRU eviction, and evictions *decrement* the
  shared :class:`~repro.core.contract.CostStats` so ``cache_bytes`` is the
  live footprint and ``peak_bytes`` the true high-water mark;
* an entry larger than the whole budget is admitted transiently (so its
  residency shows up in ``peak_bytes``) and immediately dropped;
* eviction is safe by construction: every caller has a recompute path on
  miss (positives re-contract, messages re-propagate, family tables
  re-join).

Keys are arbitrary hashable tuples; by convention the first element names
the namespace (``"pos"``, ``"full"``, ``"complete"``, ``"msg"``, ``"fam"``,
``"hist"``) so one cache instance can back every layer of a strategy.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable, Optional

from .contract import CostStats


def _nbytes_of(value: Any) -> int:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, tuple):
        return sum(_nbytes_of(v) for v in value)
    return 0


class CtCache:
    """Byte-budgeted LRU cache for ct-tables and message matrices."""

    def __init__(self, budget_bytes: Optional[int] = None,
                 stats: Optional[CostStats] = None):
        self.budget_bytes = budget_bytes
        self.stats = stats
        self._entries: "OrderedDict[Hashable, Tuple[Any, int]]" = OrderedDict()
        # get/put/evict are lock-guarded: the serve layer mutates one shared
        # cache from many client threads (OrderedDict reorder + byte
        # accounting are not atomic on their own)
        self._lock = threading.RLock()
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get(self, key: Hashable, default=None):
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.misses += 1
                return default
            self._entries.move_to_end(key)
            self.hits += 1
            return hit[0]

    def put(self, key: Hashable, value: Any,
            nbytes: Optional[int] = None) -> Any:
        """Insert (or refresh) ``key``; returns ``value`` for chaining."""
        nb = _nbytes_of(value) if nbytes is None else int(nbytes)
        with self._lock:
            if key in self._entries:
                self._evict_one(key)
            self._entries[key] = (value, nb)
            self.nbytes += nb
            if self.stats is not None:
                self.stats.bump_cache(nb)  # records the peak before any drop
            self._shrink_to_budget(just_added=key)
        return value

    # -- eviction -----------------------------------------------------------
    def _evict_one(self, key: Hashable) -> None:
        _, nb = self._entries.pop(key)
        self.nbytes -= nb
        if self.stats is not None:
            self.stats.bump_cache(-nb)

    def _shrink_to_budget(self, just_added: Optional[Hashable] = None) -> None:
        if self.budget_bytes is None:
            return
        while self.nbytes > self.budget_bytes and len(self._entries) > 1:
            # the just-added entry sits at the MRU end, so the LRU pop below
            # can only reach it once everything older is gone
            self._evict_one(next(iter(self._entries)))
            self.evictions += 1
        if self.nbytes > self.budget_bytes and just_added in self._entries:
            # the new entry alone exceeds the budget: admit-then-drop, so
            # peak_bytes reflects its transient residency
            self._evict_one(just_added)
            self.dropped += 1

    def evict_all(self) -> None:
        with self._lock:
            for key in list(self._entries):
                self._evict_one(key)
                self.evictions += 1

    def info(self) -> dict:
        return dict(entries=len(self._entries), nbytes=self.nbytes,
                    budget_bytes=self.budget_bytes, hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    dropped=self.dropped)
