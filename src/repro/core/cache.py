"""CtCache: one budgeted LRU cache under every counting strategy.

The seed carried three ad-hoc dict caches (`_OnDemandProvider._cache`,
`_CachedPositiveProvider.full`, `_TupleIdProvider._msgs`) plus a per-strategy
family memo — none with a budget, and none that ever *decremented*
``CostStats.cache_bytes``, so the Fig. 4 memory proxy (``peak_bytes``) was
wrong the moment anything should have been dropped.  This module replaces
all of them:

* every entry is charged by byte size (``CtTable.nbytes``, array
  ``.nbytes``, or an explicit ``nbytes=``);
* a byte budget triggers LRU eviction, and evictions *decrement* the
  shared :class:`~repro.core.contract.CostStats` so ``cache_bytes`` is the
  live footprint and ``peak_bytes`` the true high-water mark;
* an entry larger than the whole budget is admitted transiently (so its
  residency shows up in ``peak_bytes``) and immediately dropped;
* eviction is safe by construction: every caller has a recompute path on
  miss (positives re-contract, messages re-propagate, family tables
  re-join).

**Freshness.**  Since the store became mutable
(:meth:`~repro.core.database.RelationalDB.insert_facts`), every entry also
records the ``(version, dependency set)`` it was computed under — ``deps``
is a frozenset of *dependency tags*: relationship names (plain strings)
for the edge tables the cached value was derived from, plus
``("attr", etype, attr_name)`` tuples for the entity-attribute columns it
read (and the ``("attr*", etype)`` wildcard for entries that cannot
enumerate their attribute names precisely); ``version`` is ``db.version``
at insert time.  Both default through pluggable hooks
(``deps_fn``/``version_fn``, wired by
:class:`~repro.core.engine.CountingEngine` so existing call sites need no
changes).  :meth:`CtCache.invalidate` is then **fine-grained**: given a
delta's tag set it drops only the entries whose dependency set intersects
it (entries with unknown deps are dropped conservatively); entries over
untouched relations/attributes survive the write.  Strings never equal
tuples, so relation sweeps and attribute sweeps cannot collide.

**Tenancy.**  One physical store can back many logical databases.  Every
entry belongs to a tenant (:data:`DEFAULT_TENANT` when unspecified, which
keeps the single-DB API unchanged); :meth:`CtCache.scoped` hands out a
:class:`TenantCache` view that an engine uses exactly like a private
cache — its ``deps_fn``/``version_fn`` hooks live on the *view*, so two
tenants' engines never collide on the shared store.  Per-tenant byte
accounting supports two knobs (:meth:`CtCache.set_tenant_budget`):

* ``reserved_bytes`` — a floor the global LRU shrink may never evict
  below: a flooding tenant can only reclaim the *shared* headroom, never
  another tenant's reservation;
* ``cap_bytes`` — a ceiling: a tenant over its own cap evicts its own
  LRU entries first, before the global budget is even consulted.

Keys are arbitrary hashable tuples; by convention the first element names
the namespace (``"pos"``, ``"full"``, ``"complete"``, ``"msg"``, ``"fam"``,
``"hist"``) so one cache instance can back every layer of a strategy.
Tenants may freely reuse the same key tuples — the store disambiguates
internally by ``(tenant, key)``.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import (Any, Callable, Dict, FrozenSet, Hashable, Iterable, List,
                    Optional, Tuple)

from ..obs.trace import NULL_TRACER
from .contract import CostStats

DEFAULT_TENANT = "default"


def _nbytes_of(value: Any) -> int:
    nb = getattr(value, "nbytes", None)
    if nb is not None:
        return int(nb)
    if isinstance(value, tuple):
        return sum(_nbytes_of(v) for v in value)
    return 0


#: A dependency tag: a relationship name (str) or an attribute tuple
#: ``("attr", etype, name)`` / ``("attr*", etype)``.
DepTag = Hashable


class _Entry:
    __slots__ = ("value", "nbytes", "deps", "version", "tenant")

    def __init__(self, value: Any, nbytes: int,
                 deps: Optional[FrozenSet[DepTag]], version: Optional[int],
                 tenant: str):
        self.value, self.nbytes = value, nbytes
        self.deps, self.version = deps, version
        self.tenant = tenant


class _TenantState:
    """Per-tenant accounting: live bytes, budget knobs, and the same
    counter set the store keeps globally (so ``info()["tenants"]`` is a
    faithful per-tenant decomposition of the totals)."""

    __slots__ = ("tenant", "nbytes", "entries", "reserved", "cap", "stats",
                 "hits", "misses", "evictions", "dropped", "invalidated",
                 "delta_updated")

    def __init__(self, tenant: str):
        self.tenant = tenant
        self.nbytes = 0
        self.entries = 0
        self.reserved = 0              # floor: global shrink stops here
        self.cap: Optional[int] = None  # ceiling: own-LRU shrink above it
        self.stats: Optional[CostStats] = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dropped = 0
        self.invalidated = 0
        self.delta_updated = 0

    def info(self) -> dict:
        return dict(entries=self.entries, nbytes=self.nbytes,
                    reserved_bytes=self.reserved, cap_bytes=self.cap,
                    hits=self.hits, misses=self.misses,
                    evictions=self.evictions, dropped=self.dropped,
                    invalidated=self.invalidated,
                    delta_updated=self.delta_updated)


class CtCache:
    """Byte-budgeted LRU cache for ct-tables and message matrices, with
    per-entry ``(version, dependency-tag set)`` freshness metadata and
    per-tenant byte accounting.

    Args:
        budget_bytes: LRU byte budget across all tenants (``None`` =
            unbounded).
        stats: optional :class:`~repro.core.contract.CostStats` whose
            ``cache_bytes``/``peak_bytes`` mirror the live footprint.
        deps_fn: ``key -> frozenset of dependency tags | None`` (relation
            names and/or attribute tuples) used to stamp entries whose
            ``put`` did not pass ``deps`` explicitly (``None`` = unknown,
            dropped conservatively on invalidation).
        version_fn: ``() -> int`` store version used to stamp entries
            whose ``put`` did not pass ``version``.

    Single-tenant callers never see the tenant dimension: every method
    defaults to :data:`DEFAULT_TENANT`.  Multi-tenant callers go through
    :meth:`scoped`.
    """

    def __init__(self, budget_bytes: Optional[int] = None,
                 stats: Optional[CostStats] = None,
                 deps_fn: Optional[Callable[[Hashable],
                                            Optional[FrozenSet[DepTag]]]] = None,
                 version_fn: Optional[Callable[[], int]] = None):
        self.budget_bytes = budget_bytes
        self.stats = stats
        self.deps_fn = deps_fn
        self.version_fn = version_fn
        self._entries: "OrderedDict[Tuple[str, Hashable], _Entry]" = \
            OrderedDict()
        self._tenants: Dict[str, _TenantState] = {}
        # get/put/evict are lock-guarded: the serve layer mutates one shared
        # cache from many client threads (OrderedDict reorder + byte
        # accounting are not atomic on their own)
        self._lock = threading.RLock()
        # request tracer for hit/miss/evict events; NULL_TRACER is free, a
        # real one is wired in by CountingService.set_tracer
        self.tracer = NULL_TRACER
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dropped = 0
        self.invalidated = 0
        self.delta_updated = 0        # entries refreshed in place by a delta

    # -- tenancy ------------------------------------------------------------
    def _state(self, tenant: str) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantState(tenant)
        return st

    def scoped(self, tenant: str) -> "TenantCache":
        """A :class:`TenantCache` view over this store for ``tenant`` —
        drop-in wherever a private ``CtCache`` was used before."""
        with self._lock:
            self._state(tenant)
        return TenantCache(self, tenant)

    def set_tenant_budget(self, tenant: str, reserved_bytes: int = 0,
                          cap_bytes: Optional[int] = None) -> None:
        """Set ``tenant``'s byte reservation (floor the global shrink
        cannot cross) and optional cap (ceiling its own entries shrink
        to).  A cap below current residency shrinks immediately."""
        with self._lock:
            st = self._state(tenant)
            st.reserved = int(reserved_bytes)
            st.cap = None if cap_bytes is None else int(cap_bytes)
            self._shrink_tenant_to_cap(st, just_added=None)

    def tenants_info(self) -> Dict[str, dict]:
        with self._lock:
            return {t: st.info() for t, st in self._tenants.items()}

    # -- core ops -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return (DEFAULT_TENANT, key) in self._entries

    def contains(self, key: Hashable, tenant: str = DEFAULT_TENANT) -> bool:
        return (tenant, key) in self._entries

    def tenant_len(self, tenant: str = DEFAULT_TENANT) -> int:
        with self._lock:
            st = self._tenants.get(tenant)
            return 0 if st is None else st.entries

    def get(self, key: Hashable, default=None,
            tenant: str = DEFAULT_TENANT):
        tr = self.tracer
        tkey = (tenant, key)
        with self._lock:
            hit = self._entries.get(tkey)
            st = self._state(tenant)
            if hit is None:
                self.misses += 1
                st.misses += 1
                if tr.enabled:
                    tr.event("cache.miss", key=key, tenant=tenant)
                return default
            self._entries.move_to_end(tkey)
            self.hits += 1
            st.hits += 1
            if tr.enabled:
                tr.event("cache.hit", key=key, nbytes=hit.nbytes,
                         tenant=tenant)
            return hit.value

    def put(self, key: Hashable, value: Any,
            nbytes: Optional[int] = None,
            deps: Optional[FrozenSet[DepTag]] = None,
            version: Optional[int] = None,
            tenant: str = DEFAULT_TENANT) -> Any:
        """Insert (or refresh) ``key``; returns ``value`` for chaining.

        ``deps``/``version`` default through the ``deps_fn``/``version_fn``
        hooks, so ordinary callers never pass them."""
        nb = _nbytes_of(value) if nbytes is None else int(nbytes)
        if deps is None and self.deps_fn is not None:
            deps = self.deps_fn(key)
        if version is None and self.version_fn is not None:
            version = self.version_fn()
        tkey = (tenant, key)
        with self._lock:
            st = self._state(tenant)
            if tkey in self._entries:
                self._evict_one(tkey)
            self._entries[tkey] = _Entry(value, nb, deps, version, tenant)
            self.nbytes += nb
            st.nbytes += nb
            st.entries += 1
            if self.stats is not None:
                self.stats.bump_cache(nb)  # records the peak before any drop
            if st.stats is not None:
                st.stats.bump_cache(nb)
            self._shrink_tenant_to_cap(st, just_added=tkey)
            self._shrink_to_budget(just_added=tkey)
        return value

    def peek(self, key: Hashable, default=None,
             tenant: str = DEFAULT_TENANT):
        """Read a value WITHOUT hit/miss accounting or an LRU touch — the
        delta-maintenance walk reads entries it is about to refresh, which
        must not look like client traffic."""
        with self._lock:
            e = self._entries.get((tenant, key))
            return default if e is None else e.value

    def discard(self, key: Hashable, tenant: str = DEFAULT_TENANT) -> bool:
        """Drop one entry as *stale* (counted under ``invalidated``, not
        ``evictions``); returns whether it was resident."""
        tkey = (tenant, key)
        with self._lock:
            if tkey not in self._entries:
                return False
            self._evict_one(tkey)
            self.invalidated += 1
            self._state(tenant).invalidated += 1
            return True

    def count_delta_updates(self, n: int = 1,
                            tenant: str = DEFAULT_TENANT) -> None:
        """Record ``n`` entries refreshed in place by a delta.  This is the
        ONLY sanctioned way to move the ``delta_updated`` counter — it takes
        the store lock and keeps the global and per-tenant slices in step
        (bare ``cache.delta_updated += 1`` mutations outside this module are
        rejected by ``scripts/check_locked_metrics.py``)."""
        with self._lock:
            self.delta_updated += n
            self._state(tenant).delta_updated += n

    def entry_meta(self, key: Hashable, tenant: str = DEFAULT_TENANT
                   ) -> Optional[Tuple[Optional[FrozenSet[DepTag]],
                                       Optional[int]]]:
        """The ``(deps, version)`` stamp of a resident entry (no LRU
        touch, no hit/miss accounting), or ``None`` when absent."""
        with self._lock:
            e = self._entries.get((tenant, key))
            return None if e is None else (e.deps, e.version)

    def keys_snapshot(self, tenant: str = DEFAULT_TENANT) -> List[Hashable]:
        """A stable snapshot of ``tenant``'s resident keys (LRU -> MRU
        order) — what a delta-maintenance walk iterates while individual
        entries come and go underneath it."""
        with self._lock:
            return [k for (t, k) in self._entries if t == tenant]

    # -- eviction -----------------------------------------------------------
    def _evict_one(self, tkey: Tuple[str, Hashable]) -> None:
        e = self._entries.pop(tkey)
        self.nbytes -= e.nbytes
        st = self._tenants.get(e.tenant)
        if st is not None:
            st.nbytes -= e.nbytes
            st.entries -= 1
            if st.stats is not None:
                st.stats.bump_cache(-e.nbytes)
        if self.stats is not None:
            self.stats.bump_cache(-e.nbytes)
        if self.tracer.enabled:
            self.tracer.event("cache.evict", key=tkey[1], nbytes=e.nbytes,
                              tenant=e.tenant)

    def _protected(self, e: _Entry) -> bool:
        """Would evicting ``e`` push its tenant below its reserved floor?"""
        st = self._tenants.get(e.tenant)
        if st is None or st.reserved <= 0:
            return False
        return st.nbytes - e.nbytes < st.reserved

    def _shrink_tenant_to_cap(self, st: _TenantState,
                              just_added: Optional[Tuple[str, Hashable]]
                              ) -> None:
        """Hold one tenant under its own cap by evicting its LRU entries
        (the reserved floor does not shield a tenant from its *own* cap)."""
        if st.cap is None or st.nbytes <= st.cap:
            return
        for tkey in [tk for tk in self._entries if tk[0] == st.tenant]:
            if st.nbytes <= st.cap or st.entries <= 1:
                break
            if tkey == just_added:
                continue
            self._evict_one(tkey)
            self.evictions += 1
            st.evictions += 1
        if (st.nbytes > st.cap and just_added is not None
                and just_added in self._entries):
            # the new entry alone exceeds the tenant cap: admit-then-drop
            self._evict_one(just_added)
            self.dropped += 1
            st.dropped += 1

    def _shrink_to_budget(self, just_added: Optional[Tuple[str, Hashable]]
                          = None) -> None:
        if self.budget_bytes is None or self.nbytes <= self.budget_bytes:
            return
        # one LRU->MRU pass: evict the oldest entries whose tenants stay
        # at/above their reserved floor; reserved residency is a carve-out
        # the global budget cannot reclaim
        for tkey in list(self._entries):
            if self.nbytes <= self.budget_bytes or len(self._entries) <= 1:
                break
            if tkey == just_added:
                # the just-added entry is only reachable once everything
                # older is gone (it sits at the MRU end anyway)
                continue
            e = self._entries[tkey]
            if self._protected(e):
                continue
            self._evict_one(tkey)
            self.evictions += 1
            st = self._tenants.get(tkey[0])
            if st is not None:
                st.evictions += 1
        if (self.nbytes > self.budget_bytes
                and just_added in self._entries
                and not self._protected(self._entries[just_added])):
            # the new entry alone exceeds the shared headroom: admit-then-
            # drop, so peak_bytes reflects its transient residency
            st = self._tenants.get(just_added[0])
            self._evict_one(just_added)
            self.dropped += 1
            if st is not None:
                st.dropped += 1

    def evict_all(self, tenant: Optional[str] = None) -> None:
        """Evict everything (``tenant=None``) or one tenant's entries."""
        with self._lock:
            for tkey in list(self._entries):
                if tenant is not None and tkey[0] != tenant:
                    continue
                st = self._tenants.get(tkey[0])
                self._evict_one(tkey)
                self.evictions += 1
                if st is not None:
                    st.evictions += 1

    def invalidate(self, rels: Optional[Iterable[str]] = None,
                   tenant: Optional[str] = None) -> int:
        """Drop entries made stale by a write to ``rels``.

        Fine-grained: only entries whose dependency set *intersects*
        ``rels`` are dropped — plus entries with unknown deps (``None``),
        conservatively.  Entries over untouched relations keep their
        residency AND their LRU position.  ``rels=None`` drops everything
        (a full refresh).  ``tenant`` limits the sweep to one tenant's
        entries (``None`` sweeps all tenants — single-store callers see
        exactly the old behaviour, since everything is the default
        tenant's).

        Args:
            rels: relationship names touched by the delta, or ``None``.
            tenant: tenant whose entries to sweep, or ``None`` for all.

        Returns:
            Number of entries dropped.

        Usage::

            dropped = cache.invalidate({delta.rel})
        """
        with self._lock:
            if rels is not None:
                rels = frozenset(rels)
            stale = []
            for tkey, e in self._entries.items():
                if tenant is not None and tkey[0] != tenant:
                    continue
                if rels is None or e.deps is None or e.deps & rels:
                    stale.append(tkey)
            for tkey in stale:
                st = self._tenants.get(tkey[0])
                self._evict_one(tkey)
                if st is not None:
                    st.invalidated += 1
            self.invalidated += len(stale)
            return len(stale)

    def info(self) -> dict:
        out = dict(entries=len(self._entries), nbytes=self.nbytes,
                   budget_bytes=self.budget_bytes, hits=self.hits,
                   misses=self.misses, evictions=self.evictions,
                   dropped=self.dropped, invalidated=self.invalidated,
                   delta_updated=self.delta_updated)
        if self._tenants:
            out["tenants"] = self.tenants_info()
        return out


class TenantCache:
    """One tenant's view of a shared :class:`CtCache` — the drop-in
    handle a :class:`~repro.core.engine.CountingEngine` owns in a
    multi-tenant fleet.

    The engine wires ``deps_fn``/``version_fn``/``stats`` onto *this*
    object (exactly as it would onto a private ``CtCache``); resolution
    happens here before delegating, so tenants never clobber each other's
    hooks on the shared store.  All reads/writes/invalidations are scoped
    to the tenant; counters surface the tenant's own slice.

    Usage::

        store = CtCache(budget_bytes=64 << 20)
        store.set_tenant_budget("acme", reserved_bytes=8 << 20)
        eng = CountingEngine(db, cache=store.scoped("acme"))
    """

    def __init__(self, store: CtCache, tenant: str):
        self._store = store
        self.tenant = tenant
        self.deps_fn: Optional[Callable[[Hashable],
                                        Optional[FrozenSet[DepTag]]]] = None
        self.version_fn: Optional[Callable[[], int]] = None

    # -- hook plumbing ------------------------------------------------------
    @property
    def store(self) -> CtCache:
        return self._store

    def _st(self) -> _TenantState:
        return self._store._state(self.tenant)

    @property
    def tracer(self):
        return self._store.tracer

    @tracer.setter
    def tracer(self, value) -> None:
        self._store.tracer = value

    @property
    def stats(self) -> Optional[CostStats]:
        return self._st().stats

    @stats.setter
    def stats(self, value: Optional[CostStats]) -> None:
        self._st().stats = value

    @property
    def budget_bytes(self) -> Optional[int]:
        cap = self._st().cap
        return cap if cap is not None else self._store.budget_bytes

    @property
    def nbytes(self) -> int:
        return self._st().nbytes

    # -- counters (the tenant's slice; writes go through the locked
    # ``count_delta_updates`` below, which keeps the store total in step) ---
    @property
    def hits(self) -> int:
        return self._st().hits

    @property
    def misses(self) -> int:
        return self._st().misses

    @property
    def evictions(self) -> int:
        return self._st().evictions

    @property
    def dropped(self) -> int:
        return self._st().dropped

    @property
    def invalidated(self) -> int:
        return self._st().invalidated

    @property
    def delta_updated(self) -> int:
        return self._st().delta_updated

    def count_delta_updates(self, n: int = 1) -> None:
        self._store.count_delta_updates(n, tenant=self.tenant)

    # -- scoped ops ---------------------------------------------------------
    def __len__(self) -> int:
        return self._store.tenant_len(self.tenant)

    def __contains__(self, key: Hashable) -> bool:
        return self._store.contains(key, tenant=self.tenant)

    def get(self, key: Hashable, default=None):
        return self._store.get(key, default, tenant=self.tenant)

    def put(self, key: Hashable, value: Any,
            nbytes: Optional[int] = None,
            deps: Optional[FrozenSet[DepTag]] = None,
            version: Optional[int] = None) -> Any:
        if deps is None and self.deps_fn is not None:
            deps = self.deps_fn(key)
        if version is None and self.version_fn is not None:
            version = self.version_fn()
        return self._store.put(key, value, nbytes=nbytes, deps=deps,
                               version=version, tenant=self.tenant)

    def peek(self, key: Hashable, default=None):
        return self._store.peek(key, default, tenant=self.tenant)

    def discard(self, key: Hashable) -> bool:
        return self._store.discard(key, tenant=self.tenant)

    def entry_meta(self, key: Hashable):
        return self._store.entry_meta(key, tenant=self.tenant)

    def keys_snapshot(self) -> List[Hashable]:
        return self._store.keys_snapshot(tenant=self.tenant)

    def evict_all(self) -> None:
        self._store.evict_all(tenant=self.tenant)

    def invalidate(self, rels: Optional[Iterable[str]] = None) -> int:
        return self._store.invalidate(rels, tenant=self.tenant)

    def info(self) -> dict:
        out = self._st().info()
        out["tenant"] = self.tenant
        out["budget_bytes"] = self.budget_bytes
        return out
