"""Distributed relational counting over a device mesh.

Counting is linear in edge rows, so the JOIN sweep data-parallelises
perfectly: shard every relationship's edge list over the ``data`` mesh axis,
run the gather -> one-hot multiply -> segment-sum hop on local rows, and
``psum`` the per-entity partials.  Entity-indexed messages stay replicated
(they are small: n_entities x value-space); the ct value space itself can be
sharded over ``model`` for the Möbius/projection phase, which is elementwise
across the attribute axes.

This is the scale-out path for the paper's technique: the 15.8M-row Visual
Genome sweep becomes 15.8M / (pods x data) rows per chip with one all-reduce
per hop.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map

from .contract import CostStats, entity_onehot, _onehot, _expand
from .ct import CtTable
from .database import RelationalDB
from .variables import Atom, CtVar, LatticePoint, Var, edge_var


def _pad_to(arr: np.ndarray, mult: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pad axis 0 to a multiple of ``mult``; returns (padded, weight_mask)."""
    n = arr.shape[0]
    target = ((n + mult - 1) // mult) * mult
    pad = target - n
    w = np.ones(target, dtype=np.float32)
    if pad:
        arr = np.concatenate([arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])
        w[n:] = 0.0
    return arr, w


def _sharded_hop(mesh: Mesh, axis: str, n_parent: int, n_hot: int, dtype,
                 value_axis: Optional[str] = None):
    """Build the shard_map'd join hop for a given arity.

    ``value_axis``: mesh axis to shard the child value-space (column) axis
    over.  The flattened output value axis is child-D-major, so a contiguous
    child-D shard stays a contiguous output shard — each ``value_axis`` rank
    computes its slice of columns for all rows, and the psum runs over
    ``axis`` only.  This puts the otherwise-idle TP ranks to work on the
    JOIN sweep (memory + collective terms drop by the TP degree — §Perf H3)."""

    def hop(child_msg, gidx, sidx, w, *hots):
        m = child_msg[gidx] * w[:, None].astype(dtype)       # (rows_l, D_l)
        for hot in hots:
            rl, d = m.shape
            m = (m[:, :, None] * hot[:, None, :]).reshape(rl, d * hot.shape[1])
        out = jax.ops.segment_sum(m, sidx, num_segments=n_parent)
        return jax.lax.psum(out, axis)

    vspec = value_axis
    in_specs = (P(None, vspec), P(axis), P(axis), P(axis)) + (P(axis),) * n_hot
    return shard_map(hop, mesh=mesh, in_specs=in_specs,
                     out_specs=P(None, vspec), check_vma=False)


def sharded_positive_ct(db: RelationalDB, point: LatticePoint,
                        keep: Optional[Sequence[CtVar]] = None,
                        *, mesh: Mesh, axis: str = "data",
                        dtype=jnp.float32,
                        stats: Optional[CostStats] = None) -> CtTable:
    """Positive ct-table with edge tables sharded over ``axis`` of ``mesh``.

    Semantically identical to :func:`repro.core.contract.positive_ct` (tested
    against it); each tree hop performs local partial counts followed by one
    ``psum``."""
    schema = db.schema
    if keep is None:
        keep = [v for v in point.all_ct_vars(schema, include_rind=False)]
    keep = list(keep)
    nsh = int(np.prod([mesh.shape[a] for a in (axis,)]))

    adj: Dict[Var, List[Tuple[Atom, Var]]] = {}
    for a in point.atoms:
        adj.setdefault(a.src, []).append((a, a.dst))
        adj.setdefault(a.dst, []).append((a, a.src))
    root = point.vars[0]

    def visit(v: Var, parent_atom: Optional[Atom]):
        msg, mvars = entity_onehot(db, v, keep, dtype)
        for atom, u in adj.get(v, ()):
            if atom is parent_atom:
                continue
            child_msg, child_vars = visit(u, atom)
            rt = db.relations[atom.rel]
            if u == atom.src:
                gidx_np, sidx_np = rt.src, rt.dst
                n_parent = db.entities[atom.dst.etype].size
            else:
                gidx_np, sidx_np = rt.dst, rt.src
                n_parent = db.entities[atom.src.etype].size
            gidx, w = _pad_to(gidx_np, nsh)
            sidx, _ = _pad_to(sidx_np, nsh)
            hots, hvars = [], list(child_vars)
            for a_ in rt.type.attrs:
                cv = edge_var(rt.type.name, a_.name, a_.card)
                if cv in keep:
                    col, _ = _pad_to(rt.attrs[a_.name], nsh)
                    hots.append(_onehot(jnp.asarray(col), cv.card, dtype))
                    hvars.append(cv)
            d_child = int(child_msg.shape[1])
            v_axis = ("model" if "model" in mesh.axis_names
                      and d_child % mesh.shape["model"] == 0
                      and mesh.shape["model"] > 1 else None)
            fn = _sharded_hop(mesh, axis, n_parent, len(hots), dtype,
                              value_axis=v_axis)
            hop_out = fn(child_msg, jnp.asarray(gidx), jnp.asarray(sidx),
                         jnp.asarray(w), *hots)
            if stats is not None:
                stats.joins += 1
                stats.rows_scanned += int(gidx.shape[0])
            n, d1 = msg.shape
            msg = (msg[:, :, None] * hop_out[:, None, :]).reshape(
                n, d1 * hop_out.shape[1])
            mvars = mvars + hvars
        return msg, mvars

    msg, mvars = visit(root, None)
    flat = jnp.sum(msg, axis=0)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars else flat.reshape(())
    tab = CtTable(tuple(mvars), counts)
    order = tuple(v for v in keep if v in tab.vars)
    return tab.transpose_to(order) if order != tab.vars else tab


def superset_mobius_sharded(stack: jnp.ndarray, k: int, *, mesh: Mesh,
                            axis: str = "model") -> jnp.ndarray:
    """Möbius butterfly with the flattened attribute axis sharded over
    ``axis``: the transform is elementwise across attributes, so no
    communication is needed — only the layout constraint."""
    lead = stack.shape[:k]
    d = int(np.prod(stack.shape[k:])) if stack.ndim > k else 1
    x = stack.reshape(lead + (d,))
    spec = P(*([None] * k + [axis]))
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
    for i in range(k):
        x0 = jnp.take(x, 0, axis=i) - jnp.take(x, 1, axis=i)
        x1 = jnp.take(x, 1, axis=i)
        x = jnp.stack([x0, x1], axis=i)
    return x.reshape(stack.shape)
