"""Distributed relational counting over a device mesh.

Counting is linear in edge rows, so the JOIN sweep data-parallelises
perfectly: shard every relationship's edge list over the ``data`` mesh axis,
run the gather -> one-hot multiply -> segment-sum hop on local rows, and
``psum`` the per-entity partials.  Entity-indexed messages stay replicated
(they are small: n_entities x value-space); the ct value space itself can be
sharded over ``model`` for the Möbius/projection phase, which is elementwise
across the attribute axes.

Two mesh-sharded paths live here, mirroring the two executors:

* :func:`sharded_positive_ct` — the dense one-hot path, written directly
  against the database (predates the planner);
* :class:`ShardedSparseExecutor` — the O(nnz) path: a drop-in
  :class:`~repro.core.executors.SparseExecutor` whose mixed-radix
  segment-sum hops run under ``shard_map`` over the ``data`` axis.  It
  walks :class:`~repro.core.plan.ContractionPlan` unchanged — only the two
  device primitives (edge scatter-add, root combine) are replaced, so it
  inherits every strategy/Möbius/cache behaviour and is property-tested
  against the oracle like any registered executor
  (``EXECUTORS["sparse_sharded"]``).

This is the scale-out path for the paper's technique: the 15.8M-row Visual
Genome sweep becomes 15.8M / (pods x data) rows per chip with one all-reduce
per hop.  For scaling beyond one mesh — horizontally partitioned
*databases*, one service per shard — see :mod:`repro.core.database`
(``ShardedDatabase``) and :mod:`repro.serve.router`.
"""

from __future__ import annotations

import functools
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map

from .contract import CostStats, entity_onehot, _onehot, _expand
from .ct import CtTable
from .database import RelationalDB
from .executors import EXECUTORS, SparseExecutor, _kr_segment_sum
from .variables import Atom, CtVar, LatticePoint, Var, edge_var


def _pad_to(arr: np.ndarray, mult: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pad axis 0 to a multiple of ``mult``; returns (padded, weight_mask)."""
    n = arr.shape[0]
    target = ((n + mult - 1) // mult) * mult
    pad = target - n
    w = np.ones(target, dtype=np.float32)
    if pad:
        arr = np.concatenate([arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])
        w[n:] = 0.0
    return arr, w


def _segsum_shard_kernel(total: int):
    """Per-rank Pallas scatter-add bodies for a ``shard_map`` closure, or
    ``None`` to stay on ``jax.ops.segment_sum``.  Resolved once when the
    closure is built — the cached jitted ``shard_map`` bakes the backend
    choice in, so the env override must be set before the first hop."""
    from ..kernels import ops as kernel_ops
    if not kernel_ops.segsum_kernel_enabled(total):
        return None
    from types import SimpleNamespace
    from ..kernels.segsum_kernel import (segment_sum_ones_pallas,
                                         segment_sum_rows_pallas)
    interp = kernel_ops.default_interpret()
    return SimpleNamespace(
        ones=functools.partial(segment_sum_ones_pallas, interpret=interp),
        rows=functools.partial(segment_sum_rows_pallas, interpret=interp))


def _sharded_hop(mesh: Mesh, axis: str, n_parent: int, n_hot: int, dtype,
                 value_axis: Optional[str] = None):
    """Build the shard_map'd join hop for a given arity.

    ``value_axis``: mesh axis to shard the child value-space (column) axis
    over.  The flattened output value axis is child-D-major, so a contiguous
    child-D shard stays a contiguous output shard — each ``value_axis`` rank
    computes its slice of columns for all rows, and the psum runs over
    ``axis`` only.  This puts the otherwise-idle TP ranks to work on the
    JOIN sweep (memory + collective terms drop by the TP degree — §Perf H3)."""

    def hop(child_msg, gidx, sidx, w, *hots):
        m = child_msg[gidx] * w[:, None].astype(dtype)       # (rows_l, D_l)
        for hot in hots:
            rl, d = m.shape
            m = (m[:, :, None] * hot[:, None, :]).reshape(rl, d * hot.shape[1])
        out = jax.ops.segment_sum(m, sidx, num_segments=n_parent)
        return jax.lax.psum(out, axis)

    vspec = value_axis
    in_specs = (P(None, vspec), P(axis), P(axis), P(axis)) + (P(axis),) * n_hot
    return shard_map(hop, mesh=mesh, in_specs=in_specs,
                     out_specs=P(None, vspec), check_vma=False)


def sharded_positive_ct(db: RelationalDB, point: LatticePoint,
                        keep: Optional[Sequence[CtVar]] = None,
                        *, mesh: Mesh, axis: str = "data",
                        dtype=jnp.float32,
                        stats: Optional[CostStats] = None) -> CtTable:
    """Positive ct-table (dense one-hot path) with edge tables sharded
    over ``axis`` of ``mesh``.

    Semantically identical to :func:`repro.core.contract.positive_ct`
    (tested against it); each tree hop performs local partial counts
    followed by one ``psum``.  When the mesh also has a ``model`` axis
    that divides a hop's value-space width, that hop's columns are
    sharded over it too (the otherwise-idle TP ranks join the sweep).

    Args:
        db: the database to count over.
        point: lattice point (>= 1 relationship atom).
        keep: ct-table axes to keep; defaults to every entity/edge
            attribute of the point (no indicator axes — positives only).
        mesh: the device mesh (keyword-only).
        axis: mesh axis to shard edge rows over.
        dtype: accumulation dtype of the counts.
        stats: optional :class:`~repro.core.contract.CostStats` to record
            join/row accounting into.

    Returns:
        The positive :class:`~repro.core.ct.CtTable` over ``keep``.

    Usage::

        tab = sharded_positive_ct(db, point, mesh=mesh, axis="data")
    """
    schema = db.schema
    if keep is None:
        keep = [v for v in point.all_ct_vars(schema, include_rind=False)]
    keep = list(keep)
    nsh = int(np.prod([mesh.shape[a] for a in (axis,)]))

    adj: Dict[Var, List[Tuple[Atom, Var]]] = {}
    for a in point.atoms:
        adj.setdefault(a.src, []).append((a, a.dst))
        adj.setdefault(a.dst, []).append((a, a.src))
    root = point.vars[0]

    def visit(v: Var, parent_atom: Optional[Atom]):
        msg, mvars = entity_onehot(db, v, keep, dtype)
        for atom, u in adj.get(v, ()):
            if atom is parent_atom:
                continue
            child_msg, child_vars = visit(u, atom)
            rt = db.relations[atom.rel]
            if u == atom.src:
                gidx_np, sidx_np = rt.src, rt.dst
                n_parent = db.entities[atom.dst.etype].size
            else:
                gidx_np, sidx_np = rt.dst, rt.src
                n_parent = db.entities[atom.src.etype].size
            gidx, w = _pad_to(gidx_np, nsh)
            sidx, _ = _pad_to(sidx_np, nsh)
            hots, hvars = [], list(child_vars)
            for a_ in rt.type.attrs:
                cv = edge_var(rt.type.name, a_.name, a_.card)
                if cv in keep:
                    col, _ = _pad_to(rt.attrs[a_.name], nsh)
                    hots.append(_onehot(jnp.asarray(col), cv.card, dtype))
                    hvars.append(cv)
            d_child = int(child_msg.shape[1])
            v_axis = ("model" if "model" in mesh.axis_names
                      and d_child % mesh.shape["model"] == 0
                      and mesh.shape["model"] > 1 else None)
            fn = _sharded_hop(mesh, axis, n_parent, len(hots), dtype,
                              value_axis=v_axis)
            hop_out = fn(child_msg, jnp.asarray(gidx), jnp.asarray(sidx),
                         jnp.asarray(w), *hots)
            if stats is not None:
                stats.joins += 1
                stats.rows_scanned += int(gidx.shape[0])
            n, d1 = msg.shape
            msg = (msg[:, :, None] * hop_out[:, None, :]).reshape(
                n, d1 * hop_out.shape[1])
            mvars = mvars + hvars
        return msg, mvars

    msg, mvars = visit(root, None)
    flat = jnp.sum(msg, axis=0)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars else flat.reshape(())
    tab = CtTable(tuple(mvars), counts)
    order = tuple(v for v in keep if v in tab.vars)
    return tab.transpose_to(order) if order != tab.vars else tab


# ---------------------------------------------------------------------------
# sharded sparse executor: the O(nnz) path over a device mesh
# ---------------------------------------------------------------------------

class ShardedSparseExecutor(SparseExecutor):
    """:class:`~repro.core.executors.SparseExecutor` with its segment-sum
    device steps sharded over one mesh axis.

    The plan walk, the mixed-radix code arithmetic and the caching semantics
    are inherited unchanged; only the two device primitives change:

    * **edge scatter-add** (:meth:`_edge_segment_sum`) — the per-hop edge
      list (padded to a multiple of the shard count) is split over
      ``axis``; each rank ``segment_sum``-s its local rows into the full
      ``(parent, code)`` segment space and the partials merge with a single
      ``psum``.  This is the Möbius-join parallelisation of Qian & Schulte:
      sufficient statistics are sums over data partitions.
    * **root combine** (:meth:`_reduce_by_code`) — entity rows (root codes
      + factor matrices) are split over ``axis`` the same way; one
      ``psum`` of the ``(root_card, D)`` partial tables merges them.

    Both primitives keep their jitted ``shard_map`` closures in a keyed
    cache (``_shard_fn_cache``, one entry per distinct device-step shape —
    analogous to the executor's ``_batch_cache``), so a flood of
    same-shape hops traces each step ONCE instead of rebuilding and
    retracing the closure on every hop; ``trace_counts`` records actual
    trace events per key and is asserted flat across a flood in
    ``tests/test_distributed_counting.py``.

    Counts are integer-valued, so the per-rank reordering is exact: sharded
    results are numerically identical to :class:`SparseExecutor`
    (property-tested in ``tests/test_distributed_counting.py``).

    Stacked/vmapped batch dispatch is intentionally NOT sharded
    (``positive_batch`` falls back to per-plan sharded execution):
    scaling out a *flood* of queries is the database-sharding router's job
    (:mod:`repro.serve.router`), while this class scales out one large
    contraction.

    Args:
        dtype / mobius_fn / use_pallas_mobius: as for
            :class:`~repro.core.executors.Executor`.
        mesh: the device mesh; defaults to a 1-D mesh over every visible
            device named ``(axis,)``.
        axis: mesh axis name to shard edge/entity rows over.

    Raises:
        ValueError: ``axis`` is not an axis of ``mesh``.

    Usage::

        ex = ShardedSparseExecutor(mesh=jax.make_mesh((8,), ("data",)))
        tab = CountingEngine(db, ex).contract(point, keep)
    """

    name = "sparse_sharded"

    def __init__(self, dtype=jnp.float32, mobius_fn=None,
                 use_pallas_mobius: bool = False,
                 mesh: Optional[Mesh] = None, axis: str = "data"):
        super().__init__(dtype=dtype, mobius_fn=mobius_fn,
                         use_pallas_mobius=use_pallas_mobius)
        if mesh is None:
            mesh = Mesh(np.asarray(jax.devices()), (axis,))
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh axes "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.n_ranks = int(mesh.shape[axis])
        # (kind, segment space, padded rows, widths...) -> jitted shard_map
        # closure; one trace per key, flat across a flood
        self._shard_fn_cache: Dict[Tuple, object] = {}
        self.trace_counts: Dict[Tuple, int] = {}
        self._force_local = False      # see local_mode()

    @contextmanager
    def local_mode(self):
        """Run device primitives UNSHARDED inside this context.  The
        engine's delta count maintenance contracts a handful of delta
        edges per cached entry — padding those to the mesh and paying a
        ``psum`` per hop costs more than the count itself, so the delta
        path drops to the inherited single-device segment-sums (exact
        either way; counts are integers).  Not re-entrant across threads:
        callers hold the service's execution fence."""
        prev, self._force_local = self._force_local, True
        try:
            yield self
        finally:
            self._force_local = prev

    # -- shard_map closure cache --------------------------------------------
    def _shard_fn(self, key: Tuple, build):
        """Keyed cache of jitted ``shard_map`` closures.  ``build(key)``
        constructs the closure once per distinct device-step shape; the
        jitted result is reused for every later hop with the same key, so
        a flood of same-shape queries never retraces."""
        fn = self._shard_fn_cache.get(key)
        if fn is None:
            self.trace_counts.setdefault(key, 0)
            fn = self._shard_fn_cache[key] = build(key)
        return fn

    def _count_trace(self, key: Tuple) -> None:
        # runs at TRACE time only (inside the shard_map body): the flood
        # test pins these counters flat after the first execution
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    def _build_edge_ones(self, key: Tuple):
        _, total, _ = key
        ax = self.axis
        # backend routing is resolved at BUILD time (the closure is cached
        # per key): each rank's local scatter-add runs the Pallas kernel
        # when enabled — the mesh-padding 0/1 mask rides along as the
        # kernel's weight vector — and the psum merges ranks either way
        kernel = _segsum_shard_kernel(total)

        def ones_hop(seg_l, w_l):
            self._count_trace(key)
            if kernel is not None:
                out = kernel.ones(seg_l, w_l.astype(jnp.float32),
                                  total).astype(self.dtype)
            else:
                out = jax.ops.segment_sum(w_l.astype(self.dtype), seg_l,
                                          num_segments=total)
            return jax.lax.psum(out, ax)

        return jax.jit(shard_map(ones_hop, mesh=self.mesh,
                                 in_specs=(P(ax), P(ax)), out_specs=P(None),
                                 check_vma=False))

    def _build_edge_dense(self, key: Tuple):
        _, total, _, _ = key
        ax = self.axis
        kernel = _segsum_shard_kernel(total)

        def dense_hop(seg_l, rows_l):
            self._count_trace(key)
            if kernel is not None:
                out = kernel.rows(seg_l, rows_l, total).astype(self.dtype)
            else:
                out = jax.ops.segment_sum(rows_l, seg_l, num_segments=total)
            return jax.lax.psum(out, ax)

        return jax.jit(shard_map(dense_hop, mesh=self.mesh,
                                 in_specs=(P(ax), P(ax, None)),
                                 out_specs=P(None, None), check_vma=False))

    def _build_reduce_ones(self, key: Tuple):
        _, ds, _ = key
        ax = self.axis

        def ones_reduce(c_l, w_l):
            self._count_trace(key)
            out = jax.ops.segment_sum(w_l.astype(self.dtype), c_l,
                                      num_segments=ds)
            return jax.lax.psum(out, ax)

        return jax.jit(shard_map(ones_reduce, mesh=self.mesh,
                                 in_specs=(P(ax), P(ax)), out_specs=P(None),
                                 check_vma=False))

    def _build_reduce_kr(self, key: Tuple):
        _, ds, _, widths = key
        ax = self.axis

        def kr_reduce(c_l, *ms):
            self._count_trace(key)
            return jax.lax.psum(
                _kr_segment_sum(c_l, list(ms), ds, self.dtype), ax)

        in_specs = (P(ax),) + (P(ax, None),) * len(widths)
        return jax.jit(shard_map(kr_reduce, mesh=self.mesh,
                                 in_specs=in_specs,
                                 out_specs=P(None, None), check_vma=False))

    # -- device primitives, sharded -----------------------------------------
    def _edge_segment_sum(self, seg_np: np.ndarray,
                          rows: Optional[jnp.ndarray],
                          total: int) -> jnp.ndarray:
        if self.n_ranks == 1 or self._force_local:
            return super()._edge_segment_sum(seg_np, rows, total)
        seg, w = _pad_to(seg_np, self.n_ranks)
        if rows is None:
            fn = self._shard_fn(("edge_ones", total, int(seg.shape[0])),
                                self._build_edge_ones)
            return fn(jnp.asarray(seg), jnp.asarray(w))

        rows_p = jnp.pad(rows, ((0, seg.shape[0] - rows.shape[0]), (0, 0)))
        fn = self._shard_fn(("edge_dense", total, int(seg.shape[0]),
                             int(rows_p.shape[1])), self._build_edge_dense)
        return fn(jnp.asarray(seg), rows_p)

    def _reduce_by_code(self, code, ds: int, n: int,
                        factors: Sequence[jnp.ndarray]) -> jnp.ndarray:
        if self.n_ranks == 1 or self._force_local:
            return super()._reduce_by_code(code, ds, n, factors)
        code_np = (np.zeros((n,), dtype=np.int32) if code is None
                   else np.asarray(code))
        code_p, w = _pad_to(code_np, self.n_ranks)
        if not factors:
            fn = self._shard_fn(("reduce_ones", ds, int(code_p.shape[0])),
                                self._build_reduce_ones)
            return fn(jnp.asarray(code_p), jnp.asarray(w))

        n_pad = int(code_p.shape[0])
        # no weight mask here: the factor rows are zero-padded, so padding
        # contributes nothing to segment 0
        mats = [jnp.pad(f, ((0, n_pad - n), (0, 0))) for f in factors]
        widths = tuple(int(m.shape[1]) for m in mats)
        fn = self._shard_fn(("reduce_kr", ds, n_pad, widths),
                            self._build_reduce_kr)
        return fn(jnp.asarray(code_p), *mats).reshape(-1)

    # -- batching -----------------------------------------------------------
    def _positive_stacked(self, db, plans, stats):
        # vmap over shard_map is deliberately avoided: per-plan execution is
        # already mesh-parallel, and query-level fan-out belongs to the
        # serve router.  positive_batch's loop fallback handles this.  On a
        # 1-rank mesh nothing is sharded, so the inherited stacked path
        # (bit-identical there) keeps flood dispatch fast.
        if self.n_ranks == 1:
            return super()._positive_stacked(db, plans, stats)
        raise NotImplementedError("sharded sparse plans run one at a time")


EXECUTORS["sparse_sharded"] = ShardedSparseExecutor


def sharded_sparse_positive_ct(db: RelationalDB, point: LatticePoint,
                               keep: Optional[Sequence[CtVar]] = None,
                               *, mesh: Optional[Mesh] = None,
                               axis: str = "data", dtype=jnp.float32,
                               stats: Optional[CostStats] = None) -> CtTable:
    """Positive ct-table via the sparse O(nnz) path, edge lists sharded
    over ``axis`` of ``mesh``.

    Convenience wrapper: compiles the :class:`~repro.core.plan
    .ContractionPlan` for ``(point, keep)`` and evaluates it with a
    :class:`ShardedSparseExecutor`.  Numerically identical to the
    single-device sparse executor (and to :func:`sharded_positive_ct`,
    the dense path).

    Args:
        db: the database to count over.
        point: lattice point (>= 1 relationship atom).
        keep: ct-table axes to keep; defaults to every entity/edge
            attribute of the point (no indicator axes — positives only).
        mesh / axis: device mesh and the axis to shard rows over;
            ``mesh=None`` builds a 1-D mesh over all visible devices.
        dtype: accumulation dtype of the counts.
        stats: optional :class:`~repro.core.contract.CostStats` to record
            join/row accounting into.

    Returns:
        The positive :class:`~repro.core.ct.CtTable` over ``keep``.

    Usage::

        tab = sharded_sparse_positive_ct(db, point, mesh=mesh)
    """
    from .plan import compile_plan_cached
    if keep is None:
        keep = point.all_ct_vars(db.schema, include_rind=False)
    ex = ShardedSparseExecutor(dtype=dtype, mesh=mesh, axis=axis)
    plan = compile_plan_cached(db.schema, point, tuple(keep))
    return ex.positive(db, plan, stats)


def merge_stacked(stacked: jnp.ndarray, axis_name: str = "data"
                  ) -> jnp.ndarray:
    """Device reduction of a ``(n_partials, ...)`` stack of same-shape
    count tables — the router's merge step, meant to be traced inside one
    jitted dispatch (see :class:`~repro.serve.batching.TableMerger`).

    With at least one device per partial the stack is laid over a fresh
    ``data`` mesh and tree-merged with a ``psum`` — each shard's partial
    is reduced where it lives, one collective instead of ``n - 1``
    sequential adds.  On fewer devices (the one-host case) it is a single
    stacked ``jnp.sum``.  Exact either way: counts are integers and
    addition is associative, so no reassociation error exists to care
    about.

    Usage::

        merged = merge_stacked(jnp.stack([tab_a, tab_b]))
    """
    n = int(stacked.shape[0])
    if n == 1:
        return stacked[0]
    devs = jax.devices()
    if len(devs) >= n:
        mesh = Mesh(np.asarray(devs[:n]), (axis_name,))
        red = shard_map(
            lambda x: jax.lax.psum(jnp.sum(x, axis=0), axis_name),
            mesh=mesh, in_specs=P(axis_name), out_specs=P(),
            check_vma=False)
        return red(stacked)
    return jnp.sum(stacked, axis=0)


def superset_mobius_sharded(stack: jnp.ndarray, k: int, *, mesh: Mesh,
                            axis: str = "model") -> jnp.ndarray:
    """Möbius butterfly with the flattened attribute axis sharded over
    ``axis``: the transform is elementwise across attributes, so no
    communication is needed — only the layout constraint.

    Args:
        stack: the butterfly input; the leading ``k`` axes are the binary
            indicator axes, the rest is the attribute value space.
        k: number of leading indicator axes to transform over.
        mesh / axis: device mesh and the axis to shard attributes over.

    Returns:
        The transformed stack, same shape as ``stack``.

    Usage::

        neg = superset_mobius_sharded(stack, k, mesh=mesh, axis="model")
    """
    lead = stack.shape[:k]
    d = int(np.prod(stack.shape[k:])) if stack.ndim > k else 1
    x = stack.reshape(lead + (d,))
    spec = P(*([None] * k + [axis]))
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
    for i in range(k):
        x0 = jnp.take(x, 0, axis=i) - jnp.take(x, 1, axis=i)
        x1 = jnp.take(x, 1, axis=i)
        x = jnp.stack([x0, x1], axis=i)
    return x.reshape(stack.shape)
