"""PRECOUNT / ONDEMAND / HYBRID / TUPLEID counts-caching strategies
(paper Algs. 1-3 + the tuple-ID future-work variant).

All four expose the same interface to structure search:

    prepare(db, lattice)                    # pre-search phase
    family_ct(point, keep_vars) -> CtTable  # during search

and record the paper's instrumentation (Fig. 3 time decomposition into
metadata / positive / negative, Fig. 4 memory, Table 5 ct sizes) in
``stats``.

Since the planner/executor/cache refactor each strategy is a *thin policy*
over shared machinery (:mod:`repro.core.engine`): it picks a positive-table
policy, decides what runs at ``prepare`` time vs. search time, and shares
one byte-budgeted :class:`~repro.core.cache.CtCache` across positives,
messages, family memos and histograms.  The contraction backend is
pluggable (``executor="dense" | "sparse"``) and the Möbius negative phase
runs through the executor (wired to the Pallas kernel with
``use_pallas_mobius=True``, or any ``mobius_fn`` override).

* PRECOUNT — prepare() contracts the positive ct-table for every lattice
  point AND runs the Möbius join to the complete table over *all* variables
  of the point; family_ct() is a pure projection.  Pays the Eq. (3) blowup.
* ONDEMAND — prepare() builds only per-variable histograms (metadata);
  family_ct() contracts the family's positive tables from the raw data (the
  expensive JOINs, re-run per family) then runs a small Möbius join.
* HYBRID — prepare() contracts and caches only the *positive* ct-table per
  lattice point (JOINs once, like PRECOUNT); family_ct() projects the
  cached positives down to the family and runs a small Möbius join (like
  ONDEMAND, but with zero data access).
* TUPLEID — prepare() caches per-relationship message matrices (tuple-ID
  propagation); family positives recombine them with zero edge access.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from .contract import CostStats
from .ct import CtTable
from .database import RelationalDB
from .engine import (CachedFullPositives, CountingEngine, OnDemandPositives,
                     TupleIdPositives)
from .mobius import (butterfly_batch, complete_ct, complete_ct_many,
                     positive_queries)
from .variables import CtVar, LatticePoint


def _freeze(point: LatticePoint, keep: Sequence[CtVar]) -> Tuple:
    return (point.atoms, tuple(keep))


@dataclass
class Strategy:
    """Base policy: shared engine, unified family memo, Möbius wiring.

    Subclasses set ``_policy_cls`` and ``_precount_complete`` /
    ``_warm_hists`` flags — everything else (caching, stats, executor and
    Möbius dispatch) lives in the shared machinery.
    """

    name: str = "base"
    dtype: object = jnp.float32
    use_butterfly: bool = True
    mobius_fn: Optional[object] = None     # overrides the executor's step
    stats: CostStats = field(default_factory=CostStats)
    executor: object = "dense"             # name or Executor instance
    cache_budget_bytes: Optional[int] = None
    use_pallas_mobius: bool = False

    _policy_cls = None                     # set by subclasses
    _precount_complete = False             # PRECOUNT: complete tables upfront
    _warm_hists = False                    # ONDEMAND: hists are the metadata

    # -- pre-search phase ----------------------------------------------------
    def prepare(self, db: RelationalDB,
                lattice: Sequence[LatticePoint]) -> None:
        self.db, self.lattice = db, list(lattice)
        with self.stats.timer("metadata"):
            from .executors import make_executor
            ex = (self.executor if not isinstance(self.executor, str)
                  else make_executor(self.executor, dtype=self.dtype,
                                     use_pallas_mobius=self.use_pallas_mobius))
            self.engine = CountingEngine(
                db, ex, self.stats,
                cache_budget_bytes=self.cache_budget_bytes, dtype=self.dtype)
            self.provider = self._policy_cls(self.engine)
            self._service = None           # rebuilt lazily over this engine
            self._rows_counted = set()
            if self._warm_hists:
                for point in lattice:
                    for v in point.vars:
                        self.provider.hist(v, ())
        # data access inside the policy times itself (-> time_positive),
        # including any eviction-driven recompute later on
        self.provider.precompute(lattice)
        if self._precount_complete:
            for point in lattice:
                self._complete_full(point)

    # -- complete tables -----------------------------------------------------
    def _mobius_fn(self):
        return self.mobius_fn if self.mobius_fn is not None \
            else self.engine.executor.mobius

    def _timed_complete(self, point: LatticePoint,
                        keep: Tuple[CtVar, ...]) -> CtTable:
        """Möbius join timed as negative-phase work; positive contractions
        nested inside it (ONDEMAND joins, eviction recomputes) time
        themselves in the policy, so the disjoint timer subtracts that
        growth to keep the Fig. 3 decomposition disjoint."""
        with self.stats.disjoint_timer("negative"):
            return complete_ct(point, keep, self.provider, self.stats,
                               use_butterfly=self.use_butterfly,
                               mobius_fn=self._mobius_fn())

    def _complete_full(self, point: LatticePoint) -> CtTable:
        """Complete (positive+negative) table over *all* axes of a point —
        the PRECOUNT global ct.  Cached; recomputed if evicted.  Keyed by
        ``(atoms, keep)`` so the delta path can reconstruct the exact
        query and push butterfly deltas onto the resident table."""
        keep = tuple(point.all_ct_vars(self.db.schema, include_rind=True))
        key = ("complete", point.atoms, keep)
        hit = self.engine.cache.get(key)
        if hit is None:
            hit = self._timed_complete(point, keep)
            if key not in self._rows_counted:    # once per point, not per
                self._rows_counted.add(key)      # eviction recompute
                self.stats.ct_rows += hit.nnz_rows()
            self.engine.cache.put(key, hit)
        return hit

    # -- search phase --------------------------------------------------------
    def family_ct(self, point: LatticePoint,
                  keep: Sequence[CtVar]) -> CtTable:
        if self._precount_complete:
            return self._complete_full(point).project(keep)
        key = ("fam",) + _freeze(point, keep)
        hit = self.engine.cache.get(key)
        if hit is not None:
            return hit
        tab = self._timed_complete(point, tuple(keep))
        self.engine.cache.put(key, tab)
        return tab

    # -- batched search phase (the serve layer as counting backend) ----------
    def service(self):
        """Lazy per-strategy :class:`~repro.serve.service.CountingService`
        over the shared engine — the batching front-end for this
        strategy's positive contractions."""
        svc = getattr(self, "_service", None)
        if svc is None:
            from ..serve.service import CountingService
            svc = self._service = CountingService(self.engine)
        return svc

    def _mobius_batch_fn(self):
        """The batched negative-phase step, honouring a ``mobius_fn``
        override the same way :meth:`_mobius_fn` does."""
        if self.mobius_fn is not None:
            return lambda stacks, k: butterfly_batch(stacks, k,
                                                     self.mobius_fn)
        return self.engine.executor.mobius_batch

    def _mobius_fused_fn(self):
        """The FUSED batched negative phase (assembly + transform +
        finalise in one jitted dispatch per shape/perm group).  A
        ``mobius_fn`` override opts out: the fused evaluator traces the
        executor's own step, so an ad-hoc override falls back to the
        unfused batched path."""
        if self.mobius_fn is not None:
            return None
        return self.engine.executor.mobius_batch_fused

    # -- mutations -----------------------------------------------------------
    def apply_delta(self, delta, **kw):
        """Reconcile this strategy's cache after a store mutation —
        delegates to :meth:`~repro.core.engine.CountingEngine
        .apply_delta` (fine-grained invalidation + in-place delta updates
        of positive artefacts).

        Usage::

            delta = db.insert_facts("Rated", src, dst, {"rating": vals})
            report = strategy.apply_delta(delta)
        """
        return self.engine.apply_delta(delta, **kw)

    def family_ct_many(self, point: LatticePoint,
                       keeps: Sequence[Sequence[CtVar]]) -> list:
        """Fetch a whole round of family tables at once — both Möbius
        phases batched.

        The positive sub-queries every missing family's Möbius join will
        issue are enumerated up front (:func:`~repro.core.mobius
        .positive_queries`), filtered to what the positive policy would
        actually contract from data, and executed through the counting
        service in signature-bucketed stacked dispatches.  The *negative*
        phase of the missing families then runs through
        :func:`~repro.core.mobius.complete_ct_many`: butterfly input
        stacks are grouped by shape (same-signature families are
        same-shape by construction) and each group is transformed in ONE
        jitted dispatch (:meth:`~repro.core.executors.Executor
        .mobius_batch`).  Results — including the recompute semantics
        under cache eviction — are numerically identical to per-family
        :meth:`family_ct`, which serves the final answers from the warmed
        ``"fam"`` cache."""
        keeps = [tuple(k) for k in keeps]
        if self._precount_complete or len(keeps) <= 1:
            return [self.family_ct(point, keep) for keep in keeps]
        cache = self.engine.cache
        missing = [keep for keep in keeps
                   if ("fam",) + _freeze(point, keep) not in cache]
        missing = list(dict.fromkeys(missing))
        if missing and self.provider.supports_batch_prefetch:
            queries = []
            for keep in missing:
                queries.extend(positive_queries(point, keep,
                                                self.use_butterfly))
            self.service().prefetch(self.provider, queries)
        fresh = {}
        if missing:
            with self.stats.disjoint_timer("negative"):
                tabs = complete_ct_many(
                    [(point, keep) for keep in missing], self.provider,
                    self.stats, use_butterfly=self.use_butterfly,
                    mobius_fn=self._mobius_fn(),
                    mobius_batch_fn=self._mobius_batch_fn(),
                    mobius_fused_fn=self._mobius_fused_fn())
            for keep, tab in zip(missing, tabs):
                cache.put(("fam",) + _freeze(point, keep), tab)
                fresh[keep] = tab      # return directly: under a tight
                                       # budget the puts may evict each
                                       # other, and a cache round-trip
                                       # would recompute per family
        return [fresh[keep] if keep in fresh
                else self.family_ct(point, keep) for keep in keeps]


class OnDemand(Strategy):
    _policy_cls = OnDemandPositives
    _warm_hists = True

    def __init__(self, **kw):
        super().__init__(name="ONDEMAND", **kw)


class Precount(Strategy):
    _policy_cls = CachedFullPositives
    _precount_complete = True

    def __init__(self, **kw):
        super().__init__(name="PRECOUNT", **kw)


class Hybrid(Strategy):
    _policy_cls = CachedFullPositives

    def __init__(self, **kw):
        super().__init__(name="HYBRID", **kw)


class TupleId(Strategy):
    """The paper's future-work pre-count variant: tuple-ID propagation."""

    _policy_cls = TupleIdPositives

    def __init__(self, **kw):
        super().__init__(name="TUPLEID", **kw)


STRATEGIES = {"PRECOUNT": Precount, "ONDEMAND": OnDemand, "HYBRID": Hybrid,
              "TUPLEID": TupleId}


def make_strategy(name: str, **kw) -> Strategy:
    return STRATEGIES[name.upper()](**kw)


# ---------------------------------------------------------------------------
# compatibility constructors for the pre-refactor provider classes (tests
# and external callers build these directly around complete_ct)
# ---------------------------------------------------------------------------

def _engine(db, stats, dtype):
    return CountingEngine(db, "dense", stats, dtype=dtype)


def _OnDemandProvider(db, stats, dtype=jnp.float32) -> OnDemandPositives:
    return OnDemandPositives(_engine(db, stats, dtype))


def _CachedPositiveProvider(db, stats, dtype=jnp.float32) -> CachedFullPositives:
    return CachedFullPositives(_engine(db, stats, dtype))


def _TupleIdProvider(db, stats, dtype=jnp.float32) -> TupleIdPositives:
    return TupleIdPositives(_engine(db, stats, dtype))
