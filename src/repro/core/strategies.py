"""PRECOUNT / ONDEMAND / HYBRID counts-caching strategies (paper Algs. 1-3).

All three expose the same interface to structure search:

    prepare(db, lattice)                  # pre-search phase
    family_ct(point, keep_vars) -> CtTable  # during search

and record the paper's instrumentation (Fig. 3 time decomposition into
metadata / positive / negative, Fig. 4 memory, Table 5 ct sizes) in ``stats``.

* PRECOUNT — prepare() contracts the positive ct-table for every lattice
  point AND runs the Möbius join to the complete table over *all* variables
  of the point; family_ct() is a pure projection.  Pays the Eq. (3) blowup.
* ONDEMAND — prepare() builds only per-variable histograms (metadata);
  family_ct() contracts the family's positive tables from the raw data (the
  expensive JOINs, re-run per family) then runs a small Möbius join.  Family
  results are memoised for revisits.
* HYBRID — prepare() contracts and caches only the *positive* ct-table per
  lattice point (JOINs once, like PRECOUNT); family_ct() projects the cached
  positives down to the family and runs a small Möbius join (like ONDEMAND,
  but with zero data access).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from .contract import CostStats, entity_hist, positive_ct
from .ct import CtTable
from .database import RelationalDB
from .mobius import PositiveProvider, complete_ct
from .variables import CtVar, LatticePoint, Var, build_lattice


def _freeze(point: LatticePoint, keep: Sequence[CtVar]) -> Tuple:
    return (point.atoms, tuple(keep))


class _OnDemandProvider:
    """Contracts positive tables straight from the database (counts JOINs);
    memoises within a strategy instance (the paper's post-count cache)."""

    def __init__(self, db: RelationalDB, stats: CostStats, dtype=jnp.float32):
        self.db, self.stats, self.dtype = db, stats, dtype
        self._cache: Dict[Tuple, CtTable] = {}
        self._hists: Dict[Tuple, CtTable] = {}

    def positive(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> CtTable:
        key = _freeze(point, keep)
        if key not in self._cache:
            with self.stats.timer("positive"):   # the per-family JOIN cost
                t = positive_ct(self.db, point, keep, self.dtype, self.stats)
            self._cache[key] = t
            self.stats.bump_cache(t.nbytes)
            self.stats.ct_rows += t.nnz_rows()
        return self._cache[key]

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        key = (var, tuple(keep))
        if key not in self._hists:
            self._hists[key] = entity_hist(self.db, var, keep, self.dtype)
        return self._hists[key]


class _CachedPositiveProvider:
    """Serves positives by *projection* from full-attribute positive tables
    pre-computed per lattice point — zero data access (HYBRID / PRECOUNT)."""

    def __init__(self, db: RelationalDB, stats: CostStats, dtype=jnp.float32):
        self.db, self.stats, self.dtype = db, stats, dtype
        self.full: Dict[frozenset, CtTable] = {}   # rels -> full positive ct
        self._hists: Dict[Tuple, CtTable] = {}

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        for point in lattice:
            t = positive_ct(self.db, point, None, self.dtype, self.stats)
            self.full[frozenset(point.rels)] = t
            self.stats.bump_cache(t.nbytes)
            self.stats.ct_rows += t.nnz_rows()

    def positive(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> CtTable:
        # NOTE §Perf H3 it.3: memoising these projections by (atoms, keep)
        # was tried and REFUTED — CtVar-tuple hashing overhead exceeded the
        # projection cost at every dataset size measured.
        full = self.full.get(frozenset(point.rels))
        if full is None:  # sub-pattern not in lattice (shouldn't happen: lattice is downward closed)
            full = positive_ct(self.db, point, None, self.dtype, self.stats)
            self.full[frozenset(point.rels)] = full
            self.stats.bump_cache(full.nbytes)
        return full.project(keep)

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        key = (var, tuple(keep))
        if key not in self._hists:
            self._hists[key] = entity_hist(self.db, var, keep, self.dtype)
        return self._hists[key]


@dataclass
class Strategy:
    name: str = "base"
    dtype: object = jnp.float32
    use_butterfly: bool = True
    mobius_fn: Optional[object] = None   # plug the Pallas kernel here
    stats: CostStats = field(default_factory=CostStats)

    def prepare(self, db: RelationalDB, lattice: Sequence[LatticePoint]) -> None:
        raise NotImplementedError

    def family_ct(self, point: LatticePoint, keep: Sequence[CtVar]) -> CtTable:
        raise NotImplementedError

    # shared: memoised family results (both post-counting methods revisit)
    def _memo_get(self, key):
        return getattr(self, "_family_cache", {}).get(key)

    def _memo_put(self, key, tab: CtTable):
        if not hasattr(self, "_family_cache"):
            self._family_cache: Dict = {}
        self._family_cache[key] = tab
        self.stats.bump_cache(tab.nbytes)


class OnDemand(Strategy):
    def __init__(self, **kw):
        super().__init__(name="ONDEMAND", **kw)

    def prepare(self, db: RelationalDB, lattice: Sequence[LatticePoint]) -> None:
        self.db, self.lattice = db, list(lattice)
        with self.stats.timer("metadata"):
            self.provider = _OnDemandProvider(db, self.stats, self.dtype)
            for point in lattice:
                for v in point.vars:
                    self.provider.hist(v, ())

    def family_ct(self, point: LatticePoint, keep: Sequence[CtVar]) -> CtTable:
        key = _freeze(point, keep)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        # positive contractions (data access) are timed inside the provider
        # (-> time_positive); subtract that nested time from the negative
        # phase so the Fig. 3 decomposition doesn't double-count.
        pos_before = self.stats.time_positive
        with self.stats.timer("negative"):
            tab = complete_ct(point, keep, self.provider, self.stats,
                              use_butterfly=self.use_butterfly,
                              mobius_fn=self.mobius_fn)
        self.stats.time_negative -= self.stats.time_positive - pos_before
        self._memo_put(key, tab)
        return tab


class Precount(Strategy):
    def __init__(self, **kw):
        super().__init__(name="PRECOUNT", **kw)

    def prepare(self, db: RelationalDB, lattice: Sequence[LatticePoint]) -> None:
        self.db, self.lattice = db, list(lattice)
        with self.stats.timer("metadata"):
            provider = _CachedPositiveProvider(db, self.stats, self.dtype)
        with self.stats.timer("positive"):
            provider.precompute(lattice)
        self.provider = provider
        # complete (positive+negative) table per lattice point, full attrs
        self.complete: Dict[frozenset, CtTable] = {}
        with self.stats.timer("negative"):
            for point in lattice:
                keep = point.all_ct_vars(db.schema, include_rind=True)
                tab = complete_ct(point, keep, provider, self.stats,
                                  use_butterfly=self.use_butterfly,
                                  mobius_fn=self.mobius_fn)
                self.complete[frozenset(point.rels)] = tab
                self.stats.bump_cache(tab.nbytes)
                self.stats.ct_rows += tab.nnz_rows()

    def family_ct(self, point: LatticePoint, keep: Sequence[CtVar]) -> CtTable:
        return self.complete[frozenset(point.rels)].project(keep)


class Hybrid(Strategy):
    def __init__(self, **kw):
        super().__init__(name="HYBRID", **kw)

    def prepare(self, db: RelationalDB, lattice: Sequence[LatticePoint]) -> None:
        self.db, self.lattice = db, list(lattice)
        with self.stats.timer("metadata"):
            provider = _CachedPositiveProvider(db, self.stats, self.dtype)
        with self.stats.timer("positive"):
            provider.precompute(lattice)
        self.provider = provider

    def family_ct(self, point: LatticePoint, keep: Sequence[CtVar]) -> CtTable:
        key = _freeze(point, keep)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        with self.stats.timer("negative"):
            tab = complete_ct(point, keep, self.provider, self.stats,
                              use_butterfly=self.use_butterfly,
                              mobius_fn=self.mobius_fn)
        self._memo_put(key, tab)
        return tab


class _TupleIdProvider:
    """Positive tables via tuple-ID propagation (Yin et al. 2004 — the
    paper's 'Pre-Count Variants' future-work section, realised in tensors).

    prepare caches, per (atom, direction), the *message matrix*
    ``M[parent_entity, D_child_attrs x D_edge_attrs]`` — the one-hot mass
    each parent node receives through that relationship, at full attribute
    resolution.  A family positive is then a pure contraction of cached
    entity-indexed matrices (projection + Khatri-Rao reduce): the edge
    tables are never touched again.  Cost profile is the paper's: scales
    well in predicates (one matrix per relationship), less well in rows
    (matrices are entity-indexed)."""

    def __init__(self, db: RelationalDB, stats: CostStats, dtype=jnp.float32):
        self.db, self.stats, self.dtype = db, stats, dtype
        self._msgs: Dict[Tuple, Tuple] = {}   # (rel, child_var, parent_var)
        self._hists: Dict[Tuple, CtTable] = {}

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        from .contract import _join_hop, entity_onehot
        seen = set()
        for point in lattice:
            for atom in point.atoms:
                for child, parent in ((atom.src, atom.dst),
                                      (atom.dst, atom.src)):
                    key = (atom.rel, child, parent)
                    if key in seen:
                        continue
                    seen.add(key)
                    all_keep = None  # full resolution
                    child_keep = [
                        v for v in point.all_ct_vars(self.db.schema, False)
                        if (v.kind == "attr" and v.owner[0] == child)
                        or (v.kind == "edge" and v.owner[0] == atom.rel)]
                    cmsg, cvars = entity_onehot(self.db, child, child_keep,
                                                self.dtype)
                    m, mvars = _join_hop(self.db, atom, child, parent,
                                         cmsg, cvars, child_keep,
                                         self.dtype, self.stats)
                    self._msgs[key] = (m, tuple(mvars))
                    self.stats.bump_cache(int(m.nbytes))

    def positive(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> CtTable:
        """Contract the point's positive table over ``keep`` from cached
        message matrices — zero edge-table access."""
        from .contract import _khatri_rao_reduce, entity_onehot
        keep = tuple(keep)
        adj: Dict[Var, List[Tuple]] = {}
        for a in point.atoms:
            adj.setdefault(a.src, []).append((a, a.dst))
            adj.setdefault(a.dst, []).append((a, a.src))
        root = max(point.vars, key=lambda v: len(adj.get(v, ())))

        def msg_for(atom, child, parent):
            m, mvars = self._msgs[(atom.rel, child, parent)]
            # project cached full-resolution columns onto the kept ones
            want = [v for v in mvars if v in keep]
            if tuple(want) != mvars:
                wide = m.reshape((m.shape[0],) + tuple(v.card for v in mvars))
                # sum out unwanted column axes (row axis 0 = entity ids)
                dropped = tuple(i + 1 for i, v in enumerate(mvars)
                                if v not in keep)
                if dropped:
                    wide = jnp.sum(wide, axis=dropped)
                m = wide.reshape(m.shape[0], -1)
                mvars = tuple(want)
            return m, list(mvars)

        def visit(v: Var, parent_atom) -> Tuple[jnp.ndarray, List[CtVar]]:
            msg, mvars = entity_onehot(self.db, v, keep, self.dtype)
            for atom, u in adj.get(v, ()):
                if atom is parent_atom:
                    continue
                if not adj.get(u) or all(a is atom for a, _ in adj.get(u, ())):
                    hop, hop_vars = msg_for(atom, u, v)   # leaf: cached
                else:  # deeper subtree: recurse then propagate (rare, len>2)
                    child_msg, child_vars = visit(u, atom)
                    from .contract import _join_hop
                    hop, hop_vars = _join_hop(self.db, atom, u, v, child_msg,
                                              child_vars, keep, self.dtype,
                                              self.stats)
                n, d1 = msg.shape
                msg = (msg[:, :, None] * hop[:, None, :]).reshape(
                    n, d1 * hop.shape[1])
                mvars = mvars + hop_vars
            return msg, mvars

        factors: List[Tuple[jnp.ndarray, List[CtVar]]] = []
        own, own_vars = entity_onehot(self.db, root, keep, self.dtype)
        factors.append((own, own_vars))
        for atom, u in adj.get(root, ()):
            if not adj.get(u) or all(a is atom for a, _ in adj.get(u, ())):
                hop, hop_vars = msg_for(atom, u, root)
            else:
                child_msg, child_vars = visit(u, atom)
                from .contract import _join_hop
                hop, hop_vars = _join_hop(self.db, atom, u, root, child_msg,
                                          child_vars, keep, self.dtype,
                                          self.stats)
            factors.append((hop, list(hop_vars)))
        flat, mvars = _khatri_rao_reduce(factors)
        counts = flat.reshape(tuple(v.card for v in mvars)) if mvars \
            else flat.reshape(())
        tab = CtTable(tuple(mvars), counts)
        order = tuple(v for v in keep if v in tab.vars)
        if self.stats is not None:
            self.stats.ct_cells += tab.size
        return tab.transpose_to(order) if order != tab.vars else tab

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        key = (var, tuple(keep))
        if key not in self._hists:
            self._hists[key] = entity_hist(self.db, var, keep, self.dtype)
        return self._hists[key]


class TupleId(Strategy):
    """The paper's future-work pre-count variant: tuple-ID propagation."""

    def __init__(self, **kw):
        super().__init__(name="TUPLEID", **kw)

    def prepare(self, db: RelationalDB, lattice: Sequence[LatticePoint]) -> None:
        self.db, self.lattice = db, list(lattice)
        with self.stats.timer("metadata"):
            provider = _TupleIdProvider(db, self.stats, self.dtype)
        with self.stats.timer("positive"):
            provider.precompute(lattice)
        self.provider = provider

    def family_ct(self, point: LatticePoint, keep: Sequence[CtVar]) -> CtTable:
        key = _freeze(point, keep)
        hit = self._memo_get(key)
        if hit is not None:
            return hit
        pos_before = self.stats.time_positive
        with self.stats.timer("negative"):
            tab = complete_ct(point, keep, self.provider, self.stats,
                              use_butterfly=self.use_butterfly,
                              mobius_fn=self.mobius_fn)
        self.stats.time_negative -= self.stats.time_positive - pos_before
        self._memo_put(key, tab)
        return tab


STRATEGIES = {"PRECOUNT": Precount, "ONDEMAND": OnDemand, "HYBRID": Hybrid,
              "TUPLEID": TupleId}


def make_strategy(name: str, **kw) -> Strategy:
    return STRATEGIES[name.upper()](**kw)
