"""Cost instrumentation + one-hot contraction primitives.

Historically this module WAS the counting engine: a hard-coded dense
one-hot tree contraction.  After the planner/executor/cache refactor the
engine lives in three layers —

* :mod:`repro.core.plan`       compiles ``(LatticePoint, keep)`` queries,
* :mod:`repro.core.executors`  evaluates plans (dense one-hot / sparse
  segment-sum backends),
* :mod:`repro.core.cache`      budgeted LRU storage for every ct artefact —

and this module keeps what the whole stack shares: the paper-metric
instrumentation (:class:`CostStats`: Fig. 3 time decomposition, Fig. 4
memory proxy, Table 5 ct sizes), the dense one-hot helpers reused by the
dense executor and the sharded counting path, and thin compatibility
wrappers (:func:`positive_ct`, :func:`entity_hist`) that compile + execute
on the dense backend.

Each dense hop is ``gather → (outer) multiply → segment_sum`` — on TPU the
one-hot multiply/accumulate maps onto the MXU (see
``kernels/hist_kernel.py``).  Complexity: O(edges × D) per hop where D is
the flattened value-space of the subtree — the paper's Eq. (3) growth.
The sparse executor replaces this with O(nnz) scatter-adds; see
:mod:`repro.core.executors`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.hist import LatencyHistogram
from .ct import CtTable
from .database import RelationalDB
from .variables import CtVar, LatticePoint, Var, attr_var


@dataclass
class CostStats:
    """Instrumentation mirroring the paper's reported metrics.

    ``cache_bytes`` is the *live* cache footprint: :class:`~repro.core
    .cache.CtCache` bumps it on insert and **decrements it on eviction or
    drop**, so ``peak_bytes`` (the Fig. 4 memory proxy) is a true
    high-water mark even under a byte budget.

    Beyond the Fig. 3 *totals*, each timed phase also feeds a
    log-bucketed :class:`~repro.obs.hist.LatencyHistogram` in
    ``phase_hists`` — per-interval p50/p95/p99 for metadata/positive/
    negative work, surfaced under ``"phases"`` in :meth:`as_dict`.
    """
    joins: int = 0                # number of edge-table join sweeps
    rows_scanned: int = 0         # edge rows touched by joins
    ct_cells: int = 0             # dense ct cells materialised
    ct_rows: int = 0              # sparse-equivalent rows materialised
    cache_bytes: int = 0          # live cache footprint
    peak_bytes: int = 0           # high-water mark (Fig. 4 proxy)
    time_metadata: float = 0.0    # Fig. 3 decomposition
    time_positive: float = 0.0
    time_negative: float = 0.0
    phase_hists: Dict[str, LatencyHistogram] = field(default_factory=dict)

    def bump_cache(self, delta: int) -> None:
        self.cache_bytes += delta
        self.peak_bytes = max(self.peak_bytes, self.cache_bytes)

    def observe_phase(self, which: str, dt: float) -> None:
        h = self.phase_hists.get(which)
        if h is None:
            h = self.phase_hists[which] = LatencyHistogram()
        h.observe(dt)

    class _Timer:
        def __init__(self, stats: "CostStats", which: str) -> None:
            self.stats, self.which = stats, which

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            dt = time.perf_counter() - self.t0
            setattr(self.stats, f"time_{self.which}",
                    getattr(self.stats, f"time_{self.which}") + dt)
            self.stats.observe_phase(self.which, dt)

    class _DisjointTimer(_Timer):
        """Time a phase EXCLUDING nested work that times itself into
        another bucket — the Fig. 3 decomposition must stay disjoint
        (e.g. a Möbius join timed as ``negative`` whose cache misses
        re-contract positives that time themselves as ``positive``)."""

        def __init__(self, stats: "CostStats", which: str,
                     nested: str) -> None:
            super().__init__(stats, which)
            self.nested = nested

        def __enter__(self):
            self.nested0 = getattr(self.stats, f"time_{self.nested}")
            return super().__enter__()

        def __exit__(self, *exc):
            super().__exit__(*exc)
            grown = getattr(self.stats, f"time_{self.nested}") - self.nested0
            setattr(self.stats, f"time_{self.which}",
                    getattr(self.stats, f"time_{self.which}") - grown)

    def timer(self, which: str) -> "CostStats._Timer":
        return CostStats._Timer(self, which)

    def disjoint_timer(self, which: str,
                       nested: str = "positive") -> "CostStats._Timer":
        """A :meth:`timer` for ``which`` that subtracts whatever nested
        work added to ``time_<nested>`` while it ran."""
        return CostStats._DisjointTimer(self, which, nested)

    def as_dict(self) -> Dict[str, float]:
        return dict(joins=self.joins, rows_scanned=self.rows_scanned,
                    ct_cells=self.ct_cells, ct_rows=self.ct_rows,
                    cache_bytes=self.cache_bytes, peak_bytes=self.peak_bytes,
                    time_metadata=self.time_metadata,
                    time_positive=self.time_positive,
                    time_negative=self.time_negative,
                    time_total=self.time_metadata + self.time_positive
                    + self.time_negative,
                    phases={k: h.as_dict()
                            for k, h in self.phase_hists.items()})


# --------------------------------------------------------------------------
# one-hot helpers (dense backend + sharded counting)
# --------------------------------------------------------------------------

def _onehot(codes: jnp.ndarray, card: int, dtype) -> jnp.ndarray:
    return jax.nn.one_hot(codes, card, dtype=dtype)


def _expand(msg: jnp.ndarray, mvars: List[CtVar],
            hot: jnp.ndarray, hvar: CtVar) -> Tuple[jnp.ndarray, List[CtVar]]:
    """(n, D) x (n, V) -> (n, D*V); track flattened axis order (row-major)."""
    n, d = msg.shape
    out = (msg[:, :, None] * hot[:, None, :]).reshape(n, d * hot.shape[1])
    return out, mvars + [hvar]


def entity_onehot(db: RelationalDB, var: Var, keep: Sequence[CtVar],
                  dtype=jnp.float32) -> Tuple[jnp.ndarray, List[CtVar]]:
    """(n_var, D) one-hot product over the kept attributes of ``var``."""
    tab = db.entities[var.etype]
    msg = jnp.ones((tab.size, 1), dtype=dtype)
    mvars: List[CtVar] = []
    for a in tab.type.attrs:
        cv = attr_var(var, a.name, a.card)
        if cv in keep:
            msg, mvars = _expand(msg, mvars,
                                 _onehot(jnp.asarray(tab.attrs[a.name]),
                                         a.card, dtype), cv)
    return msg, mvars


def entity_hist(db: RelationalDB, var: Var, keep: Sequence[CtVar],
                dtype=jnp.float32) -> CtTable:
    """Histogram over kept attributes of one variable (metadata stage).

    With no kept attributes this degenerates to the population size — the
    Cartesian factor for an unconstrained variable."""
    msg, mvars = entity_onehot(db, var, keep, dtype)
    flat = jnp.sum(msg, axis=0)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars else flat[0]
    return CtTable(tuple(mvars), counts)


def _khatri_rao_reduce(factors: List[Tuple[jnp.ndarray, List[CtVar]]],
                       max_chunk_cells: int = 32_000_000
                       ) -> Tuple[jnp.ndarray, List[CtVar]]:
    """``sum_n  f1[n,:] ⊗ f2[n,:] ⊗ ...`` without materialising the full
    (n, prod D) expansion: the widest factor becomes the right operand of a
    per-chunk matmul (MXU-friendly), the rest are Khatri-Rao'd per chunk.

    Memory is bounded by ``chunk × prod(D_but_widest)`` + the output."""
    factors = [f for f in factors]
    mvars: List[CtVar] = []
    # move the widest factor last; record the resulting axis order
    widest = max(range(len(factors)), key=lambda i: factors[i][0].shape[1])
    order = [i for i in range(len(factors)) if i != widest] + [widest]
    mats = [factors[i][0] for i in order]
    for i in order:
        mvars.extend(factors[i][1])
    n = mats[0].shape[0]
    d_left = int(np.prod([m.shape[1] for m in mats[:-1]], dtype=np.int64))
    d_last = mats[-1].shape[1]
    if len(mats) == 1:
        return jnp.sum(mats[0], axis=0), mvars
    chunk = max(64, min(n, max_chunk_cells // max(d_left, 1)))
    out = jnp.zeros((d_left, d_last), mats[0].dtype)
    for s in range(0, n, chunk):
        kr = mats[0][s:s + chunk]
        for m in mats[1:-1]:
            blk = m[s:s + chunk]
            kr = (kr[:, :, None] * blk[:, None, :]).reshape(kr.shape[0], -1)
        out = out + kr.T @ mats[-1][s:s + chunk]
    return out.reshape(-1), mvars


# --------------------------------------------------------------------------
# compatibility wrapper: compile + execute on the dense backend
# --------------------------------------------------------------------------

def positive_ct(db: RelationalDB, point: LatticePoint,
                keep: Optional[Sequence[CtVar]] = None,
                dtype=jnp.float32,
                stats: Optional[CostStats] = None) -> CtTable:
    """Positive ct-table ``ct_+`` of a lattice point: counts over value
    combinations of ``keep`` among groundings where every relationship of
    the point holds.  ``keep`` may contain entity-attr and edge-attr CtVars
    of the point; defaults to all of them.  Indicator axes are *not*
    present (they are all implicitly T) — the Möbius join adds them.

    Equivalent to compiling a plan and running the dense executor; callers
    that care about the backend should use :class:`~repro.core.engine
    .CountingEngine` directly.
    """
    from .executors import DenseExecutor     # local import: avoids a cycle
    from .plan import compile_plan
    plan = compile_plan(db.schema, point, keep)
    return DenseExecutor(dtype=dtype).positive(db, plan, stats)


def cartesian_size(db: RelationalDB, vars: Sequence[Var]) -> float:
    out = 1.0
    for v in vars:
        out *= float(db.entities[v.etype].size)
    return out
