"""Positive ct-tables via tree tensor contraction (the JOIN problem on MXU).

The SQL ``INNER JOIN + GROUP BY + COUNT(*)`` of FACTORBASE becomes a single
message-passing sweep over the lattice point's variable tree:

* per-variable one-hot attribute encodings,
* per-relationship edge gathers + segment-sums (the join),
* elementwise products at shared variables (the group-by combine).

Each hop is ``gather → (outer) multiply → segment_sum`` — on TPU the one-hot
multiply/accumulate maps onto the MXU (see ``kernels/hist_kernel.py``); here we
express it with ``jax.ops.segment_sum`` so XLA can fuse it on any backend.

Complexity: O(edges × D) per hop where D is the flattened value-space of the
subtree — the paper's Eq. (3) growth, paid once per lattice point in
PRECOUNT/HYBRID and once per family in ONDEMAND.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ct import CtTable
from .database import RelationalDB
from .schema import Schema
from .variables import Atom, CtVar, LatticePoint, Var, attr_var, edge_var


@dataclass
class CostStats:
    """Instrumentation mirroring the paper's reported metrics."""
    joins: int = 0                # number of edge-table join sweeps
    rows_scanned: int = 0         # edge rows touched by joins
    ct_cells: int = 0             # dense ct cells materialised
    ct_rows: int = 0              # sparse-equivalent rows materialised
    cache_bytes: int = 0          # live cache footprint (Fig. 4 proxy)
    peak_bytes: int = 0
    time_metadata: float = 0.0    # Fig. 3 decomposition
    time_positive: float = 0.0
    time_negative: float = 0.0

    def bump_cache(self, delta: int) -> None:
        self.cache_bytes += delta
        self.peak_bytes = max(self.peak_bytes, self.cache_bytes)

    class _Timer:
        def __init__(self, stats: "CostStats", which: str) -> None:
            self.stats, self.which = stats, which

        def __enter__(self):
            self.t0 = time.perf_counter()
            return self

        def __exit__(self, *exc):
            dt = time.perf_counter() - self.t0
            setattr(self.stats, f"time_{self.which}",
                    getattr(self.stats, f"time_{self.which}") + dt)

    def timer(self, which: str) -> "CostStats._Timer":
        return CostStats._Timer(self, which)

    def as_dict(self) -> Dict[str, float]:
        return dict(joins=self.joins, rows_scanned=self.rows_scanned,
                    ct_cells=self.ct_cells, ct_rows=self.ct_rows,
                    cache_bytes=self.cache_bytes, peak_bytes=self.peak_bytes,
                    time_metadata=self.time_metadata,
                    time_positive=self.time_positive,
                    time_negative=self.time_negative,
                    time_total=self.time_metadata + self.time_positive
                    + self.time_negative)


# --------------------------------------------------------------------------
# one-hot helpers
# --------------------------------------------------------------------------

def _onehot(codes: jnp.ndarray, card: int, dtype) -> jnp.ndarray:
    return jax.nn.one_hot(codes, card, dtype=dtype)


def _expand(msg: jnp.ndarray, mvars: List[CtVar],
            hot: jnp.ndarray, hvar: CtVar) -> Tuple[jnp.ndarray, List[CtVar]]:
    """(n, D) x (n, V) -> (n, D*V); track flattened axis order (row-major)."""
    n, d = msg.shape
    out = (msg[:, :, None] * hot[:, None, :]).reshape(n, d * hot.shape[1])
    return out, mvars + [hvar]


def entity_onehot(db: RelationalDB, var: Var, keep: Sequence[CtVar],
                  dtype=jnp.float32) -> Tuple[jnp.ndarray, List[CtVar]]:
    """(n_var, D) one-hot product over the kept attributes of ``var``."""
    tab = db.entities[var.etype]
    msg = jnp.ones((tab.size, 1), dtype=dtype)
    mvars: List[CtVar] = []
    for a in tab.type.attrs:
        cv = attr_var(var, a.name, a.card)
        if cv in keep:
            msg, mvars = _expand(msg, mvars, _onehot(jnp.asarray(tab.attrs[a.name]), a.card, dtype), cv)
    return msg, mvars


def entity_hist(db: RelationalDB, var: Var, keep: Sequence[CtVar],
                dtype=jnp.float32) -> CtTable:
    """Histogram over kept attributes of one variable (metadata stage).

    With no kept attributes this degenerates to the population size — the
    Cartesian factor for an unconstrained variable."""
    msg, mvars = entity_onehot(db, var, keep, dtype)
    flat = jnp.sum(msg, axis=0)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars else flat[0]
    return CtTable(tuple(mvars), counts)


# --------------------------------------------------------------------------
# tree contraction
# --------------------------------------------------------------------------

def positive_ct(db: RelationalDB, point: LatticePoint,
                keep: Optional[Sequence[CtVar]] = None,
                dtype=jnp.float32,
                stats: Optional[CostStats] = None) -> CtTable:
    """Positive ct-table ``ct_+`` of a lattice point: counts over value
    combinations of ``keep`` among groundings where every relationship of the
    point holds.  ``keep`` may contain entity-attr and edge-attr CtVars of the
    point; defaults to all of them.  Indicator axes are *not* present (they
    are all implicitly T) — the Möbius join adds them.
    """
    schema = db.schema
    if keep is None:
        keep = [v for v in point.all_ct_vars(schema, include_rind=False)]
    keep = list(keep)

    if not point.atoms:
        raise ValueError("positive_ct needs at least one atom")

    # var tree: adjacency var -> [(atom, other_var)]
    adj: Dict[Var, List[Tuple[Atom, Var]]] = {}
    for a in point.atoms:
        adj.setdefault(a.src, []).append((a, a.dst))
        adj.setdefault(a.dst, []).append((a, a.src))
    # root at the tree centre (max degree): interior per-row messages stay
    # one-hop wide, and the root-level product is deferred to the chunked
    # Khatri-Rao contraction below instead of a full (n, prod D) expansion.
    root = max(point.vars, key=lambda v: len(adj.get(v, ())))

    def visit(v: Var, parent_atom: Optional[Atom]) -> Tuple[jnp.ndarray, List[CtVar]]:
        msg, mvars = entity_onehot(db, v, keep, dtype)
        for atom, u in adj.get(v, ()):  # children
            if atom is parent_atom:
                continue
            child_msg, child_vars = visit(u, atom)
            hop, hop_vars = _join_hop(db, atom, child=u, parent=v,
                                      child_msg=child_msg, child_vars=child_vars,
                                      keep=keep, dtype=dtype, stats=stats)
            n, d1 = msg.shape
            msg = (msg[:, :, None] * hop[:, None, :]).reshape(n, d1 * hop.shape[1])
            mvars = mvars + hop_vars
        return msg, mvars

    # collect the root's factors WITHOUT expanding them against each other
    factors: List[Tuple[jnp.ndarray, List[CtVar]]] = []
    own_msg, own_vars = entity_onehot(db, root, keep, dtype)
    factors.append((own_msg, own_vars))
    for atom, u in adj.get(root, ()):
        child_msg, child_vars = visit(u, atom)
        hop, hop_vars = _join_hop(db, atom, child=u, parent=root,
                                  child_msg=child_msg, child_vars=child_vars,
                                  keep=keep, dtype=dtype, stats=stats)
        factors.append((hop, hop_vars))

    flat, mvars = _khatri_rao_reduce(factors)
    counts = flat.reshape(tuple(v.card for v in mvars)) if mvars else flat.reshape(())
    tab = CtTable(tuple(mvars), counts)
    # canonical order: as in `keep`
    order = tuple(v for v in keep if v in tab.vars)
    tab = tab.transpose_to(order) if order != tab.vars else tab
    if stats is not None:
        stats.ct_cells += tab.size
    return tab


def _khatri_rao_reduce(factors: List[Tuple[jnp.ndarray, List[CtVar]]],
                       max_chunk_cells: int = 32_000_000
                       ) -> Tuple[jnp.ndarray, List[CtVar]]:
    """``sum_n  f1[n,:] ⊗ f2[n,:] ⊗ ...`` without materialising the full
    (n, prod D) expansion: the widest factor becomes the right operand of a
    per-chunk matmul (MXU-friendly), the rest are Khatri-Rao'd per chunk.

    Memory is bounded by ``chunk × prod(D_but_widest)`` + the output."""
    factors = [f for f in factors]
    mvars: List[CtVar] = []
    # move the widest factor last; record the resulting axis order
    widest = max(range(len(factors)), key=lambda i: factors[i][0].shape[1])
    order = [i for i in range(len(factors)) if i != widest] + [widest]
    mats = [factors[i][0] for i in order]
    for i in order:
        mvars.extend(factors[i][1])
    n = mats[0].shape[0]
    d_left = int(np.prod([m.shape[1] for m in mats[:-1]], dtype=np.int64))
    d_last = mats[-1].shape[1]
    if len(mats) == 1:
        return jnp.sum(mats[0], axis=0), mvars
    chunk = max(64, min(n, max_chunk_cells // max(d_left, 1)))
    out = jnp.zeros((d_left, d_last), mats[0].dtype)
    for s in range(0, n, chunk):
        kr = mats[0][s:s + chunk]
        for m in mats[1:-1]:
            blk = m[s:s + chunk]
            kr = (kr[:, :, None] * blk[:, None, :]).reshape(kr.shape[0], -1)
        out = out + kr.T @ mats[-1][s:s + chunk]
    return out.reshape(-1), mvars


def _join_hop(db: RelationalDB, atom: Atom, child: Var, parent: Var,
              child_msg: jnp.ndarray, child_vars: List[CtVar],
              keep: Sequence[CtVar], dtype, stats: Optional[CostStats]
              ) -> Tuple[jnp.ndarray, List[CtVar]]:
    """Push a child-subtree message through one relationship: the join.

    (n_child, D) -> (n_parent, D * E) where E covers kept edge attributes.
    Edge-attr axes are sized ``card + 1`` (N/A slot last, empty here) so they
    line up with complete tables without re-indexing.
    """
    rt = db.relations[atom.rel]
    if child == atom.src and parent == atom.dst:
        gather_idx, scatter_idx = jnp.asarray(rt.src), jnp.asarray(rt.dst)
        n_parent = db.entities[atom.dst.etype].size
    elif child == atom.dst and parent == atom.src:
        gather_idx, scatter_idx = jnp.asarray(rt.dst), jnp.asarray(rt.src)
        n_parent = db.entities[atom.src.etype].size
    else:
        raise AssertionError("atom does not connect child/parent")

    m = child_msg[gather_idx]                     # (edges, D)
    mvars = list(child_vars)
    for a in rt.type.attrs:
        cv = edge_var(rt.type.name, a.name, a.card)
        if cv in keep:
            hot = _onehot(jnp.asarray(rt.attrs[a.name]), cv.card, dtype)  # card+1, NA empty
            m, mvars = _expand(m, mvars, hot, cv)
    out = jax.ops.segment_sum(m, scatter_idx, num_segments=n_parent)
    if stats is not None:
        stats.joins += 1
        stats.rows_scanned += int(gather_idx.shape[0])
    return out, mvars


def cartesian_size(db: RelationalDB, vars: Sequence[Var]) -> float:
    out = 1.0
    for v in vars:
        out *= float(db.entities[v.etype].size)
    return out
