"""Contingency tables (ct-tables).

The paper stores ct-tables as sparse SQL rows; on TPU we store them as dense
count tensors over the attribute value space, one axis per :class:`CtVar`.
Dense tensors keep projection (the PRECOUNT/HYBRID family-extraction
primitive) a pure ``sum`` over axes — a VPU-friendly reduction — and keep the
Möbius transform a strided butterfly.  (Sparsity is exploited upstream:
the sparse *executor* contracts raw edge lists in O(nnz) and only the
final table is dense — see :mod:`repro.core.executors`.)  Tables are the
unit of account in the byte-budgeted :class:`~repro.core.cache.CtCache`.

``nnz_rows`` reports the sparse-equivalent row count so benchmarks can be
compared against the paper's Table 5 numbers.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .variables import CtVar


@dataclass
class CtTable:
    vars: Tuple[CtVar, ...]
    counts: jnp.ndarray               # shape == tuple(v.card for v in vars)

    def __post_init__(self) -> None:
        expect = tuple(v.card for v in self.vars)
        if tuple(self.counts.shape) != expect:
            raise ValueError(f"ct shape {self.counts.shape} != vars {expect}")

    # -- bookkeeping --------------------------------------------------------
    @property
    def size(self) -> int:
        """Dense cell count (memory proxy)."""
        return int(np.prod([v.card for v in self.vars], dtype=np.int64)) if self.vars else 1

    @property
    def nbytes(self) -> int:
        return int(self.counts.nbytes)

    def nnz_rows(self) -> int:
        """Sparse-equivalent number of ct-table rows (paper Table 5)."""
        return int(jnp.count_nonzero(self.counts))

    def total(self) -> float:
        return float(jnp.sum(self.counts))

    # -- algebra ------------------------------------------------------------
    def axis_of(self, var: CtVar) -> int:
        return self.vars.index(var)

    def project(self, keep: Sequence[CtVar]) -> "CtTable":
        """Marginalise onto ``keep`` (paper: *projection*), preserving the
        order given in ``keep``."""
        keep = tuple(keep)
        missing = [v for v in keep if v not in self.vars]
        if missing:
            raise KeyError(f"project: vars not in table: {missing}")
        drop = tuple(i for i, v in enumerate(self.vars) if v not in keep)
        counts = jnp.sum(self.counts, axis=drop) if drop else self.counts
        cur = tuple(v for v in self.vars if v in keep)
        # permute to requested order
        perm = tuple(cur.index(v) for v in keep)
        counts = jnp.transpose(counts, perm) if perm != tuple(range(len(perm))) else counts
        return CtTable(keep, counts)

    def transpose_to(self, order: Sequence[CtVar]) -> "CtTable":
        order = tuple(order)
        if set(order) != set(self.vars):
            raise ValueError("transpose_to needs the same var set")
        perm = tuple(self.vars.index(v) for v in order)
        return CtTable(order, jnp.transpose(self.counts, perm))

    def outer(self, other: "CtTable") -> "CtTable":
        """Tensor (Cartesian) product — used to extend a component ct over
        unconstrained variables."""
        a = self.counts.reshape(self.counts.shape + (1,) * other.counts.ndim)
        return CtTable(self.vars + other.vars, a * other.counts)

    def scale(self, c) -> "CtTable":
        return CtTable(self.vars, self.counts * c)

    def __sub__(self, other: "CtTable") -> "CtTable":
        other = other.transpose_to(self.vars)
        return CtTable(self.vars, self.counts - other.counts)

    def __add__(self, other: "CtTable") -> "CtTable":
        other = other.transpose_to(self.vars)
        return CtTable(self.vars, self.counts + other.counts)


def scalar_table(value: float, dtype=jnp.float32) -> CtTable:
    return CtTable((), jnp.asarray(value, dtype=dtype))
