"""Counting engine: planner + executor + cache, shared by every strategy.

This is the machinery layer of the planner/executor/cache architecture.
A :class:`CountingEngine` owns

* the database handle,
* one :class:`~repro.core.executors.Executor` (``"dense"``, ``"sparse"``,
  or ``"sparse_sharded"`` — the mesh-parallel sparse backend from
  :mod:`repro.core.distributed`; in a horizontally partitioned deployment
  each shard of a :class:`~repro.core.database.ShardedDatabase` gets its
  own engine, see :mod:`repro.serve.router`),
* one :class:`~repro.core.cache.CtCache` (byte-budgeted LRU, shared by all
  namespaces: positives, messages, family tables, histograms),
* the shared :class:`~repro.core.contract.CostStats` instrumentation.

On top sit three *positive-table policies* — they all satisfy the
:class:`~repro.core.mobius.PositiveProvider` protocol consumed by the
Möbius join, and differ only in WHEN joins run and WHAT is cached:

* :class:`OnDemandPositives` — contract from raw data per request, memoise
  the result (the paper's post-counting data access pattern);
* :class:`CachedFullPositives` — contract each lattice point once at full
  attribute resolution up front; serve requests by projection
  (PRECOUNT / HYBRID pre-counting);
* :class:`TupleIdPositives` — cache per-(relationship, direction) message
  matrices up front (tuple-ID propagation, Yin et al. 2004); serve
  requests by projecting + recombining cached messages with zero edge
  table access.

Eviction is always safe: every policy recomputes on miss.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp

from .cache import CtCache
from .contract import CostStats
from .ct import CtTable
from .database import RelationalDB
from .executors import Executor, make_executor, project_columns
from .plan import ContractionPlan, compile_plan_cached
from .variables import Atom, CtVar, LatticePoint, Var, attr_var, edge_var


class CountingEngine:
    """Shared planner/executor/cache machinery."""

    def __init__(self, db: RelationalDB, executor="dense",
                 stats: Optional[CostStats] = None,
                 cache: Optional[CtCache] = None,
                 cache_budget_bytes: Optional[int] = None,
                 dtype=jnp.float32):
        self.db = db
        self.stats = stats if stats is not None else CostStats()
        self.executor: Executor = (executor if isinstance(executor, Executor)
                                   else make_executor(executor, dtype=dtype))
        self.cache = cache if cache is not None else CtCache(
            cache_budget_bytes, self.stats)
        self.dtype = dtype
        # one rows-counted set per engine: policies AND the counting
        # service share artefact key namespaces ("pos"/"full"/...), so
        # Table 5's "once per distinct artefact" accounting must be shared
        # too, or a service-computed table recomputed by a policy after
        # eviction would be counted twice
        self.rows_counted: Set[Tuple] = set()

    def count_rows_once(self, key: Tuple, tab: CtTable) -> None:
        if key not in self.rows_counted:
            self.rows_counted.add(key)
            self.stats.ct_rows += tab.nnz_rows()

    def plan(self, point: LatticePoint,
             keep: Optional[Sequence[CtVar]] = None) -> ContractionPlan:
        if keep is None:
            keep = point.all_ct_vars(self.db.schema, include_rind=False)
        return compile_plan_cached(self.db.schema, point, tuple(keep))

    def contract(self, point: LatticePoint,
                 keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Positive ct-table straight from the data (counts as JOIN work)."""
        return self.executor.positive(self.db, self.plan(point, keep),
                                      self.stats)

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        key = ("hist", self.executor.name, var, tuple(keep))
        hit = self.cache.get(key)
        if hit is None:
            hit = self.cache.put(key, self.executor.hist(
                self.db, var, tuple(keep), self.stats))
        return hit

    def mobius_fn(self):
        """The executor's negative-phase step, ``(stack, k) -> stack``."""
        return self.executor.mobius

    def mobius_batch_fn(self):
        """The executor's BATCHED negative-phase step,
        ``(stacks, k) -> [stack]`` — one jitted transform over many
        same-shape butterfly stacks (see :meth:`~repro.core.executors
        .Executor.mobius_batch`).  This is what lets a serving layer or a
        search round pay one negative-phase dispatch per stack *shape*
        rather than one per family."""
        return self.executor.mobius_batch


class _Policy:
    """Base: delegate histograms; subclasses implement ``positive``.

    All data-access work (contractions, message propagation) is timed here
    under ``time_positive`` — including eviction-driven *recomputes* — so
    the Fig. 3 decomposition stays truthful under a cache budget.
    ``ct_rows`` (Table 5) is bumped once per distinct artefact, not per
    recompute."""

    def __init__(self, engine: CountingEngine):
        self.engine = engine

    def _count_rows_once(self, key: Tuple, tab: CtTable) -> None:
        self.engine.count_rows_once(key, tab)

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        return self.engine.hist(var, keep)

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        pass

    # -- serve-layer integration --------------------------------------------
    supports_batch_prefetch = False    # callers skip query enumeration when
                                       # a policy can never batch (TUPLEID)

    def batchable_misses(self, queries: Sequence[Tuple[LatticePoint,
                                                       Tuple[CtVar, ...]]]
                         ) -> List[Tuple[LatticePoint,
                                         Optional[Tuple[CtVar, ...]]]]:
        """Of the positive queries a Möbius join is about to issue, the
        deduplicated subset this policy would contract *from data* on miss
        — i.e. what a batching service should execute as one
        signature-bucketed dispatch.  Policies whose misses are not plan
        contractions (tuple-ID message recombination) return []."""
        return []

    def absorb(self, point: LatticePoint,
               keep: Optional[Tuple[CtVar, ...]], tab: CtTable) -> None:
        """Accept a service-computed positive table for a query previously
        reported by :meth:`batchable_misses` (same caching + row
        accounting as the policy's own miss path)."""
        raise NotImplementedError


class OnDemandPositives(_Policy):
    """Contract positives from the database per request (counts JOINs);
    memoised in the shared cache (the paper's post-count cache)."""

    supports_batch_prefetch = True

    def _key(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> Tuple:
        return ("pos", self.engine.executor.name, point.atoms, tuple(keep))

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        eng = self.engine
        key = self._key(point, keep)
        hit = eng.cache.get(key)
        if hit is None:
            with eng.stats.timer("positive"):   # the per-family JOIN cost
                hit = eng.contract(point, keep)
            self._count_rows_once(key, hit)
            eng.cache.put(key, hit)
        return hit

    def batchable_misses(self, queries):
        out, seen = [], set()
        for point, keep in queries:
            key = self._key(point, keep)
            if key not in self.engine.cache and key not in seen:
                seen.add(key)
                out.append((point, tuple(keep)))
        return out

    def absorb(self, point, keep, tab):
        key = self._key(point, keep)
        self._count_rows_once(key, tab)
        self.engine.cache.put(key, tab)


class CachedFullPositives(_Policy):
    """Serve positives by *projection* from full-attribute positive tables
    contracted once per lattice point — zero data access afterwards
    (HYBRID / PRECOUNT).  Evicted entries are re-contracted on miss."""

    supports_batch_prefetch = True

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        for point in lattice:
            self._full(point)

    def _full_key(self, point: LatticePoint) -> Tuple:
        return ("full", self.engine.executor.name, frozenset(point.rels))

    def _full(self, point: LatticePoint) -> CtTable:
        eng = self.engine
        key = self._full_key(point)
        hit = eng.cache.get(key)
        if hit is None:
            with eng.stats.timer("positive"):
                hit = eng.contract(point, None)
            self._count_rows_once(key, hit)
            eng.cache.put(key, hit)
        return hit

    def batchable_misses(self, queries):
        # misses here are evicted full-resolution tables: one (point, None)
        # re-contraction per distinct sub-point no longer resident
        out, seen = [], set()
        for point, _ in queries:
            key = self._full_key(point)
            if key not in self.engine.cache and key not in seen:
                seen.add(key)
                out.append((point, None))
        return out

    def absorb(self, point, keep, tab):
        key = self._full_key(point)
        self._count_rows_once(key, tab)
        self.engine.cache.put(key, tab)

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        # NOTE §Perf H3 it.3: memoising these projections by (atoms, keep)
        # was tried and REFUTED — CtVar-tuple hashing overhead exceeded the
        # projection cost at every dataset size measured.
        return self._full(point).project(keep)


class TupleIdPositives(_Policy):
    """Positive tables via tuple-ID propagation (the paper's 'Pre-Count
    Variants' future-work section, realised in tensors).

    ``precompute`` caches, per (relationship, direction), the full-resolution
    message matrix ``M[parent_entity, D_child_attrs x D_edge_attrs]`` — the
    mass each parent node receives through that relationship.  A family
    positive is then a pure contraction of cached entity-indexed matrices
    (column projection + root reduce): edge tables are never touched again.
    Cost profile per the paper: scales well in predicates (one matrix per
    relationship), less well in rows (matrices are entity-indexed)."""

    def _full_resolution(self, atom: Atom, child: Var
                         ) -> Tuple[Tuple[CtVar, ...], Tuple[CtVar, ...]]:
        schema = self.engine.db.schema
        cattrs = tuple(attr_var(child, a.name, a.card)
                       for a in schema.entity(child.etype).attrs)
        rel = schema.relationship(atom.rel)
        eattrs = tuple(edge_var(rel.name, a.name, a.card) for a in rel.attrs)
        return cattrs, eattrs

    def _msg(self, atom: Atom, child: Var, parent: Var):
        eng = self.engine
        key = ("msg", eng.executor.name, atom.rel, child, parent)
        hit = eng.cache.get(key)
        if hit is None:
            cattrs, eattrs = self._full_resolution(atom, child)
            with eng.stats.timer("positive"):
                m, mvars = eng.executor.leaf_hop(eng.db, atom, child, parent,
                                                 cattrs, eattrs, eng.stats)
            hit = eng.cache.put(key, (m, tuple(mvars)), nbytes=int(m.nbytes))
        return hit

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        seen: Set[Tuple] = set()
        for point in lattice:
            for atom in point.atoms:
                for child, parent in ((atom.src, atom.dst),
                                      (atom.dst, atom.src)):
                    if (atom.rel, child, parent) not in seen:
                        seen.add((atom.rel, child, parent))
                        self._msg(atom, child, parent)

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        eng = self.engine
        keep = tuple(keep)
        plan = eng.plan(point, keep)
        factors: List[Tuple[jnp.ndarray, Tuple[CtVar, ...]]] = []
        for hop in plan.root.hops:
            if hop.is_leaf_hop:
                m, mvars = self._msg(hop.atom, hop.child, hop.parent)
                factors.append(project_columns(m, mvars, keep))
            else:   # deeper subtree (chains of length > 2): propagate live
                factors.append(eng.executor.hop_message(eng.db, hop,
                                                        eng.stats))
        return eng.executor.root_reduce(eng.db, plan.root.own, factors,
                                        keep, eng.stats)


POSITIVE_POLICIES = {
    "ondemand": OnDemandPositives,
    "cached_full": CachedFullPositives,
    "tupleid": TupleIdPositives,
}
