"""Counting engine: planner + executor + cache, shared by every strategy.

This is the machinery layer of the planner/executor/cache architecture.
A :class:`CountingEngine` owns

* the database handle,
* one :class:`~repro.core.executors.Executor` (``"dense"``, ``"sparse"``,
  or ``"sparse_sharded"`` — the mesh-parallel sparse backend from
  :mod:`repro.core.distributed`; in a horizontally partitioned deployment
  each shard of a :class:`~repro.core.database.ShardedDatabase` gets its
  own engine, see :mod:`repro.serve.router`),
* one :class:`~repro.core.cache.CtCache` (byte-budgeted LRU, shared by all
  namespaces: positives, messages, family tables, histograms),
* the shared :class:`~repro.core.contract.CostStats` instrumentation.

On top sit three *positive-table policies* — they all satisfy the
:class:`~repro.core.mobius.PositiveProvider` protocol consumed by the
Möbius join, and differ only in WHEN joins run and WHAT is cached:

* :class:`OnDemandPositives` — contract from raw data per request, memoise
  the result (the paper's post-counting data access pattern);
* :class:`CachedFullPositives` — contract each lattice point once at full
  attribute resolution up front; serve requests by projection
  (PRECOUNT / HYBRID pre-counting);
* :class:`TupleIdPositives` — cache per-(relationship, direction) message
  matrices up front (tuple-ID propagation, Yin et al. 2004); serve
  requests by projecting + recombining cached messages with zero edge
  table access.

Eviction is always safe: every policy recomputes on miss.

**Mutations.**  The engine is version-aware: cache entries are stamped
with the ``(db.version, dependency-tag set)`` they were computed under
(:func:`key_deps` derives the tags — relation names plus
``("attr", etype, name)`` attribute tuples — from the key itself, so no
call site changes), and :meth:`CountingEngine.apply_delta` reconciles the
cache after a :class:`~repro.core.database.FactDelta` or
:class:`~repro.core.database.AttrDelta` is applied to the store.
Reconciliation re-derives the paper's pre/post trade-off *over time*:
positive artefacts (``"pos"``/``"full"`` tables, ``"msg"`` matrices) are
multilinear in each relationship's edge multiset, so a small fact delta
**updates them in place** by counting just the delta edges — batched,
surviving same-executor entries run through ONE
:meth:`~repro.core.executors.Executor.positive_batch` dispatch over the
delta view.  Derived ``"fam"``/``"complete"`` tables are ALSO updated in
place: the Möbius transform is linear, so the positive block deltas push
through the butterfly (:func:`~repro.core.mobius.complete_ct_delta_many`,
one fused dispatch per ``(shape, perm)`` group) and add onto the resident
tables exactly.  Above the cost threshold the entry is dropped instead and
recomputed on next miss (post-counting the write).  Entries whose
dependency tags miss the delta — including every ``"hist"`` on a fact
delta — are retained untouched.  Attribute deltas invalidate exactly the
entries whose tags intersect the written ``(etype, attr)`` columns
(positive counts are *not* linear in attribute values, so there is no
in-place path) and retain everything else.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import (Dict, FrozenSet, Hashable, List, Optional, Sequence, Set,
                    Tuple)

import jax.numpy as jnp

from ..obs.trace import NULL_TRACER
from .cache import CtCache
from .contract import CostStats
from .ct import CtTable
from .database import AttrDelta, FactDelta, RelationalDB
from .executors import Executor, make_executor, project_columns
from .mobius import complete_ct_delta_many
from .plan import ContractionPlan, compile_plan_cached
from .variables import Atom, CtVar, LatticePoint, Var, attr_var, edge_var


def _attr_tags(keep) -> Set[Tuple]:
    """``("attr", etype, name)`` tags for the entity-attr axes of a keep
    tuple (edge-attr and rind axes are covered by the relation name)."""
    return {("attr", v.owner[0].etype, v.owner[1])
            for v in keep if v.kind == "attr"}


def key_deps(key: Tuple) -> Optional[FrozenSet[Hashable]]:
    """The dependency tags a cache entry was derived from, read off the
    key itself (every namespace embeds its pattern).  Tags mix relationship
    names (edge-table dependencies) with ``("attr", etype, name)`` tuples
    (entity-attribute-column dependencies) and the ``("attr*", etype)``
    wildcard for entries that read every attribute of a type:

    * ``("pos", executor, atoms, keep)`` — the atoms' relations + the kept
      entity-attr columns;
    * ``("full", executor, atoms)`` — the atoms' relations + the
      ``("attr*", etype)`` wildcard per pattern variable (full attribute
      resolution reads every column of each variable's type);
    * ``("fam", atoms, keep)`` / ``("complete", atoms, keep)`` — the
      atoms' relations + the kept entity-attr columns;
    * ``("msg", executor, atom, child, parent)`` — the atom's relation +
      ``("attr*", child_etype)`` (messages carry the child's full
      attribute resolution);
    * ``("hist", executor, var, keep)`` — the kept entity-attr columns
      (no relation tags: histograms are immune to fact deltas; entity
      table sizes are immutable);
    * anything else — ``None`` (unknown; invalidation drops it
      conservatively).
    """
    try:
        ns = key[0]
        if ns == "pos":
            return frozenset({a.rel for a in key[2]} | _attr_tags(key[3]))
        if ns == "full":
            etypes = {v.etype for a in key[2] for v in (a.src, a.dst)}
            return frozenset({a.rel for a in key[2]}
                             | {("attr*", et) for et in etypes})
        if ns in ("fam", "complete"):
            return frozenset({a.rel for a in key[1]} | _attr_tags(key[2]))
        if ns == "msg":
            return frozenset({key[2].rel, ("attr*", key[3].etype)})
        if ns == "hist":
            return frozenset(_attr_tags(key[3]))
    except (TypeError, AttributeError, IndexError):
        pass
    return None


@dataclass
class DeltaReport:
    """What one :meth:`CountingEngine.apply_delta` reconciliation did to
    the cache: entries refreshed in place (``updated``), dropped
    (``invalidated``) and left untouched (``retained``)."""

    rel: str
    op: str
    num_edges: int
    updated: int = 0
    invalidated: int = 0
    retained: int = 0
    version: int = 0

    def as_dict(self) -> dict:
        return dict(rel=self.rel, op=self.op, num_edges=self.num_edges,
                    updated=self.updated, invalidated=self.invalidated,
                    retained=self.retained, version=self.version)


class CountingEngine:
    """Shared planner/executor/cache machinery."""

    def __init__(self, db: RelationalDB, executor="dense",
                 stats: Optional[CostStats] = None,
                 cache: Optional[CtCache] = None,
                 cache_budget_bytes: Optional[int] = None,
                 dtype=jnp.float32):
        self.db = db
        self.stats = stats if stats is not None else CostStats()
        self.executor: Executor = (executor if isinstance(executor, Executor)
                                   else make_executor(executor, dtype=dtype))
        self.cache = cache if cache is not None else CtCache(
            cache_budget_bytes, self.stats)
        # freshness stamps: every entry records the relations it depends on
        # (derived from the key, so no call-site changes) and the store
        # version it was computed under
        self.cache.deps_fn = key_deps
        self.cache.version_fn = lambda: self.db.version
        # request tracer (NULL_TRACER is free); CountingService.set_tracer
        # wires a real one through engine + executor + cache together
        self.tracer = NULL_TRACER
        self.dtype = dtype
        # one rows-counted set per engine: policies AND the counting
        # service share artefact key namespaces ("pos"/"full"/...), so
        # Table 5's "once per distinct artefact" accounting must be shared
        # too, or a service-computed table recomputed by a policy after
        # eviction would be counted twice
        self.rows_counted: Set[Tuple] = set()
        # default-keep memo: all_ct_vars walks the schema per call and a
        # serve flood resolves keep=None for the same points every round
        self._keep_cache: Dict[Tuple, Tuple[CtVar, ...]] = {}

    def count_rows_once(self, key: Tuple, tab: CtTable) -> None:
        if key not in self.rows_counted:
            self.rows_counted.add(key)
            self.stats.ct_rows += tab.nnz_rows()

    def plan(self, point: LatticePoint,
             keep: Optional[Sequence[CtVar]] = None) -> ContractionPlan:
        if keep is None:
            keep = self._keep_cache.get(point.atoms)
            if keep is None:
                keep = tuple(point.all_ct_vars(self.db.schema,
                                               include_rind=False))
                self._keep_cache[point.atoms] = keep
        return compile_plan_cached(self.db.schema, point, tuple(keep))

    def contract(self, point: LatticePoint,
                 keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Positive ct-table straight from the data (counts as JOIN work)."""
        return self.executor.positive(self.db, self.plan(point, keep),
                                      self.stats)

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        key = ("hist", self.executor.name, var, tuple(keep))
        hit = self.cache.get(key)
        if hit is None:
            hit = self.cache.put(key, self.executor.hist(
                self.db, var, tuple(keep), self.stats))
        return hit

    def mobius_fn(self):
        """The executor's negative-phase step, ``(stack, k) -> stack``."""
        return self.executor.mobius

    def mobius_batch_fn(self):
        """The executor's BATCHED negative-phase step,
        ``(stacks, k) -> [stack]`` — one jitted transform over many
        same-shape butterfly stacks (see :meth:`~repro.core.executors
        .Executor.mobius_batch`).  This is what lets a serving layer or a
        search round pay one negative-phase dispatch per stack *shape*
        rather than one per family."""
        return self.executor.mobius_batch

    def mobius_fused_fn(self):
        """The executor's FUSED batched negative phase,
        ``(block_lists, k, perm) -> [table array]`` — butterfly-stack
        assembly, transform and final transpose in one jitted dispatch
        per ``(shape, perm)`` group (see :meth:`~repro.core.executors
        .Executor.mobius_batch_fused`)."""
        return self.executor.mobius_batch_fused

    # -- delta count maintenance --------------------------------------------
    def apply_delta(self, delta,
                    max_update_fraction: float = 0.25) -> DeltaReport:
        """Reconcile the cache after ``delta`` was applied to ``self.db``.

        Accepts a :class:`~repro.core.database.FactDelta` (relationship
        writes) or an :class:`~repro.core.database.AttrDelta`
        (entity-attribute writes).  For a fact delta, walks the resident
        entries once and, per entry:

        * dependency tags miss ``delta.rel`` → **retained** untouched
          (this is the fine-grained invalidation: a write to one relation
          leaves every other relation's artefacts hot);
        * positive artefact (``"pos"``/``"full"`` table, ``"msg"``
          matrix) and the delta is *small* (``delta.num_edges <=
          max_update_fraction *`` the relation's post-delta edge count) →
          **updated in place**: the entry's own contraction plan runs over
          a delta view of the database (just the changed edges) and the
          result is added/subtracted — exact, because positive counts are
          multilinear in each relationship's edge multiset and lattice
          patterns use distinct relations.  All surviving ``"pos"`` /
          ``"full"`` entries go through ONE
          :meth:`~repro.core.executors.Executor.positive_batch` dispatch
          (grouped by plan signature internally) instead of one dispatch
          per entry;
        * derived ``"fam"``/``"complete"`` table and the delta is small →
          **updated in place through the butterfly**: the Möbius transform
          is linear, so the block deltas (delta-view contractions) push
          through :func:`~repro.core.mobius.complete_ct_delta_many` — one
          fused negative-phase dispatch per ``(shape, perm)`` group — and
          add onto the resident tables, bit-exact vs recompute.  Entries
          whose kept indicators sum ``delta.rel`` out are provably
          unaffected and retained;
        * otherwise → **invalidated** (dropped; recomputed on next miss —
          the post-count fallback of the pre/post trade-off, applied to
          writes).

        An attribute delta has no in-place path (counts are not linear in
        attribute *values*): entries whose tags intersect the written
        ``(etype, attr)`` columns are invalidated, everything else —
        including every artefact over other types' attributes and all
        purely relational entries — is retained.

        Deltas must be reconciled in application order, one per call:
        ``delta.new_version`` must equal the store's current version
        (otherwise a second delta to an overlapping pattern would double
        the cross terms).

        Args:
            delta: the applied :class:`~repro.core.database.FactDelta` or
                :class:`~repro.core.database.AttrDelta`.
            max_update_fraction: in-place-update cost threshold, as a
                fraction of the relation's current edge count.

        Returns:
            A :class:`DeltaReport` with updated/invalidated/retained
            counts.

        Raises:
            ValueError: ``delta`` is not the store's latest version
                (reconcile each delta immediately after applying it, or
                fall back to ``cache.invalidate({delta.rel})``).

        Usage::

            delta = db.insert_facts("Rated", src, dst, {"rating": vals})
            report = engine.apply_delta(delta)
        """
        if delta.new_version != self.db.version:
            raise ValueError(
                f"delta version {delta.new_version} != store version "
                f"{self.db.version}; reconcile deltas in application order")
        if isinstance(delta, AttrDelta):
            return self._apply_attr_delta(delta)
        rel = delta.rel
        report = DeltaReport(rel, delta.op, delta.num_edges,
                             version=self.db.version)
        rel_edges = self.db.relations[rel].num_edges
        small = delta.num_edges <= max_update_fraction * max(rel_edges, 1)
        delta_db = delta.as_db(self.db) if small else None
        cache = self.cache
        ex = self.executor
        with self.tracer.span("engine.apply_delta", rel=rel, op=delta.op,
                              num_edges=delta.num_edges,
                              small=small) as sp:
            # one classification walk over a stable snapshot, then one
            # batched dispatch per artefact family
            pos_items: List[Tuple[Tuple, CtTable, ContractionPlan]] = []
            msg_keys: List[Tuple] = []
            fam_items: List[Tuple[Tuple, LatticePoint,
                                  Tuple[CtVar, ...]]] = []
            for key in cache.keys_snapshot():
                meta = cache.entry_meta(key)
                if meta is None:           # concurrently evicted
                    continue
                deps, _version = meta
                if deps is not None and rel not in deps:
                    report.retained += 1
                    continue
                bucket = self._classify_for_delta(key) if small else None
                if bucket is None:
                    if cache.discard(key):
                        report.invalidated += 1
                    continue
                kind, payload = bucket
                if kind == "pos":
                    pos_items.append((key,) + payload)
                elif kind == "msg":
                    msg_keys.append(key)
                else:
                    fam_items.append((key,) + payload)

            # (b) surviving positive tables: ONE batched dispatch over the
            # delta view, grouped by plan signature inside positive_batch
            if pos_items:
                with self.stats.timer("positive"), ex.local_mode():
                    dtabs = ex.positive_batch(
                        delta_db, [p for _, _, p in pos_items], self.stats)
                for (key, old, _), dtab in zip(pos_items, dtabs):
                    new = old + dtab.scale(delta.sign)
                    cache.put(key, new, nbytes=new.nbytes)
                    cache.count_delta_updates()
                    report.updated += 1

            # message matrices: per-relationship segment-sums (a different
            # primitive; at most a handful per relation survive the sweep)
            for key in msg_keys:
                new_val, nb = self._delta_update_msg(key, delta_db,
                                                     delta.sign)
                if new_val is not None:
                    cache.put(key, new_val, nbytes=nb)
                    cache.count_delta_updates()
                    report.updated += 1
                elif cache.discard(key):
                    report.invalidated += 1

            # (a) derived tables: push the block deltas through the fused
            # butterfly — one negative-phase dispatch per (shape, perm)
            # group — and add onto the resident tables
            if fam_items:
                provider = _DeltaPositives(self, delta_db)
                outs = complete_ct_delta_many(
                    [(point, keep) for _, point, keep in fam_items], rel,
                    provider, self.stats,
                    mobius_fn=self.mobius_fn(),
                    mobius_batch_fn=self.mobius_batch_fn(),
                    mobius_fused_fn=self.mobius_fused_fn())
                for (key, _, _), (status, dtab) in zip(fam_items, outs):
                    if status == "zero":
                        report.retained += 1
                        continue
                    old = cache.peek(key) if status == "delta" else None
                    if old is None:
                        if cache.discard(key):
                            report.invalidated += 1
                        continue
                    new = old + dtab.scale(delta.sign)
                    cache.put(key, new, nbytes=new.nbytes)
                    cache.count_delta_updates()
                    report.updated += 1
            sp.set(updated=report.updated, invalidated=report.invalidated,
                   retained=report.retained)
        return report

    def _apply_attr_delta(self, delta: AttrDelta) -> DeltaReport:
        """Reconcile after an entity-attribute write: drop exactly the
        entries whose dependency tags intersect the written columns (or
        whose deps are unknown), retain the rest."""
        tags = delta.dep_tags()
        report = DeltaReport(delta.etype, "update_attrs", delta.num_rows,
                             version=self.db.version)
        cache = self.cache
        with self.tracer.span("engine.apply_delta", etype=delta.etype,
                              op="update_attrs",
                              num_rows=delta.num_rows) as sp:
            for key in cache.keys_snapshot():
                meta = cache.entry_meta(key)
                if meta is None:
                    continue
                deps, _version = meta
                if deps is not None and not (deps & tags):
                    report.retained += 1
                    continue
                if cache.discard(key):
                    report.invalidated += 1
            sp.set(updated=0, invalidated=report.invalidated,
                   retained=report.retained)
        return report

    def _classify_for_delta(self, key: Tuple):
        """Sort one affected resident entry into its delta-update family:
        ``("pos", (old, plan))`` for positive tables, ``("msg", ())`` for
        message matrices, ``("fam", (point, keep))`` for derived tables —
        or ``None`` when the entry cannot be delta-updated (unknown
        namespace, other executor's artefact, unplannable key) and must be
        dropped."""
        ns = key[0]
        ex = self.executor
        try:
            if ns == "pos" and key[1] == ex.name:
                old = self.cache.peek(key)
                if old is None:
                    return None
                plan = compile_plan_cached(self.db.schema,
                                           LatticePoint(key[2]),
                                           tuple(key[3]))
                return "pos", (old, plan)
            if ns == "full" and key[1] == ex.name:
                old = self.cache.peek(key)
                if old is None:
                    return None
                return "pos", (old, self.plan(LatticePoint(key[2]), None))
            if ns == "msg" and key[1] == ex.name:
                return "msg", ()
            if ns in ("fam", "complete"):
                return "fam", (LatticePoint(key[1]), tuple(key[2]))
        except (KeyError, ValueError, TypeError):
            pass
        return None

    def _delta_update_msg(self, key: Tuple, delta_db: RelationalDB,
                          sign: int) -> Tuple[Optional[object],
                                              Optional[int]]:
        """Tuple-ID message matrices are per-relationship segment-sums —
        linear in the edge list by construction, so the delta hop simply
        adds on."""
        _, _, atom, child, parent = key
        hit = self.cache.peek(key)
        if hit is None:
            return None, None
        m, mvars = hit
        schema = self.db.schema
        cattrs = tuple(attr_var(child, a.name, a.card)
                       for a in schema.entity(child.etype).attrs)
        rel_t = schema.relationship(atom.rel)
        eattrs = tuple(edge_var(rel_t.name, a.name, a.card)
                       for a in rel_t.attrs)
        ex = self.executor
        with self.stats.timer("positive"), ex.local_mode():
            dm, dvars = ex.leaf_hop(delta_db, atom, child, parent,
                                    cattrs, eattrs, self.stats)
        if tuple(dvars) != tuple(mvars):
            return None, None          # layout drifted: drop instead
        new_m = m + sign * dm
        return (new_m, tuple(mvars)), int(new_m.nbytes)


class _DeltaPositives:
    """Positive provider over a delta view, for
    :func:`~repro.core.mobius.complete_ct_delta_many`: contractions hit
    the delta edges only (exact per-block deltas, by multilinearity) while
    histograms serve FULL values through the engine's cache (the delta
    view shares the entity tables, so full histograms are exactly the
    unchanged factors of the delta's product form).  Results memoise
    per-call only — delta-view positives must never land in the real
    cache."""

    def __init__(self, engine: CountingEngine, delta_db: RelationalDB):
        self.engine = engine
        self.delta_db = delta_db
        self._memo: Dict[Tuple, CtTable] = {}

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        key = (point.atoms, tuple(keep))
        hit = self._memo.get(key)
        if hit is None:
            eng = self.engine
            plan = compile_plan_cached(eng.db.schema, point, tuple(keep))
            with eng.stats.timer("positive"), eng.executor.local_mode():
                hit = eng.executor.positive(self.delta_db, plan, eng.stats)
            self._memo[key] = hit
        return hit

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        return self.engine.hist(var, keep)


class _Policy:
    """Base: delegate histograms; subclasses implement ``positive``.

    All data-access work (contractions, message propagation) is timed here
    under ``time_positive`` — including eviction-driven *recomputes* — so
    the Fig. 3 decomposition stays truthful under a cache budget.
    ``ct_rows`` (Table 5) is bumped once per distinct artefact, not per
    recompute."""

    def __init__(self, engine: CountingEngine):
        self.engine = engine

    def _count_rows_once(self, key: Tuple, tab: CtTable) -> None:
        self.engine.count_rows_once(key, tab)

    def hist(self, var: Var, keep: Tuple[CtVar, ...]) -> CtTable:
        return self.engine.hist(var, keep)

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        pass

    # -- serve-layer integration --------------------------------------------
    supports_batch_prefetch = False    # callers skip query enumeration when
                                       # a policy can never batch (TUPLEID)

    def batchable_misses(self, queries: Sequence[Tuple[LatticePoint,
                                                       Tuple[CtVar, ...]]]
                         ) -> List[Tuple[LatticePoint,
                                         Optional[Tuple[CtVar, ...]]]]:
        """Of the positive queries a Möbius join is about to issue, the
        deduplicated subset this policy would contract *from data* on miss
        — i.e. what a batching service should execute as one
        signature-bucketed dispatch.  Policies whose misses are not plan
        contractions (tuple-ID message recombination) return []."""
        return []

    def absorb(self, point: LatticePoint,
               keep: Optional[Tuple[CtVar, ...]], tab: CtTable) -> None:
        """Accept a service-computed positive table for a query previously
        reported by :meth:`batchable_misses` (same caching + row
        accounting as the policy's own miss path)."""
        raise NotImplementedError


class OnDemandPositives(_Policy):
    """Contract positives from the database per request (counts JOINs);
    memoised in the shared cache (the paper's post-count cache)."""

    supports_batch_prefetch = True

    def _key(self, point: LatticePoint, keep: Tuple[CtVar, ...]) -> Tuple:
        return ("pos", self.engine.executor.name, point.atoms, tuple(keep))

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        eng = self.engine
        key = self._key(point, keep)
        hit = eng.cache.get(key)
        if hit is None:
            with eng.stats.timer("positive"):   # the per-family JOIN cost
                hit = eng.contract(point, keep)
            self._count_rows_once(key, hit)
            eng.cache.put(key, hit)
        return hit

    def batchable_misses(self, queries):
        out, seen = [], set()
        for point, keep in queries:
            key = self._key(point, keep)
            if key not in self.engine.cache and key not in seen:
                seen.add(key)
                out.append((point, tuple(keep)))
        return out

    def absorb(self, point, keep, tab):
        key = self._key(point, keep)
        self._count_rows_once(key, tab)
        self.engine.cache.put(key, tab)


class CachedFullPositives(_Policy):
    """Serve positives by *projection* from full-attribute positive tables
    contracted once per lattice point — zero data access afterwards
    (HYBRID / PRECOUNT).  Evicted entries are re-contracted on miss."""

    supports_batch_prefetch = True

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        for point in lattice:
            self._full(point)

    def _full_key(self, point: LatticePoint) -> Tuple:
        # keyed by the atoms (not just the rel set) so the delta path can
        # recompile the exact plan the cached table came from
        return ("full", self.engine.executor.name, point.atoms)

    def _full(self, point: LatticePoint) -> CtTable:
        eng = self.engine
        key = self._full_key(point)
        hit = eng.cache.get(key)
        if hit is None:
            with eng.stats.timer("positive"):
                hit = eng.contract(point, None)
            self._count_rows_once(key, hit)
            eng.cache.put(key, hit)
        return hit

    def batchable_misses(self, queries):
        # misses here are evicted full-resolution tables: one (point, None)
        # re-contraction per distinct sub-point no longer resident
        out, seen = [], set()
        for point, _ in queries:
            key = self._full_key(point)
            if key not in self.engine.cache and key not in seen:
                seen.add(key)
                out.append((point, None))
        return out

    def absorb(self, point, keep, tab):
        key = self._full_key(point)
        self._count_rows_once(key, tab)
        self.engine.cache.put(key, tab)

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        # NOTE §Perf H3 it.3: memoising these projections by (atoms, keep)
        # was tried and REFUTED — CtVar-tuple hashing overhead exceeded the
        # projection cost at every dataset size measured.
        return self._full(point).project(keep)


class TupleIdPositives(_Policy):
    """Positive tables via tuple-ID propagation (the paper's 'Pre-Count
    Variants' future-work section, realised in tensors).

    ``precompute`` caches, per (relationship, direction), the full-resolution
    message matrix ``M[parent_entity, D_child_attrs x D_edge_attrs]`` — the
    mass each parent node receives through that relationship.  A family
    positive is then a pure contraction of cached entity-indexed matrices
    (column projection + root reduce): edge tables are never touched again.
    Cost profile per the paper: scales well in predicates (one matrix per
    relationship), less well in rows (matrices are entity-indexed)."""

    def _full_resolution(self, atom: Atom, child: Var
                         ) -> Tuple[Tuple[CtVar, ...], Tuple[CtVar, ...]]:
        schema = self.engine.db.schema
        cattrs = tuple(attr_var(child, a.name, a.card)
                       for a in schema.entity(child.etype).attrs)
        rel = schema.relationship(atom.rel)
        eattrs = tuple(edge_var(rel.name, a.name, a.card) for a in rel.attrs)
        return cattrs, eattrs

    def _msg(self, atom: Atom, child: Var, parent: Var):
        eng = self.engine
        # keyed by the full atom (not just the rel name): the delta path
        # re-runs the hop, and for self-relationships the atom carries the
        # direction the message was propagated in
        key = ("msg", eng.executor.name, atom, child, parent)
        hit = eng.cache.get(key)
        if hit is None:
            cattrs, eattrs = self._full_resolution(atom, child)
            with eng.stats.timer("positive"):
                m, mvars = eng.executor.leaf_hop(eng.db, atom, child, parent,
                                                 cattrs, eattrs, eng.stats)
            hit = eng.cache.put(key, (m, tuple(mvars)), nbytes=int(m.nbytes))
        return hit

    def precompute(self, lattice: Sequence[LatticePoint]) -> None:
        seen: Set[Tuple] = set()
        for point in lattice:
            for atom in point.atoms:
                for child, parent in ((atom.src, atom.dst),
                                      (atom.dst, atom.src)):
                    if (atom.rel, child, parent) not in seen:
                        seen.add((atom.rel, child, parent))
                        self._msg(atom, child, parent)

    def positive(self, point: LatticePoint,
                 keep: Tuple[CtVar, ...]) -> CtTable:
        eng = self.engine
        keep = tuple(keep)
        plan = eng.plan(point, keep)
        factors: List[Tuple[jnp.ndarray, Tuple[CtVar, ...]]] = []
        for hop in plan.root.hops:
            if hop.is_leaf_hop:
                m, mvars = self._msg(hop.atom, hop.child, hop.parent)
                factors.append(project_columns(m, mvars, keep))
            else:   # deeper subtree (chains of length > 2): propagate live
                factors.append(eng.executor.hop_message(eng.db, hop,
                                                        eng.stats))
        return eng.executor.root_reduce(eng.db, plan.root.own, factors,
                                        keep, eng.stats)


POSITIVE_POLICIES = {
    "ondemand": OnDemandPositives,
    "cached_full": CachedFullPositives,
    "tupleid": TupleIdPositives,
}
