"""Hybrid count-cache routing monitor (DESIGN.md §5).

The paper's machinery applied *inside* the training framework: MoE routing
assignments form a relational database — tokens are entities (with bucket /
position attributes), experts are entities, and ``Routed(token, expert)`` is
a relationship table.  The monitor builds that database from a probe batch
and answers contingency questions with the HYBRID strategy, including
*negative* relationships ("how many high-position tokens did expert e NOT
see?") via the Möbius join — the negation problem, on live training state.

Usage (see examples/moe_routing_monitor.py):

    trace = routing_trace(model, params, batch)          # [L, B, S, K] ids
    db    = routing_db(trace[layer], buckets, cfg.n_experts)
    tab, stats = routing_ct(db)                          # complete ct-table
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.database import EntityTable, RelationTable, RelationalDB
from repro.core.schema import Attribute, EntityType, Relationship, Schema
from repro.core.strategies import Hybrid
from repro.core.variables import build_lattice
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.model import LM


def routing_trace(model: LM, params, batch) -> jnp.ndarray:
    """Per-layer top-k expert assignments for a probe batch.

    Runs the stack unrolled (monitoring path — not the jitted train step)
    and reads the router at each layer's MoE input.  Returns int32
    [L, B, S, K]."""
    cfg = model.cfg
    assert cfg.is_moe, "routing_trace requires an MoE config"
    x = model._embed_in(params, batch)
    from repro.models.model import _positions_for
    positions = _positions_for(cfg, batch, x.shape[1])
    from repro.models.transformer import block_apply
    traces: List[jnp.ndarray] = []
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        n2_in = rms_norm(x, p["norm2"])  # what moe_apply will see *after* attn
        # recompute the block to advance the stream
        x, _ = block_apply(p, x, cfg, positions)
        logits = jnp.einsum("bsd,de->bse", n2_in,
                            p["moe"].router.astype(n2_in.dtype),
                            preferred_element_type=jnp.float32)
        _, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
        traces.append(eidx.astype(jnp.int32))
    return jnp.stack(traces)


def routing_db(eidx: jnp.ndarray, buckets: jnp.ndarray,
               n_experts: int, n_buckets: int = 4,
               n_pos_buckets: int = 4) -> RelationalDB:
    """Relational view of one layer's routing.

    eidx [B, S, K] int32 expert ids; buckets [B, S] int32 in [0, n_buckets).
    Entities: token(bucket, posq), expert(group).  Relationship:
    Routed(token, expert)."""
    b, s, k = eidx.shape
    n_tok = b * s
    tok_bucket = np.asarray(buckets, np.int32).reshape(n_tok)
    posq = np.broadcast_to(
        (np.arange(s, dtype=np.int32) * n_pos_buckets) // s, (b, s)
    ).reshape(n_tok).copy()
    e_group = (np.arange(n_experts, dtype=np.int32) * 4) // n_experts

    schema = Schema(
        entities=(
            EntityType("token", n_tok, (Attribute("bucket", n_buckets),
                                        Attribute("posq", n_pos_buckets))),
            EntityType("expert", n_experts, (Attribute("group", 4),)),
        ),
        relationships=(
            Relationship("Routed", "token", "expert", ()),
        ),
    )
    src = np.repeat(np.arange(n_tok, dtype=np.int32), k)
    dst = np.asarray(eidx, np.int32).reshape(n_tok * k)
    # unique (token, expert) pairs — the relationship is a set
    pairs = np.unique(src.astype(np.int64) * n_experts + dst)
    src = (pairs // n_experts).astype(np.int32)
    dst = (pairs % n_experts).astype(np.int32)

    db = RelationalDB(
        schema,
        {"token": EntityTable(schema.entity("token"),
                              {"bucket": tok_bucket, "posq": posq}),
         "expert": EntityTable(schema.entity("expert"),
                               {"group": e_group})},
        {"Routed": RelationTable(schema.relationship("Routed"), src, dst, {})},
    )
    db.validate()
    return db


def routing_ct(db: RelationalDB) -> Tuple[object, Dict[str, float]]:
    """Complete ct-table over (Routed?, bucket, group) via HYBRID counting,
    plus summary stats.  The Routed=F rows are the negation problem answered
    by the Möbius join — no second pass over the assignments."""
    lattice = build_lattice(db.schema, 1)
    strat = Hybrid()
    strat.prepare(db, lattice)
    point = lattice[0]
    keep = point.all_ct_vars(db.schema, include_rind=True)
    # project to (bucket, group, rind)
    keep = tuple(v for v in keep
                 if v.kind == "rind" or v.owner[-1] in ("bucket", "group"))
    tab = strat.family_ct(point, keep)

    rind_ax = next(i for i, v in enumerate(tab.vars) if v.kind == "rind")
    counts = np.asarray(tab.counts)
    pos = np.take(counts, 1, axis=rind_ax)
    neg = np.take(counts, 0, axis=rind_ax)
    total = pos.sum() + neg.sum()
    load = pos.sum(axis=tuple(i for i, v in enumerate(tab.vars)
                              if i != rind_ax and v.owner[-1] != "group"
                              ) if pos.ndim > 1 else None)
    stats = {
        "pairs_total": float(total),
        "routed_pairs": float(pos.sum()),
        "unrouted_pairs": float(neg.sum()),
        "routed_fraction": float(pos.sum() / max(total, 1.0)),
        "joins": strat.stats.joins,
        "peak_cache_bytes": strat.stats.peak_bytes,
    }
    return tab, stats
