"""Sharding rules: parameter and activation PartitionSpecs.

2-D scheme (DESIGN.md §4): tensor-parallel over ``model`` on heads/ffn/vocab
dims, FSDP over ``fsdp_axes`` (``('data',)`` single-pod, ``('pod','data')``
multi-pod) on the d_model/embed dim.  Dims that do not divide the mesh axis
are replicated (e.g. hymba's 25 heads, whisper's 8 heads on a 16-way TP
axis) — the rule checks divisibility against the actual mesh.

Parameter leaf names are the contract with ``models/*``: rules key on the
trailing-dims semantics of each named leaf; leading scan (L) axes get None.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """(fsdp_axes, tp_axis) for a production mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


# trailing-dim spec templates per leaf name: "F" = fsdp, "T" = tp, "-" = none
_RULES: Dict[str, Tuple[str, ...]] = {
    # embeddings
    "embed": ("T", "F"),
    # attention
    "wq": ("F", "T"), "wk": ("F", "T"), "wv": ("F", "T"), "wo": ("T", "F"),
    "bq": ("T",), "bk": ("T",), "bv": ("T",),
    # mlp
    "wi": ("F", "T"), "wg": ("F", "T"),
    # moe (leading E dim -> expert parallel over tp)
    "router": ("F", "-"),
    "moe_wi": ("T", "F", "-"), "moe_wg": ("T", "F", "-"),
    "moe_wo": ("T", "-", "F"),
    # rwkv
    "wr": ("F", "T"), "w_decay": ("F", "T"),
    "ck": ("F", "T"), "cv": ("T", "F"), "cr": ("F", "T"),
    # ssm
    "w_in": ("F", "T"), "w_gate": ("F", "T"), "w_bc": ("F", "T"),
    "w_dt": ("F", "-"), "w_out": ("T", "F"),
}
# wk/wv of rwkv are [D, D] like wr; wo appears in attn [H,D], mlp [F,D],
# rwkv [D,D] — all ("T","F")-compatible; moe wi/wg/wo are disambiguated by a
# 3-trailing-dim check below.


def spec_for_param(path, shape, mesh: Mesh) -> P:
    fsdp, tp = mesh_axes(mesh)
    name = _leaf_name(path)
    path_str = jax.tree_util.keystr(path)
    ndim = len(shape)
    if ndim == 0:
        return P()
    rule: Optional[Tuple[str, ...]] = None
    if "moe" in path_str and name in ("wi", "wg", "wo"):
        rule = _RULES["moe_" + name]
    elif name in _RULES:
        rule = _RULES[name]
    if rule is None or ndim < len(rule):
        return P(*([None] * ndim))          # norms, biases, mus, scalars
    lead = ndim - len(rule)
    spec = [None] * lead
    for sym, dim in zip(rule, shape[lead:]):
        if sym == "F":
            spec.append(fsdp if dim % _axis_size(mesh, fsdp) == 0 else None)
        elif sym == "T":
            spec.append(tp if dim % _axis_size(mesh, tp) == 0 else None)
        else:
            spec.append(None)
    return P(*spec)


def param_shardings(abstract_params, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for_param(path, leaf.shape, mesh)),
        abstract_params)


# ----------------------------------------------------------- activations ---

def batch_spec(name: str, shape, mesh: Mesh, decode: bool = False) -> P:
    """PartitionSpec for one input-batch leaf."""
    fsdp, tp = mesh_axes(mesh)
    bdiv = lambda d: fsdp if d % _axis_size(mesh, fsdp) == 0 else None
    nd = len(shape)
    if name == "positions":                       # [3, B, S]
        return P(None, bdiv(shape[1]), None)
    if name == "pos" or nd == 0:
        return P()
    if name in ("tokens", "labels", "token"):     # [B, S]
        return P(bdiv(shape[0]), None)
    if name in ("embeds", "frames", "embed1"):    # [B, S, D]
        return P(bdiv(shape[0]), None, None)
    return P(*([None] * nd))


def batch_shardings(batch, mesh: Mesh):
    return {
        k: NamedSharding(mesh, batch_spec(k, getattr(v, "shape", ()), mesh))
        for k, v in batch.items()
    }


def cache_spec(name: str, shape, mesh: Mesh) -> P:
    """Decode-cache leaf specs: KV sequence axis sharded over ``model``
    (flash-decoding), recurrent states sharded over heads when divisible."""
    fsdp, tp = mesh_axes(mesh)
    bdiv = lambda d: fsdp if d % _axis_size(mesh, fsdp) == 0 else None
    tdiv = lambda d: tp if d % _axis_size(mesh, tp) == 0 else None
    if name in ("k", "v"):          # [L, B, S, Hkv, hd]
        return P(None, bdiv(shape[1]), tp, None, None)
    if name in ("xk", "xv"):        # [L, B, F, Hkv, hd] cross-attn (static)
        return P(None, bdiv(shape[1]), None, None, None)
    if name == "wkv":               # [L, B, H, dk, dv]
        return P(None, bdiv(shape[1]), tdiv(shape[2]), None, None)
    if name == "ssm":               # [L, B, H, N, hd]
        return P(None, bdiv(shape[1]), tdiv(shape[2]), None, None)
    if name in ("tm_x", "cm_x"):    # [L, B, D]
        return P(None, bdiv(shape[1]), None)
    return P(*([None] * len(shape)))


def cache_shardings(cache, mesh: Mesh):
    return {
        k: NamedSharding(mesh, cache_spec(k, v.shape, mesh))
        for k, v in cache.items()
    }


def logits_sharding(mesh: Mesh, batch_dim: int,
                    vocab: Optional[int] = None) -> NamedSharding:
    """[B, V] logits: batch over fsdp, vocab over tp — each only when the
    dim divides the axis (hymba's 32,001 / whisper's 51,865 vocabs do not
    divide a 16-way TP axis and are replicated; see cfg.pad_vocab for the
    padded fast path)."""
    fsdp, tp = mesh_axes(mesh)
    b_ax = fsdp if batch_dim % _axis_size(mesh, fsdp) == 0 else None
    v_ax = tp if vocab is None or vocab % _axis_size(mesh, tp) == 0 else None
    return NamedSharding(mesh, P(b_ax, v_ax))
