"""Train and serve step factories.

``make_train_step`` builds the jit-able (state, batch) -> (state, metrics)
function with microbatch gradient accumulation (``cfg.microbatch`` scans over
batch slices, f32 grad accumulator carrying the parameter sharding) and the
optimizer update.  ``make_prefill_step`` / ``make_decode_step`` build the
serving steps; decode threads the mesh through so the KV-sequence-sharded
flash-decoding shard_map can run inside the jitted step.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import LM
from repro.optim.adamw import AdamW, OptConfig, make_optimizer
from .sharding import mesh_axes, spec_for_param


def constrain_like_params(tree):
    """Pin a param-shaped tree (grad accumulator, compressed grads) to the
    parameter sharding.  Without this XLA all-gathers FSDP-sharded gradient
    slices into the f32 accumulator — measured at 5.8 TB/chip/step on
    arctic-480b (EXPERIMENTS.md §Perf beyond-cells note)."""
    am = jax.sharding.get_abstract_mesh()
    if am is None or am.empty:
        return tree
    return jax.tree_util.tree_map_with_path(
        lambda path, x: jax.lax.with_sharding_constraint(
            x, spec_for_param(path, x.shape, am)), tree)


def init_train_state(model: LM, opt, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params)}


def make_train_step(model: LM, opt, compress=None) -> Callable:
    cfg = model.cfg

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if cfg.microbatch > 1:
            m = cfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape((m, b // m) + x.shape[1:])

            def split_leaf(k, x):
                if k == "positions":
                    return jnp.moveaxis(
                        x.reshape((3, m, x.shape[1] // m) + x.shape[2:]), 1, 0)
                return split(x)

            mbs = {k: split_leaf(k, v) for k, v in batch.items()}

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                g_acc = constrain_like_params(g_acc)
                return (g_acc, l_acc + loss), metrics

            g0 = constrain_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = loss_sum / m
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if compress is not None:
            grads, state = compress(grads, state)
        new_params, new_opt, opt_metrics = opt.update(
            params, grads, state["opt"])
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt, **{
            k: v for k, v in state.items() if k not in ("params", "opt")}}, metrics

    return train_step


def make_loss_step(model: LM) -> Callable:
    def step(params, batch):
        return model.loss(params, batch)[0]
    return step


def make_prefill_step(model: LM) -> Callable:
    def prefill(params, batch):
        return model.prefill(params, batch)
    return prefill


def make_decode_step(model: LM, mesh=None, seq_sharded: bool = True) -> Callable:
    """decode(params, cache, batch) -> (logits, cache).  With a mesh, the KV
    sequence axis is sharded over 'model' and combined via psum."""
    dp_axes = None
    seq_axis = None
    if mesh is not None and seq_sharded:
        fsdp, tp = mesh_axes(mesh)
        dp_axes, seq_axis = fsdp, tp

    def decode(params, cache, batch):
        return model.decode_step(params, cache, batch, dp_axes=dp_axes,
                                 seq_axis=seq_axis, mesh=mesh)

    return decode
