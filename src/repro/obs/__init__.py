"""Observability for the counting stack: tracing, percentiles, export.

Three pieces, each usable alone:

* :mod:`repro.obs.trace` — ring-buffered request tracing with a free
  no-op default (:data:`NULL_TRACER`) and the ``REPRO_TRACE`` env knob;
* :mod:`repro.obs.hist` — fixed-bucket log-scale latency histograms
  whose merge is exactly associative (p50/p95/p99 + max);
* :mod:`repro.obs.registry` — Prometheus-text / JSON rendering of
  snapshots, plus :mod:`repro.obs.slowlog` (top-K slow queries) and
  :mod:`repro.obs.profile` (``jax.profiler`` annotations on jitted
  dispatches).

This package deliberately imports nothing from :mod:`repro.core` or
:mod:`repro.serve`, so every layer of the stack can depend on it.
"""

from .hist import CountHistogram, LatencyHistogram, N_BUCKETS
from .profile import annotate
from .registry import MetricsRegistry, prometheus_lines
from .slowlog import SlowQuery, SlowQueryLog
from .trace import (NULL_TRACER, NullTracer, Span, SpanContext, SpanRecord,
                    Tracer, build_trees, default_tracer)

__all__ = [
    "CountHistogram", "LatencyHistogram", "N_BUCKETS",
    "annotate",
    "MetricsRegistry", "prometheus_lines",
    "SlowQuery", "SlowQueryLog",
    "NULL_TRACER", "NullTracer", "Span", "SpanContext", "SpanRecord",
    "Tracer", "build_trees", "default_tracer",
]
