"""Fixed-bucket log-scale latency histograms (p50/p95/p99 + max).

`CostStats` and `ServiceMetrics` keep *totals* (`exec_s`, `wait_s`);
totals hide tails, and the paper's scalability argument (Fig. 3) is
about tails — one straggling shard, one pathological contraction.  A
:class:`LatencyHistogram` buckets each observation by the bit length of
its duration in nanoseconds: bucket ``i`` covers ``[2^(i-1), 2^i) ns``,
64 buckets span sub-nanosecond to ~292 years, and the bucketing is two
integer ops — cheap enough for every queue-wait observation.

Because buckets are *fixed* (no rebalancing, no per-instance state in
the bounds), merging two histograms is element-wise count addition:
exactly associative and commutative, which is what lets
``ServiceMetrics.merged`` roll per-shard histograms into fleet-level
percentiles without bias.  Percentile queries return the upper bound of
the bucket holding that rank (capped at the true observed max), so the
reported p99 is within 2x of the true p99 by construction.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence

__all__ = ["CountHistogram", "LatencyHistogram", "N_BUCKETS"]

N_BUCKETS = 64


class LatencyHistogram:
    """Log2-bucketed latency histogram over seconds.

    Usage::

        h = LatencyHistogram()
        h.observe(0.004)                  # 4 ms
        h.percentile(0.99)                # upper bound of the p99 bucket
        merged = LatencyHistogram.merged([h1, h2])   # exact count sums
    """

    __slots__ = ("counts", "count", "sum_s", "max_s")

    def __init__(self, counts: Optional[Sequence[int]] = None,
                 count: int = 0, sum_s: float = 0.0, max_s: float = 0.0):
        self.counts: List[int] = (list(counts) if counts is not None
                                  else [0] * N_BUCKETS)
        if len(self.counts) != N_BUCKETS:
            raise ValueError(f"expected {N_BUCKETS} buckets, "
                             f"got {len(self.counts)}")
        self.count = count
        self.sum_s = sum_s
        self.max_s = max_s

    # -- recording ----------------------------------------------------------
    @staticmethod
    def bucket_of(duration_s: float) -> int:
        """Bucket index for a duration: ``min(bitlen(ns), 63)``."""
        ns = int(duration_s * 1e9)
        if ns <= 0:
            return 0
        return min(ns.bit_length(), N_BUCKETS - 1)

    @staticmethod
    def bucket_upper_s(i: int) -> float:
        """Upper bound of bucket ``i`` in seconds (``2^i`` ns)."""
        return (1 << i) / 1e9

    def observe(self, duration_s: float) -> None:
        self.counts[self.bucket_of(duration_s)] += 1
        self.count += 1
        self.sum_s += duration_s
        if duration_s > self.max_s:
            self.max_s = duration_s

    # -- queries ------------------------------------------------------------
    def percentile(self, q: float) -> float:
        """The ``q``-quantile (``0 < q <= 1``) as the upper bound of the
        bucket containing that rank, capped at the observed max.  Empty
        histograms report 0."""
        if self.count == 0:
            return 0.0
        rank = max(1, int(q * self.count + 0.999999))  # ceil, 1-based
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return min(self.bucket_upper_s(i), self.max_s)
        return self.max_s

    @property
    def mean_s(self) -> float:
        return self.sum_s / self.count if self.count else 0.0

    # -- merge / serialisation ---------------------------------------------
    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """In-place element-wise merge (exactly associative); returns self."""
        for i, c in enumerate(other.counts):
            if c:
                self.counts[i] += c
        self.count += other.count
        self.sum_s += other.sum_s
        if other.max_s > self.max_s:
            self.max_s = other.max_s
        return self

    @classmethod
    def merged(cls, many: Iterable["LatencyHistogram"]) -> "LatencyHistogram":
        out = cls()
        for h in many:
            out.merge(h)
        return out

    def copy(self) -> "LatencyHistogram":
        return LatencyHistogram(self.counts, self.count, self.sum_s,
                                self.max_s)

    def as_dict(self) -> Dict[str, float]:
        """Snapshot summary: count, mean, p50/p95/p99, max (seconds)."""
        return dict(count=self.count,
                    mean_s=round(self.mean_s, 6),
                    p50_s=round(self.percentile(0.50), 6),
                    p95_s=round(self.percentile(0.95), 6),
                    p99_s=round(self.percentile(0.99), 6),
                    max_s=round(self.max_s, 6))

    def nonzero_buckets(self) -> List[tuple]:
        """``(upper_bound_s, cumulative_count)`` per occupied bucket —
        the shape Prometheus' ``_bucket{le=...}`` lines need."""
        out, cum = [], 0
        for i, c in enumerate(self.counts):
            if c:
                cum += c
                out.append((self.bucket_upper_s(i), cum))
        return out

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, LatencyHistogram)
                and self.counts == other.counts
                and self.count == other.count)

    def __repr__(self) -> str:       # pragma: no cover - debugging aid
        d = self.as_dict()
        return (f"LatencyHistogram(n={d['count']}, p50={d['p50_s']}s, "
                f"p99={d['p99_s']}s, max={d['max_s']}s)")


class CountHistogram(LatencyHistogram):
    """The same log2-bucketed machinery over dimensionless counts.

    ``observe(n)`` records an integer magnitude (e.g. families re-scored
    per refresh) instead of a duration.  Reusing the latency buckets via
    the nanosecond scaling would shift every observation by 1e9, so the
    count variant buckets the raw value; the merge/percentile algebra is
    inherited unchanged.

    Usage::

        h = CountHistogram()
        h.observe(37)                     # 37 families this refresh
        h.as_dict()["p95"]                # un-suffixed keys: counts, not s
    """

    __slots__ = ()

    @staticmethod
    def bucket_of(value: float) -> int:
        n = int(value)
        if n <= 0:
            return 0
        return min(n.bit_length(), N_BUCKETS - 1)

    @staticmethod
    def bucket_upper_s(i: int) -> float:
        return float(1 << i)

    def as_dict(self) -> Dict[str, float]:
        """Snapshot summary with un-suffixed keys (these are counts)."""
        return dict(count=self.count,
                    mean=round(self.mean_s, 3),
                    p50=round(self.percentile(0.50), 3),
                    p95=round(self.percentile(0.95), 3),
                    p99=round(self.percentile(0.99), 3),
                    max=round(self.max_s, 3))

    def __repr__(self) -> str:       # pragma: no cover - debugging aid
        d = self.as_dict()
        return (f"CountHistogram(n={d['count']}, p50={d['p50']}, "
                f"p99={d['p99']}, max={d['max']})")
