"""Optional ``jax.profiler`` hooks: line device profiles up with spans.

Host-side spans (:mod:`repro.obs.trace`) stop at the jit boundary — the
device timeline in a ``jax.profiler`` trace shows XLA op names, not
"which bucket dispatch was this".  Wrapping each jitted dispatch in a
``jax.profiler.TraceAnnotation`` with the *same name the span uses*
("exec.positive_batch", "exec.mobius_batch") makes the two timelines
joinable by eye in TensorBoard / Perfetto.

Annotations are off by default (they cost a C++ call even when no
profiler session is active) and enabled process-wide via
:func:`enable` or the ``REPRO_JAX_PROFILE`` env var.  When off,
:func:`annotate` returns a shared no-op context manager; when jax's
profiler is unavailable the hooks silently stay off — this module never
makes jax a hard import requirement for the tracer.
"""

from __future__ import annotations

import os

__all__ = ["annotate", "enable", "disable", "enabled"]


class _NullAnnotation:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullAnnotation()
_enabled = False
_trace_annotation = None     # resolved lazily on first enable()


def _resolve():
    global _trace_annotation
    if _trace_annotation is None:
        try:
            from jax.profiler import TraceAnnotation
            _trace_annotation = TraceAnnotation
        except Exception:            # pragma: no cover - jax always present
            _trace_annotation = False
    return _trace_annotation


def enable() -> bool:
    """Turn profiler annotations on; returns whether jax's profiler is
    actually available."""
    global _enabled
    _enabled = bool(_resolve())
    return _enabled


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def annotate(name: str):
    """A context manager marking ``name`` on the device profile timeline
    when enabled, or a shared no-op otherwise.

    Usage::

        with annotate("exec.positive_batch"):
            out = jitted_fn(batch)
    """
    if _enabled and _trace_annotation:
        return _trace_annotation(name)
    return _NULL


if os.environ.get("REPRO_JAX_PROFILE", "").strip() not in ("", "0"):
    enable()
