"""Metrics export: one registry, two render targets (Prometheus text, JSON).

The serve layer already produces rich snapshots (`ServiceMetrics.snapshot`,
`CountingRouter.stats`, `CtCache.info`); what was missing is a single
place that collects them and renders formats a scraper or a human can
consume.  A :class:`MetricsRegistry` holds named *sources* — callables
returning nested dicts (or objects with a ``snapshot()``/``stats()``
method, or plain dicts) — and flattens them on demand:

* :meth:`collect` → the raw nested dict per source (JSON-able);
* :meth:`to_json` → that, serialised;
* :meth:`prometheus` → flattened ``repro_<source>_<path>`` gauge lines,
  with :class:`~repro.obs.hist.LatencyHistogram` summaries expanded into
  native ``_bucket{le=...}`` / ``_sum`` / ``_count`` series.

Sources are re-evaluated at every collect, so registering a live
service/router once is enough; snapshots stay point-in-time.
"""

from __future__ import annotations

import json
from typing import Callable, Dict, List, Union

from .hist import LatencyHistogram

__all__ = ["MetricsRegistry", "prometheus_lines"]

Source = Union[dict, Callable[[], dict], object]


def _sanitize(name: str) -> str:
    """Prometheus metric names: ``[a-zA-Z_][a-zA-Z0-9_]*``."""
    out = [(c if c.isalnum() or c == "_" else "_") for c in name]
    s = "".join(out)
    return s if s and not s[0].isdigit() else "_" + s


def prometheus_lines(prefix: str, value, lines: List[str]) -> None:
    """Flatten one snapshot value into Prometheus text lines under
    ``prefix``.  Dicts recurse with ``_``-joined keys; lists/tuples index;
    histograms render native bucket series; numbers become gauges;
    strings and ``None`` are skipped (Prometheus has no string samples)."""
    if isinstance(value, LatencyHistogram):
        for le, cum in value.nonzero_buckets():
            lines.append(f'{prefix}_bucket{{le="{le:g}"}} {cum}')
        lines.append(f'{prefix}_bucket{{le="+Inf"}} {value.count}')
        lines.append(f"{prefix}_sum {value.sum_s:g}")
        lines.append(f"{prefix}_count {value.count}")
    elif isinstance(value, bool):
        lines.append(f"{prefix} {int(value)}")
    elif isinstance(value, (int, float)):
        lines.append(f"{prefix} {value:g}")
    elif isinstance(value, dict):
        # A histogram that went through as_dict() round-trips as a dict of
        # numbers and is flattened like any other nested mapping.
        for k, v in value.items():
            prometheus_lines(f"{prefix}_{_sanitize(str(k))}", v, lines)
    elif isinstance(value, (list, tuple)):
        for i, v in enumerate(value):
            prometheus_lines(f"{prefix}_{i}", v, lines)
    # strings / None / arbitrary objects: not representable, skip


class MetricsRegistry:
    """Named snapshot sources rendered to Prometheus text or JSON.

    Usage::

        reg = MetricsRegistry()
        reg.register("router", router.stats)       # callable, re-evaluated
        reg.register("svc0", svc)                  # object with .stats()
        text = reg.prometheus()
        blob = reg.to_json(indent=2)
    """

    def __init__(self):
        self._sources: Dict[str, Source] = {}

    def register(self, name: str, source: Source) -> None:
        """Attach a source under ``name``.  A source may be a dict, a
        zero-arg callable returning a dict, or an object exposing
        ``snapshot()`` or ``stats()``.  Re-registering replaces."""
        self._sources[name] = source

    def unregister(self, name: str) -> None:
        self._sources.pop(name, None)

    def sources(self) -> List[str]:
        return sorted(self._sources)

    @staticmethod
    def _resolve(source: Source) -> dict:
        if callable(source):
            return source()
        for attr in ("stats", "snapshot"):
            fn = getattr(source, attr, None)
            if callable(fn):
                return fn()
        if isinstance(source, dict):
            return source
        raise TypeError(f"unusable metrics source: {source!r}")

    def collect(self) -> Dict[str, dict]:
        """Evaluate every source; returns ``{name: snapshot_dict}``."""
        return {name: self._resolve(src)
                for name, src in sorted(self._sources.items())}

    def to_json(self, indent: int = None) -> str:
        """The collected snapshots as a JSON document."""
        return json.dumps(self.collect(), indent=indent, sort_keys=True,
                          default=_json_default)

    def prometheus(self) -> str:
        """All sources flattened to Prometheus text exposition format,
        metric names ``repro_<source>_<nested_path>``."""
        lines: List[str] = []
        for name, snap in self.collect().items():
            prometheus_lines(f"repro_{_sanitize(name)}", snap, lines)
        return "\n".join(lines) + ("\n" if lines else "")


def _json_default(obj):
    if isinstance(obj, LatencyHistogram):
        return obj.as_dict()
    if isinstance(obj, (set, frozenset)):
        return sorted(map(str, obj))
    if hasattr(obj, "as_dict"):
        return obj.as_dict()
    return str(obj)
