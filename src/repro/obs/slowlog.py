"""Slow-query log: the top-K slowest requests over a threshold.

Percentile histograms (:mod:`repro.obs.hist`) say *that* a p99 exists;
the slow-query log says *which queries it was* — each entry keeps the
span name, duration, and whatever the call site knew (plan signature,
routing decision, shard count), so the offender can be replayed.

A bounded min-heap keyed on duration keeps the K slowest seen; offers
under the threshold are one float compare, so the log is safe to feed
from the serve layer's end-to-end observation points unconditionally.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import List, Optional

__all__ = ["SlowQuery", "SlowQueryLog"]


class SlowQuery:
    """One slow request: what it was, how long it took, what the serve
    layer knew about it."""

    __slots__ = ("name", "duration_s", "at", "info")

    def __init__(self, name: str, duration_s: float, at: float, info: dict):
        self.name, self.duration_s, self.at = name, duration_s, at
        self.info = info

    def as_dict(self) -> dict:
        return dict(name=self.name, duration_s=round(self.duration_s, 6),
                    at=round(self.at, 3),
                    info={k: (v if isinstance(v, (int, float, bool,
                                                  type(None))) else str(v))
                          for k, v in self.info.items()})

    def __repr__(self) -> str:       # pragma: no cover - debugging aid
        return (f"SlowQuery({self.name!r}, {self.duration_s * 1e3:.1f}ms, "
                f"{self.info!r})")


class SlowQueryLog:
    """Top-K slowest offers above ``threshold_s``.

    Args:
        threshold_s: durations at or below this are ignored; ``None``
            disables automatic offers (``offer`` returns ``False``) while
            keeping the object around so call sites stay unconditional.
        top_k: how many entries to retain (smallest is evicted first).

    Usage::

        log = SlowQueryLog(threshold_s=0.05, top_k=16)
        log.offer("router.e2e", dt, signature=sig, mode="fanout")
        for q in log.entries():
            print(q.name, q.duration_s, q.info)
    """

    def __init__(self, threshold_s: Optional[float] = 0.05, top_k: int = 32):
        self.threshold_s = threshold_s
        self.top_k = top_k
        self._heap: List[tuple] = []   # (duration, tiebreak, SlowQuery)
        self._tie = itertools.count()
        self._lock = threading.Lock()
        self.offered = 0
        self.admitted = 0

    def offer(self, name: str, duration_s: float, **info) -> bool:
        """Consider one request; returns whether it was admitted."""
        self.offered += 1
        thr = self.threshold_s
        if thr is None or duration_s <= thr:
            return False
        with self._lock:
            if len(self._heap) >= self.top_k:
                if duration_s <= self._heap[0][0]:
                    return False
                heapq.heappop(self._heap)
            heapq.heappush(self._heap, (duration_s, next(self._tie),
                                        SlowQuery(name, duration_s,
                                                  time.time(), info)))
            self.admitted += 1
        return True

    def entries(self) -> List[SlowQuery]:
        """Retained queries, slowest first."""
        with self._lock:
            return [q for _, _, q in sorted(self._heap, reverse=True)]

    def as_dicts(self) -> List[dict]:
        return [q.as_dict() for q in self.entries()]

    def clear(self) -> None:
        with self._lock:
            self._heap.clear()
        self.offered = self.admitted = 0

    def __len__(self) -> int:
        return len(self._heap)
