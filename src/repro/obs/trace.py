"""Request tracing: ring-buffered spans threaded through the counting stack.

The serve layer's counters (:mod:`repro.serve.metrics`) answer "how much";
they cannot answer "where did *this* query's 40 ms go".  A
:class:`Tracer` records **spans** — named intervals with monotonic
``(t0, t1)`` timestamps, a trace id shared by everything one request
touched, and a parent link — into a fixed-capacity ring buffer
(:class:`collections.deque`), so a traced flood reconstructs, per query,
the full path router submit → shard service queue → bucket execution →
shard merge → cache install, including which shard was the straggler and
which dispatch path (fan-out fast path, fused flush, per-ticket fallback)
handled it.

Design constraints, in order:

* **Off is free.**  The default tracer is :data:`NULL_TRACER`; its
  ``span()`` hands back one shared no-op context manager and its
  ``event()``/``record()`` return immediately.  Hot paths that would pay
  even for building the ``attrs`` dict guard with ``tracer.enabled``.
* **On is cheap.**  Recording a span is one ``deque.append`` of a slotted
  record (appends are atomic under CPython, so the hot path takes no
  lock); the ring bounds memory and old spans simply fall off.
* **Cross-thread by value.**  A span's :class:`SpanContext` is a plain
  ``(trace_id, span_id)`` pair; code that hands work to another thread
  (the service queue, the router fan-out) stores the context on the work
  item and the executing side parents its spans on it explicitly.
  Same-thread nesting is implicit via a thread-local span stack.
* **Retroactive spans.**  Queue residency is only known when the entry is
  drained; :meth:`Tracer.record` writes a span from timestamps captured
  earlier, so no span object needs to live across threads.

Enable per service/router via the ``tracer=`` knob (or
``CountingService.set_tracer`` / ``CountingRouter.set_tracer``), or
process-wide with the ``REPRO_TRACE`` environment variable (any value
other than ``"" / "0"``; an integer sets the ring capacity), which
:func:`default_tracer` resolves at construction time.

Usage::

    tracer = Tracer(capacity=65536)
    with tracer.span("router.submit", mode="fanout") as sp:
        ctx = sp.context                     # hand to another thread
    tracer.record("service.queue", t0, t1, parent=ctx)
    trees = tracer.trees()                   # per-trace nested span trees
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from typing import Dict, List, NamedTuple, Optional, Sequence

from .slowlog import SlowQueryLog

__all__ = ["SpanContext", "SpanRecord", "Span", "Tracer", "NullTracer",
           "NULL_TRACER", "default_tracer", "build_trees"]

_ids = itertools.count(1)          # span ids; next() is atomic in CPython
_trace_ids = itertools.count(1)


class SpanContext(NamedTuple):
    """The by-value identity of a span — what crosses thread boundaries."""
    trace_id: int
    span_id: int


class SpanRecord:
    """One finished span in the ring (slotted: a traced flood records
    thousands of these)."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "t0", "t1",
                 "attrs", "thread")

    def __init__(self, trace_id: int, span_id: int, parent_id: Optional[int],
                 name: str, t0: float, t1: float, attrs: Optional[dict],
                 thread: str):
        self.trace_id, self.span_id, self.parent_id = (trace_id, span_id,
                                                       parent_id)
        self.name, self.t0, self.t1 = name, t0, t1
        self.attrs, self.thread = attrs, thread

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        return dict(trace_id=self.trace_id, span_id=self.span_id,
                    parent_id=self.parent_id, name=self.name,
                    t0=round(self.t0, 6), t1=round(self.t1, 6),
                    duration_s=round(self.duration_s, 6),
                    thread=self.thread,
                    attrs={k: (v if isinstance(v, (int, float, bool,
                                                   type(None))) else str(v))
                           for k, v in (self.attrs or {}).items()})

    def __repr__(self) -> str:       # pragma: no cover - debugging aid
        return (f"SpanRecord({self.name!r}, trace={self.trace_id}, "
                f"span={self.span_id}, parent={self.parent_id}, "
                f"dur={self.duration_s * 1e3:.3f}ms)")


class _NullSpan:
    """Shared no-op span: the entire cost of disabled tracing at a call
    site is one method call returning this singleton."""

    __slots__ = ()
    context = None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """A live span (context manager).  Created by :meth:`Tracer.span`;
    the record is appended to the ring on ``__exit__`` — which the
    ``with`` statement guarantees, so every started span closes."""

    __slots__ = ("_tracer", "name", "attrs", "trace_id", "span_id",
                 "parent_id", "t0", "t1", "_pushed")

    def __init__(self, tracer: "Tracer", name: str,
                 parent: Optional[SpanContext], attrs: dict):
        self._tracer = tracer
        self.name, self.attrs = name, attrs
        self.span_id = next(_ids)
        if parent is not None:
            self.trace_id, self.parent_id = parent.trace_id, parent.span_id
        else:
            top = tracer._current()
            if top is not None:
                self.trace_id, self.parent_id = top.trace_id, top.span_id
            else:
                self.trace_id, self.parent_id = next(_trace_ids), None
        self.t0 = self.t1 = 0.0
        self._pushed = False

    @property
    def context(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    def set(self, **attrs) -> None:
        """Attach attributes after the fact (e.g. the straggler shard is
        only known once the merge finished)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self._pushed = True
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = time.perf_counter()
        if self._pushed:
            self._tracer._pop(self)
        self._tracer._append(SpanRecord(
            self.trace_id, self.span_id, self.parent_id, self.name,
            self.t0, self.t1, self.attrs or None,
            threading.current_thread().name))
        return False


class NullTracer:
    """The off switch: every operation is a no-op returning a shared
    object.  ``enabled`` lets the hottest call sites (cache gets) skip
    even the argument packing."""

    enabled = False
    slow: Optional[SlowQueryLog] = None

    def span(self, name: str, parent: Optional[SpanContext] = None,
             **attrs) -> _NullSpan:
        return NULL_SPAN

    def event(self, name: str, parent: Optional[SpanContext] = None,
              **attrs) -> None:
        return None

    def record(self, name: str, t0: float, t1: float,
               parent: Optional[SpanContext] = None,
               **attrs) -> Optional[SpanContext]:
        return None

    def records(self) -> List[SpanRecord]:
        return []

    def traces(self) -> Dict[int, List[SpanRecord]]:
        return {}

    def trees(self) -> List[dict]:
        return []

    def clear(self) -> None:
        return None

    def snapshot(self) -> dict:
        return dict(enabled=False, recorded=0, resident=0, dropped=0)


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Ring-buffered span recorder.

    Args:
        capacity: ring size in spans; the oldest fall off (``dropped``
            counts them).
        slow_threshold_s: end-to-end latency above which a query lands in
            the slow-query log (``None`` keeps the log but disables
            automatic offers from the serve layer's e2e observation
            points).
        slow_k: slow-query log size (top-K by duration).

    Usage::

        tracer = Tracer()
        with tracer.span("work", queries=8):
            ...
        assert tracer.records()[-1].name == "work"
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 slow_threshold_s: Optional[float] = 0.05,
                 slow_k: int = 32):
        self.capacity = capacity
        self._ring: "deque[SpanRecord]" = deque(maxlen=capacity)
        self._local = threading.local()
        self.recorded = 0              # total appends (ring may have fewer)
        self.slow = SlowQueryLog(threshold_s=slow_threshold_s, top_k=slow_k)

    # -- recording ----------------------------------------------------------
    def span(self, name: str, parent: Optional[SpanContext] = None,
             **attrs) -> Span:
        """A live span context manager.  ``parent=None`` nests under the
        current thread's innermost open span (or starts a new trace);
        pass an explicit :class:`SpanContext` to link across threads."""
        return Span(self, name, parent, attrs)

    def event(self, name: str, parent: Optional[SpanContext] = None,
              **attrs) -> None:
        """A zero-duration span — cache hits/misses/evictions, flush
        triggers: things that happen *at* a time rather than *over* one."""
        now = time.perf_counter()
        self.record(name, now, now, parent=parent, **attrs)

    def record(self, name: str, t0: float, t1: float,
               parent: Optional[SpanContext] = None,
               **attrs) -> SpanContext:
        """Retroactive span from timestamps captured earlier (queue
        residency is only known at drain time).  Returns the new span's
        context so children can parent on it."""
        if parent is not None:
            trace_id, parent_id = parent.trace_id, parent.span_id
        else:
            top = self._current()
            if top is not None:
                trace_id, parent_id = top.trace_id, top.span_id
            else:
                trace_id, parent_id = next(_trace_ids), None
        span_id = next(_ids)
        self._append(SpanRecord(trace_id, span_id, parent_id, name, t0, t1,
                                attrs or None,
                                threading.current_thread().name))
        return SpanContext(trace_id, span_id)

    def _append(self, rec: SpanRecord) -> None:
        self._ring.append(rec)         # deque append: atomic, no lock
        self.recorded += 1

    # -- implicit same-thread nesting ---------------------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _current(self) -> Optional[Span]:
        st = getattr(self._local, "stack", None)
        return st[-1] if st else None

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        elif span in st:               # tolerate exotic exit orders
            st.remove(span)

    # -- analysis -----------------------------------------------------------
    def records(self) -> List[SpanRecord]:
        """Snapshot of the resident spans, oldest first."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self.recorded = 0
        self.slow.clear()

    def traces(self) -> Dict[int, List[SpanRecord]]:
        """Resident spans grouped by trace id (each list sorted by t0)."""
        out: Dict[int, List[SpanRecord]] = {}
        for rec in self.records():
            out.setdefault(rec.trace_id, []).append(rec)
        for recs in out.values():
            recs.sort(key=lambda r: (r.t0, r.span_id))
        return out

    def trees(self) -> List[dict]:
        """Per-trace nested span trees (see :func:`build_trees`)."""
        return build_trees(self.records())

    def snapshot(self) -> dict:
        """JSON-able tracer health: ring occupancy + slow-query log."""
        resident = len(self._ring)
        return dict(enabled=True, capacity=self.capacity,
                    recorded=self.recorded, resident=resident,
                    dropped=self.recorded - resident,
                    traces=len({r.trace_id for r in self._ring}),
                    slow_queries=self.slow.as_dicts())


def build_trees(records: Sequence[SpanRecord]) -> List[dict]:
    """Nest span records into per-trace trees.

    Args:
        records: any iterable of :class:`SpanRecord` (ring snapshot).

    Returns:
        One dict per trace — ``{"trace_id", "spans", "roots": [...]}``
        where each node is the span's :meth:`~SpanRecord.as_dict` plus a
        ``children`` list (sorted by ``t0``).  A span whose parent fell
        off the ring is promoted to a root (the tree stays complete).

    Usage::

        trees = build_trees(tracer.records())
    """
    by_trace: Dict[int, List[SpanRecord]] = {}
    for rec in records:
        by_trace.setdefault(rec.trace_id, []).append(rec)
    out: List[dict] = []
    for trace_id in sorted(by_trace):
        recs = by_trace[trace_id]
        nodes = {r.span_id: dict(r.as_dict(), children=[]) for r in recs}
        roots: List[dict] = []
        for r in sorted(recs, key=lambda r: (r.t0, r.span_id)):
            node = nodes[r.span_id]
            parent = nodes.get(r.parent_id) if r.parent_id else None
            (parent["children"] if parent is not None else roots).append(node)
        out.append(dict(trace_id=trace_id, spans=len(recs), roots=roots))
    return out


def default_tracer() -> NullTracer:
    """The process-default tracer, resolved from ``REPRO_TRACE``:

    * unset / ``""`` / ``"0"`` → :data:`NULL_TRACER` (free);
    * an integer > 1 → a :class:`Tracer` with that ring capacity;
    * anything else truthy → a :class:`Tracer` with the default capacity.

    ``REPRO_TRACE_SLOW_MS`` sets the slow-query threshold (default 50).

    Usage::

        svc = CountingService(engine)          # tracer=default_tracer()
    """
    raw = os.environ.get("REPRO_TRACE", "").strip()
    if raw in ("", "0"):
        return NULL_TRACER
    slow_ms = float(os.environ.get("REPRO_TRACE_SLOW_MS", "50") or 50)
    capacity = int(raw) if raw.isdigit() and int(raw) > 1 else 65536
    return Tracer(capacity=capacity, slow_threshold_s=slow_ms / 1e3)
