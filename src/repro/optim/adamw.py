"""Optimizers built from scratch (no optax in this environment).

AdamW with configurable moment dtype (bf16 moments keep the 340B/480B cells
inside v5e HBM; the update math runs in f32) and Adafactor for
memory-starved deployments.  Global-norm clipping and warmup-cosine schedule
included.  Optimizer state inherits the parameter sharding (ZeRO-style:
states live wherever the param shard lives).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"
    kind: str = "adamw"            # adamw | adafactor


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def _decay_mask(path) -> bool:
    """Apply weight decay only to matrices (not norms/biases/scalars)."""
    name = ""
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = str(entry.key)
            break
        if hasattr(entry, "name"):
            name = str(entry.name)
            break
    return not any(t in name for t in ("norm", "mu_", "bias", "b_", "ln_",
                                       "a_log", "d_skip", "decay_base", "u_bonus"))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


class AdamW:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params) -> Dict[str, Any]:
        dt = jnp.dtype(self.cfg.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        cfg = self.cfg
        step = state["step"] + 1
        lr = schedule(cfg, step)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        b1, b2 = cfg.betas
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        sdt = jnp.dtype(cfg.state_dtype)

        def upd(path, p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            upd32 = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
            if _decay_mask(path):
                upd32 = upd32 + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd32).astype(p.dtype)
            return {"p": new_p, "m": m32.astype(sdt), "v": v32.astype(sdt)}

        out = jax.tree_util.tree_map_with_path(
            upd, params, grads, state["m"], state["v"])
        is_cell = lambda t: isinstance(t, dict) and set(t) == {"p", "m", "v"}
        new_params = jax.tree.map(lambda t: t["p"], out, is_leaf=is_cell)
        new_m = jax.tree.map(lambda t: t["m"], out, is_leaf=is_cell)
        new_v = jax.tree.map(lambda t: t["v"], out, is_leaf=is_cell)
        return new_params, {"m": new_m, "v": new_v, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}


class Adafactor:
    """Factored second moment (row/col) — O(n+m) state for matrices."""

    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params):
        def factored(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(factored, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        cfg = self.cfg
        step = state["step"] + 1
        lr = schedule(cfg, step)
        d = 1.0 - 0.8 ** step.astype(jnp.float32)   # beta2 ramp

        def upd(p, g, f):
            g32 = g.astype(jnp.float32)
            sq = g32 * g32 + 1e-30
            if p.ndim >= 2:
                r = d * f["r"] + (1 - d) * jnp.mean(sq, axis=-1)
                c = d * f["c"] + (1 - d) * jnp.mean(sq, axis=-2)
                denom = jnp.sqrt(r[..., None] * c[..., None, :]
                                 / jnp.maximum(jnp.mean(r, -1, keepdims=True)[..., None], 1e-30))
                newf = {"r": r, "c": c}
            else:
                v = d * f["v"] + (1 - d) * sq
                denom = jnp.sqrt(v)
                newf = {"v": v}
            upd32 = g32 / jnp.maximum(denom, 1e-30)
            # relative update clipping
            rms = jnp.sqrt(jnp.mean(upd32 * upd32) + 1e-30)
            upd32 = upd32 / jnp.maximum(1.0, rms)
            new_p = (p.astype(jnp.float32) - lr * upd32).astype(p.dtype)
            return {"p": new_p, "f2": newf}

        out = jax.tree.map(upd, params, grads, state["f"])
        is_cell = lambda t: isinstance(t, dict) and set(t) == {"p", "f2"}
        new_params = jax.tree.map(lambda t: t["p"], out, is_leaf=is_cell)
        new_f = jax.tree.map(lambda t: t["f2"], out, is_leaf=is_cell)
        return new_params, {"f": new_f, "step": step}, {"lr": lr}


def make_optimizer(cfg: OptConfig):
    return Adafactor(cfg) if cfg.kind == "adafactor" else AdamW(cfg)
