"""Int8 gradient compression with error feedback.

For the cross-pod (DCN) reduction axis: gradients are quantised to int8 with
a per-tensor scale before the all-reduce and dequantised after; the
quantisation residual is carried in an error-feedback buffer and added back
the next step, which keeps SGD-style convergence (Seide et al., 1-bit SGD
lineage).  8x less DCN traffic on the pod axis for ~0 quality cost.

Used as the ``compress`` hook of ``make_train_step``: it transforms the
gradient pytree (and threads its buffer through the train state under
``"ef"``).  The quantise/dequantise pair is placed around the values the
psum sees — under SPMD the all-reduce then moves int8.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def _quant(g32: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressor():
    """Returns compress(grads, state) -> (grads', state') for make_train_step."""

    def compress(grads, state):
        ef = state.get("ef")
        if ef is None:
            ef = init_error_feedback(grads)

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, scale = _quant(g32)
            deq = _dequant(q, scale)
            return deq.astype(g.dtype), (g32 - deq)

        out = jax.tree.map(lambda g, e: one(g, e), grads, ef)
        is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
        new_g = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_g, {**state, "ef": new_ef}

    return compress


def compression_ratio_bits() -> float:
    return 32.0 / 8.0
