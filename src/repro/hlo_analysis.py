"""Trip-count-aware static analysis of optimized (post-SPMD) HLO.

XLA's ``cost_analysis`` visits each ``while`` body **once**, so for
scan-over-layers models it undercounts FLOPs/bytes/collectives by the layer
count.  This module re-derives the three roofline inputs from the HLO text
with loop multipliers:

* computations are parsed into per-op records with a local symbol table
  (every %name's shape is known at its definition);
* FLOPs: ``dot`` ops -> 2 x |output| x |contracting dims| (from the printed
  ``lhs_contracting_dims`` and the lhs operand's shape);
* HBM bytes: operand+output bytes of every materialising op at fusion
  boundaries (ops inside ``fused_computation``s are not double counted);
* collective link bytes: output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute with standard per-chip
  link factors (all-reduce = 2x);
* call graph: fusion/call/while/conditional/sort edges; a while's trip count
  is the max integer constant found in its condition computation (falling
  back to constants in its init tuple) — exactly the bound jax's
  ``lax.scan`` lowers to.

Shapes in the per-device SPMD module are per-chip, so all results are
per-chip quantities.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
    "s1": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_LINK_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_NO_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call", "custom-call",
                 "after-all", "partition-id", "replica-id"}

# Ops that materialise buffers on TPU (fusion boundaries).  The CPU backend
# fuses far less than TPU, so counting operand+output bytes of *every* op
# would overstate HBM traffic several-fold; elementwise/convert/compare ops
# are assumed fused into these anchors (documented in EXPERIMENTS.md).
_BYTES_OPS = {"dot", "convolution", "fusion", "reduce", "reduce-window",
              "scatter", "gather", "sort", "transpose", "copy", "concatenate",
              "dynamic-slice", "dynamic-update-slice", "select-and-scatter",
              "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute", "all-gather-start", "all-reduce-start",
              "pad", "reverse", "cholesky", "triangular-solve", "fft",
              "rng", "rng-bit-generator", "iota"}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _shape_elems(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class OpRec:
    name: str
    kind: str
    out_bytes: int
    operand_names: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: Dict[str, OpRec] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    flops: float = 0.0
    bytes_hbm: float = 0.0
    coll_link_bytes: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)
    calls: List[Tuple[str, str]] = field(default_factory=list)  # (kind, callee)
    while_info: List[Tuple[str, str, str]] = field(default_factory=list)
    int_constants: List[int] = field(default_factory=list)
    has_slice: bool = False       # body contains dynamic-slice / gather
    has_dus: bool = False         # body contains dynamic-update-slice
    pending_bytes: List[Tuple[str, int, List[int], Optional[str]]] = \
        field(default_factory=list)   # (kind, out_bytes, operand_bytes, callee)


def _first_shape(text: str) -> Optional[Tuple[str, str]]:
    m = _SHAPE_RE.search(text)
    return (m.group(1), m.group(2)) if m else None


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        is_def = _DEF_RE.match(s) is not None
        header = (re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", s)
                  if (s.endswith("{") and not is_def) else None)
        if header and cur is None:
            cur = Computation(header.group(2))
            comps[cur.name] = cur
            if header.group(1):
                comps["__entry__"] = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # output shape(s) = everything before the op token; op token = first
        # lowercase identifier directly followed by '('
        opm = re.match(r"^(\(?.*?)\s([a-z][\w\-]*)\(", rhs)
        kind = opm.group(2) if opm else ""
        head = opm.group(1) if opm else rhs
        out_bytes = _shapes_bytes(head)
        # operand names
        args_m = re.search(rf"{re.escape(kind)}\((.*?)\)(,|$)", rhs) if kind else None
        operands = []
        if args_m:
            operands = re.findall(r"%([\w.\-]+)", args_m.group(1))
        rec = OpRec(name, kind, out_bytes, operands, s)
        cur.ops[name] = rec
        cur.order.append(name)
        if kind in ("dynamic-slice", "gather"):
            cur.has_slice = True
        if kind == "dynamic-update-slice":
            cur.has_dus = True

        if kind == "constant":
            cm = re.search(r"constant\((\d+)\)", rhs)
            if cm and ("s32[]" in head or "u32[]" in head):
                cur.int_constants.append(int(cm.group(1)))

        # ---- flops: dot ----
        if kind == "dot":
            lhs_dims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            out_sh = _first_shape(head)
            lhs_name = operands[0] if operands else None
            lhs_rec = cur.ops.get(lhs_name) if lhs_name else None
            contract = 1
            if lhs_dims and lhs_rec:
                lsh = _first_shape(lhs_rec.line.split("=", 1)[1])
                if lsh and lhs_dims.group(1):
                    ldims = lsh[1].split(",") if lsh[1] else []
                    for di in lhs_dims.group(1).split(","):
                        if di and int(di) < len(ldims):
                            contract *= int(ldims[int(di)])
            if out_sh:
                cur.flops += 2.0 * _shape_elems(*out_sh) * contract

        # ---- collectives ----
        for ck in _COLLECTIVES:
            if kind in (ck, ck + "-start"):
                nb = out_bytes
                cur.coll_link_bytes += nb * _LINK_FACTOR[ck]
                cur.coll_counts[ck] = cur.coll_counts.get(ck, 0) + 1
                break

        # ---- call edges ----
        if kind == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", rhs)
            if cm:
                cur.calls.append(("fusion", cm.group(1)))
        elif kind == "call":
            cm = re.search(r"to_apply=%?([\w.\-]+)", rhs)
            if cm:
                cur.calls.append(("call", cm.group(1)))
        elif kind == "while":
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            init = operands[0] if operands else ""
            if bm and cm:
                cur.while_info.append((bm.group(1), cm.group(1), init))
        elif kind == "conditional":
            for cm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=%?([\w.\-]+))", rhs):
                blob = cm.group(1) or cm.group(2) or ""
                for nm in re.findall(r"%?([\w.\-]+)", blob):
                    if nm:
                        cur.calls.append(("cond", nm))
        elif kind == "sort":
            cm = re.search(r"to_apply=%?([\w.\-]+)", rhs)
            if cm:
                cur.calls.append(("sort", cm.group(1)))

        # ---- hbm bytes: defer to a second pass (fusion bodies may appear
        # later in the text; slice/dus-aware accounting needs them) ----
        if kind in _BYTES_OPS:
            opsz = []
            for on in operands:
                orc = cur.ops.get(on)
                if orc is not None:
                    m2 = re.match(r"^(\(?.*?)\s[a-z][\w\-]*\(",
                                  orc.line.split("=", 1)[1].strip())
                    ohead = m2.group(1) if m2 else orc.line.split("=", 1)[1]
                    opsz.append(_shapes_bytes(ohead))
            callee = None
            if kind == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", rhs)
                callee = cm.group(1) if cm else None
            if kind == "fusion" and "dynamic-update-slice" in name:
                callee = (callee or "") + ".dynamic-update-slice"
            cur.pending_bytes.append((kind, out_bytes, opsz, callee))

    _resolve_bytes(comps)
    return comps


def _resolve_bytes(comps: Dict[str, Computation]) -> None:
    """Second pass: charge HBM traffic per op with slice/in-place awareness.

    * dynamic-update-slice (incl. fusions rooted on one): the big aliased
      buffer is updated in place — traffic = 2 x slice bytes.
    * dynamic-slice / gather (incl. fusions containing one): a large operand
      is only *read at slice granularity* — cap each operand at the fusion's
      output size.  Without this, reading one layer's [B,S,D] activation
      slice from a [L,B,S,D] residual stack is billed L times too much.
    """
    for c in comps.values():
        if c.name == "__entry__":
            continue
        for kind, out_bytes, opsz, callee in c.pending_bytes:
            body = comps.get((callee or "").removesuffix(".dynamic-update-slice")) \
                if callee else None
            is_dus = (kind == "dynamic-update-slice"
                      or (kind == "fusion"
                          and (("dynamic-update-slice" in (callee or ""))
                               or (body is not None and body.has_dus))))
            slice_like = (kind in ("dynamic-slice", "gather")
                          or (body is not None and body.has_slice))
            if is_dus and opsz:
                c.bytes_hbm += 2 * (sum(opsz) - max(opsz))
            elif kind == "dynamic-slice":
                c.bytes_hbm += 2 * out_bytes
            elif slice_like and opsz:
                capped = [min(o, max(out_bytes, 1)) for o in opsz]
                c.bytes_hbm += out_bytes + sum(capped)
            else:
                c.bytes_hbm += out_bytes + sum(opsz)


def _trip_count(comp: Computation, body: str, cond: str, init: str,
                comps: Dict[str, Computation]) -> int:
    cond_comp = comps.get(cond)
    cands: List[int] = []
    if cond_comp is not None:
        cands += [c for c in cond_comp.int_constants if c > 0]
        # conditions may call helper comparators — look one level deep
        for _, callee in cond_comp.calls:
            sub = comps.get(callee)
            if sub:
                cands += [c for c in sub.int_constants if c > 0]
    if not cands:
        init_rec = comp.ops.get(init)
        if init_rec is not None:
            for on in init_rec.operand_names:
                orc = comp.ops.get(on)
                if orc is not None and orc.kind == "constant":
                    cm = re.search(r"constant\((\d+)\)", orc.line)
                    if cm and int(cm.group(1)) > 0:
                        cands.append(int(cm.group(1)))
    return max(cands) if cands else 1


def analyze(text: str) -> Dict[str, float]:
    comps = parse_hlo(text)
    entry = comps.pop("__entry__", None)
    if entry is not None:
        roots = [entry]
    else:
        # fallback: computations never called by others
        called = {callee for c in comps.values() for _, callee in c.calls}
        called |= {b for c in comps.values() for b, cnd, _ in c.while_info}
        called |= {cnd for c in comps.values() for b, cnd, _ in c.while_info}
        roots = [c for c in comps.values() if c.name not in called]
    totals = {"flops": 0.0, "bytes": 0.0, "coll_link_bytes": 0.0}
    counts: Dict[str, int] = {}
    fused: Dict[str, bool] = {}

    import functools

    @functools.lru_cache(maxsize=None)
    def cost(name: str, in_fusion: bool) -> Tuple[float, float, float]:
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0)
        f = c.flops
        b = 0.0 if in_fusion else c.bytes_hbm
        cl = c.coll_link_bytes
        for kind, callee in c.calls:
            cf, cb, ccl = cost(callee, in_fusion or kind == "fusion")
            f += cf
            b += cb
            cl += ccl
        for body, cond, init in c.while_info:
            t = _trip_count(c, body, cond, init, comps)
            bf, bb, bcl = cost(body, in_fusion)
            f += t * bf
            b += t * bb
            cl += t * bcl
        return (f, b, cl)

    for r in roots:
        f, b, cl = cost(r.name, False)
        totals["flops"] += f
        totals["bytes"] += b
        totals["coll_link_bytes"] += cl

    # collective op counts (with multipliers is overkill — report static)
    for c in comps.values():
        for k, v in c.coll_counts.items():
            counts[k] = counts.get(k, 0) + v
    totals["collective_op_sites"] = counts
    return totals
