"""CountingService: a query-serving front-end for the counting engine.

FACTORBASE answers instantiation counts as database *queries*; this module
treats them the same way at scale.  A :class:`CountingService` accepts many
concurrent positive-count queries — from one structure search, several
searches sharing a database, or external clients on their own threads —
and executes them in **signature-bucketed micro-batches** against one
shared byte-budgeted :class:`~repro.core.cache.CtCache`:

* ``submit(point, keep)`` returns a :class:`CountTicket` immediately.
  Queries already resident in the cache short-circuit without queueing;
  identical in-flight queries are coalesced onto one pending entry.
* Pending queries are bucketed by
  :meth:`~repro.core.plan.ContractionPlan.shape_signature`.  A bucket is
  dispatched when it reaches ``max_batch_size``, when the oldest pending
  query exceeds ``max_wait_s``, when backpressure demands it, or when a
  caller blocks on a ticket — whichever comes first.
* Dispatch goes through :func:`~repro.serve.batching.execute_bucketed`,
  which stacks structurally identical plans into single vmapped
  contractions (:meth:`~repro.core.executors.Executor.positive_batch`).
* **Backpressure**: the queue is bounded by ``max_in_flight`` queries and
  by the estimated bytes of pending results (default: the cache budget);
  exceeding either limit drains the queue instead of growing it.

Locking: the queue lock only guards scheduler state — triggered batches
execute *after* it is released, so submits keep flowing while a batch
runs; one execution lock serialises engine/cache mutation across client
threads (the cache itself is also lock-guarded for its other users).

Results land in the engine's cache under the same keys the on-demand
positive policy uses, so a structure search sharing the engine is served
directly from the warmed cache; :meth:`CountingService.prefetch` runs the
same machinery for an explicit policy (see
:meth:`repro.core.strategies.Strategy.family_ct_many`).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.ct import CtTable
from ..core.engine import CountingEngine
from ..core.plan import ContractionPlan
from ..core.variables import CtVar, LatticePoint
from .batching import execute_bucketed
from .metrics import ServiceMetrics

Sink = Callable[[LatticePoint, Tuple[CtVar, ...], CtTable], None]


class _Pending:
    """One in-flight query: a compiled plan plus everyone waiting on it."""

    __slots__ = ("point", "keep", "plan", "sig", "sinks", "cache_result",
                 "enqueued_at", "event", "result", "error")

    def __init__(self, point: LatticePoint, keep: Tuple[CtVar, ...],
                 plan: ContractionPlan):
        self.point, self.keep, self.plan = point, keep, plan
        self.sig = plan.shape_signature()
        self.sinks: List[Sink] = []
        self.cache_result = False      # a sink-less client wants it cached
        self.enqueued_at = time.perf_counter()
        self.event = threading.Event()
        self.result: Optional[CtTable] = None
        self.error: Optional[BaseException] = None


class CountTicket:
    """Handle for a submitted query; ``result()`` blocks (flushing the
    service if needed) until the count table is available.

    Usage::

        ticket = service.submit(point)
        tab = ticket.result(timeout=30.0)
    """

    def __init__(self, service: "CountingService",
                 entry: Optional[_Pending] = None,
                 result: Optional[CtTable] = None):
        self._service = service
        self._entry = entry
        self._result = result

    @property
    def done(self) -> bool:
        return self._result is not None or (
            self._entry is not None and self._entry.event.is_set())

    def result(self, timeout: Optional[float] = None) -> CtTable:
        """The count table for this query.

        Args:
            timeout: seconds to wait after flushing (None = forever).

        Returns:
            The positive :class:`~repro.core.ct.CtTable` over the query's
            ``keep`` axes.

        Raises:
            TimeoutError: the query did not complete within ``timeout``.
            BaseException: whatever the executing batch raised — every
                waiter of a failed batch sees the same exception.
        """
        if self._result is not None:
            return self._result
        assert self._entry is not None
        if not self._entry.event.is_set():
            self._service.flush()          # our entry may ride this drain …
            if not self._entry.event.wait(timeout):   # … or a concurrent one
                raise TimeoutError("count query did not complete in time")
        if self._entry.error is not None:  # execution failed: every waiter
            raise self._entry.error        # sees the batch's exception
        self._result = self._entry.result
        return self._result


class CountingService:
    """Signature-bucketed micro-batching scheduler over a
    :class:`~repro.core.engine.CountingEngine`.

    Args:
        engine: the planner/executor/cache stack to execute against.
        max_batch_size: dispatch a signature bucket at this many queries.
        max_wait_s: dispatch everything once the oldest pending query is
            this stale (checked on submit; ``None`` disables the trigger).
        max_in_flight: backpressure — force a full drain beyond this many
            pending queries.
        max_pending_bytes: backpressure — force a full drain beyond this
            many estimated result bytes pending (defaults to the engine's
            cache budget).
        metrics: counters sink; defaults to a fresh
            :class:`~repro.serve.metrics.ServiceMetrics`.

    Raises:
        ValueError: ``max_batch_size < 1``.

    Usage::

        svc = CountingService(CountingEngine(db, "sparse"), max_batch_size=32)
        tab = svc.count(point)
    """

    def __init__(self, engine: CountingEngine,
                 max_batch_size: int = 64,
                 max_wait_s: Optional[float] = None,
                 max_in_flight: int = 1024,
                 max_pending_bytes: Optional[int] = None,
                 metrics: Optional[ServiceMetrics] = None):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        self.engine = engine
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self.max_in_flight = max_in_flight
        self.max_pending_bytes = (max_pending_bytes if max_pending_bytes
                                  is not None else engine.cache.budget_bytes)
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self._lock = threading.RLock()         # queue state
        self._exec_lock = threading.Lock()     # execution + cache writes
        self._pending: Dict[Tuple, _Pending] = {}
        self._by_sig: Dict[Tuple, List[Tuple]] = {}   # sig -> [req_key]
        self._pending_bytes = 0

    # -- client API ---------------------------------------------------------
    def submit(self, point: LatticePoint,
               keep: Optional[Sequence[CtVar]] = None,
               sink: Optional[Sink] = None) -> CountTicket:
        """Enqueue one positive-count query; returns immediately.

        With no ``sink`` the result is cached under the engine's on-demand
        positive key (and cache-resident queries short-circuit here); a
        ``sink(point, keep, tab)`` callback routes the result elsewhere
        (e.g. a strategy policy's absorb hook).

        Args:
            point: lattice point to count (>= 1 relationship atom).
            keep: ct-table axes; defaults to every entity/edge attribute
                of the point.
            sink: optional result callback, called during batch execution.

        Returns:
            A :class:`CountTicket` (already ``done`` on a cache hit).

        Usage::

            ticket = svc.submit(point, keep)
        """
        plan = self.engine.plan(point, keep)
        keep_t = plan.keep
        to_execute: List[_Pending] = []
        with self._lock:
            self.metrics.requests += 1
            if sink is None:
                hit = self.engine.cache.get(self._cache_key(point, keep_t))
                if hit is not None:
                    self.metrics.cache_hits += 1
                    return CountTicket(self, result=hit)
            req_key = (point.atoms, keep_t)
            entry = self._pending.get(req_key)
            if entry is not None:
                if sink is not None:
                    entry.sinks.append(sink)
                else:
                    entry.cache_result = True
                self.metrics.coalesced += 1
                return CountTicket(self, entry=entry)
            entry = _Pending(point, keep_t, plan)
            entry.cache_result = sink is None
            if sink is not None:
                entry.sinks.append(sink)
            self._pending[req_key] = entry
            self._by_sig.setdefault(entry.sig, []).append(req_key)
            self._pending_bytes += self._estimate_bytes(plan)
            self.metrics.enqueued += 1
            ticket = CountTicket(self, entry=entry)
            to_execute = self._drain_triggered(entry)
        if to_execute:       # run OUTSIDE the lock: submits keep flowing
            self._execute(to_execute)
        return ticket

    def count(self, point: LatticePoint,
              keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous convenience: :meth:`submit` + blocking ``result()``.

        Usage::

            tab = svc.count(point)
        """
        return self.submit(point, keep).result()

    def count_many(self, queries: Sequence[Tuple[LatticePoint,
                                                 Optional[Sequence[CtVar]]]]
                   ) -> List[CtTable]:
        """Submit a whole query list, dispatch it bucketed, return results
        in submission order — the natural API for a client that has its
        round's frontier in hand.

        Args:
            queries: ``(point, keep)`` pairs (``keep=None`` = all axes).

        Returns:
            One :class:`~repro.core.ct.CtTable` per query, positionally
            aligned with ``queries``.

        Usage::

            tabs = svc.count_many([(p, None) for p in lattice])
        """
        tickets = [self.submit(point, keep) for point, keep in queries]
        self.flush()
        return [t.result() for t in tickets]

    def prefetch(self, policy, queries: Sequence[Tuple[LatticePoint,
                                                       Tuple[CtVar, ...]]]
                 ) -> int:
        """Batch-warm a positive policy's cache: ask the policy which of
        ``queries`` it would have to contract from data
        (:meth:`~repro.core.engine._Policy.batchable_misses`), execute those
        in signature buckets, and hand each result back through the
        policy's absorb hook.

        Args:
            policy: a positive policy from :mod:`repro.core.engine`
                (``batchable_misses``/``absorb`` protocol).
            queries: the ``(point, keep)`` positive sub-queries about to
                be issued (see :func:`repro.core.mobius.positive_queries`).

        Returns:
            The number of queries actually executed (cache misses).

        Usage::

            n = svc.prefetch(strategy.provider, positive_queries(point, keep))
        """
        todo = policy.batchable_misses(list(queries))
        if not todo:
            return 0
        for point, keep in todo:
            self.submit(point, keep, sink=policy.absorb)
        self.flush()
        return len(todo)

    # -- scheduler ----------------------------------------------------------
    def flush(self) -> None:
        """Drain and execute every pending query."""
        with self._lock:
            entries = self._drain_all()
        if entries:
            self._execute(entries)

    def pending(self) -> int:
        """Number of queries currently queued (not yet dispatched)."""
        with self._lock:
            return len(self._pending)

    def _drain_all(self) -> List[_Pending]:
        """Take the whole queue (lock held)."""
        entries = list(self._pending.values())
        self._pending.clear()
        self._by_sig.clear()
        self._pending_bytes = 0
        if entries:
            self.metrics.flushes += 1
        return entries

    def _drain_bucket(self, sig: Tuple) -> List[_Pending]:
        """Take one signature bucket (lock held)."""
        keys = self._by_sig.pop(sig, [])
        entries = [self._pending.pop(k) for k in keys]
        self._pending_bytes -= sum(self._estimate_bytes(e.plan)
                                   for e in entries)
        if entries:
            self.metrics.flushes += 1
        return entries

    def _drain_triggered(self, entry: _Pending) -> List[_Pending]:
        """Apply the dispatch triggers after admitting ``entry`` (lock
        held); returns whatever must now execute."""
        over_count = len(self._pending) > self.max_in_flight
        over_bytes = (self.max_pending_bytes is not None
                      and self._pending_bytes > self.max_pending_bytes
                      and len(self._pending) > 1)
        if over_count or over_bytes:
            self.metrics.backpressure_flushes += 1
            return self._drain_all()
        if len(self._by_sig.get(entry.sig, ())) >= self.max_batch_size:
            self.metrics.size_flushes += 1
            return self._drain_bucket(entry.sig)
        if self.max_wait_s is not None:
            oldest = min(e.enqueued_at for e in self._pending.values())
            if time.perf_counter() - oldest >= self.max_wait_s:
                self.metrics.wait_flushes += 1
                return self._drain_all()
        return []

    def _execute(self, entries: List[_Pending]) -> None:
        # one batch executes at a time: the exec lock serialises engine
        # stats bumps, metrics, cache writes and sink callbacks across
        # client threads (the queue lock is NOT held here).  Entries are
        # already out of the queue, so every event MUST be set even on
        # failure — a waiter left unsignalled would hang forever.
        eng = self.engine
        try:
            with self._exec_lock:
                now = time.perf_counter()
                for e in entries:
                    self.metrics.observe_wait(now - e.enqueued_at)
                with eng.stats.timer("positive"):
                    tabs = execute_bucketed(
                        eng.executor, eng.db, [e.plan for e in entries],
                        eng.stats, max_batch_size=self.max_batch_size,
                        metrics=self.metrics)
                for e, tab in zip(entries, tabs):
                    for sink in e.sinks:
                        sink(e.point, e.keep, tab)
                    if e.cache_result or not e.sinks:
                        key = self._cache_key(e.point, e.keep)
                        eng.count_rows_once(key, tab)
                        eng.cache.put(key, tab)
                    e.result = tab
        except BaseException as err:
            for e in entries:
                if e.result is None and e.error is None:
                    e.error = err          # propagate to every waiter
            raise
        finally:
            for e in entries:
                e.event.set()

    # -- bookkeeping --------------------------------------------------------
    def _cache_key(self, point: LatticePoint,
                   keep: Tuple[CtVar, ...]) -> Tuple:
        # same namespace as OnDemandPositives: a search sharing this engine
        # is served straight from the warmed cache
        return ("pos", self.engine.executor.name, point.atoms, tuple(keep))

    def _estimate_bytes(self, plan: ContractionPlan) -> int:
        itemsize = np.dtype(self.engine.dtype).itemsize
        return int(np.prod(plan.out_shape, dtype=np.int64)) * itemsize

    def stats(self) -> dict:
        """Service + cache health snapshot (JSON-able; see
        :meth:`~repro.serve.metrics.ServiceMetrics.snapshot`).

        Usage::

            print(svc.stats()["qps"], svc.stats()["cache"]["hits"])
        """
        return self.metrics.snapshot(self.engine.cache)
