"""CountingService: a query-serving front-end for the counting engine.

FACTORBASE answers instantiation counts as database *queries*; this module
treats them the same way at scale.  A :class:`CountingService` accepts many
concurrent positive-count queries — from one structure search, several
searches sharing a database, or external clients on their own threads —
and executes them in **signature-bucketed micro-batches** against one
shared byte-budgeted :class:`~repro.core.cache.CtCache`:

* ``submit(point, keep)`` returns a :class:`CountTicket` immediately.
  Queries already resident in the cache short-circuit without queueing;
  identical in-flight queries are coalesced onto one pending entry.
* ``submit_complete(point, keep)`` queues a **complete-CT** query
  (positive + Möbius negative phase, ``keep`` may include relationship
  indicator axes).  Complete queries ride the same scheduler; dispatch
  batches their positive sub-queries in signature buckets AND their
  negative-phase butterfly transforms in same-shape groups
  (:func:`~repro.serve.batching.execute_complete_bucketed`).
* Pending queries are bucketed by
  :meth:`~repro.core.plan.ContractionPlan.shape_signature`.  A bucket is
  dispatched when it reaches ``max_batch_size``, when the oldest pending
  query exceeds ``max_wait_s``, when backpressure demands it, or when a
  caller blocks on a ticket — whichever comes first.
* Dispatch goes through :func:`~repro.serve.batching.execute_bucketed`,
  which stacks structurally identical plans into single vmapped
  contractions (:meth:`~repro.core.executors.Executor.positive_batch`).
* **Backpressure**: the queue is bounded by ``max_in_flight`` queries and
  by the estimated bytes of pending results (default: the cache budget);
  exceeding either limit drains the queue instead of growing it.
* **Dispatcher thread** (:meth:`CountingService.start`, or
  ``dispatcher=True``): a dedicated scheduler thread that fires the
  ``max_wait_s`` deadline *without* requiring a subsequent submit — the
  asynchronous front-end a real service needs.  :meth:`CountingService
  .shutdown` stops it and either drains the queue or fails every pending
  waiter with :class:`ServiceShutdown` (no ticket is ever left hanging).

Locking: the queue lock only guards scheduler state — triggered batches
execute *after* it is released, so submits keep flowing while a batch
runs; one execution lock serialises engine/cache mutation across client
threads (the cache itself is also lock-guarded for its other users).

Results land in the engine's cache under the same keys the on-demand
positive policy uses, so a structure search sharing the engine is served
directly from the warmed cache; :meth:`CountingService.prefetch` runs the
same machinery for an explicit policy (see
:meth:`repro.core.strategies.Strategy.family_ct_many`).
"""

from __future__ import annotations

import asyncio
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cache import DEFAULT_TENANT
from ..core.ct import CtTable
from ..core.engine import CountingEngine, DeltaReport, OnDemandPositives
from ..core.plan import ContractionPlan
from ..core.variables import CtVar, LatticePoint
from ..obs.trace import NullTracer, SpanContext, default_tracer
from .batching import execute_bucketed, execute_complete_bucketed
from .metrics import ServiceMetrics

Sink = Callable[[LatticePoint, Tuple[CtVar, ...], CtTable], None]


class ServiceShutdown(RuntimeError):
    """The service was shut down: raised by new submits after
    :meth:`CountingService.shutdown`, and propagated to every waiter whose
    query was still pending when a non-draining shutdown ran."""


class TenantAdmissionError(RuntimeError):
    """A submit was rejected by per-tenant admission control: the tenant
    already has ``admission_max`` queries pending and its policy is
    ``"shed"``.  The client should back off and retry; other tenants'
    services are unaffected."""


class _TokenBucket:
    """Per-tenant token bucket: ``capacity`` tokens, refilled continuously
    at ``capacity / window_s`` tokens per second.  One token buys one
    *admitted* query (cache hits and coalesces are free — they cost the
    pool nothing).  Thread-safe; the clock is injectable for tests."""

    def __init__(self, capacity: int, window_s: float,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError("rate_limit capacity must be >= 1")
        if window_s <= 0:
            raise ValueError("rate_limit window must be > 0 seconds")
        self.capacity = float(capacity)
        self.rate = capacity / float(window_s)
        self.clock = clock
        self._tokens = float(capacity)
        self._stamp = clock()
        self._lock = threading.Lock()

    def acquire(self) -> float:
        """Take one token if available.

        Returns:
            ``0.0`` on success, else the seconds until a token will have
            accrued (no token is consumed on failure).
        """
        with self._lock:
            now = self.clock()
            self._tokens = min(self.capacity, self._tokens
                               + (now - self._stamp) * self.rate)
            self._stamp = now
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return 0.0
            return (1.0 - self._tokens) / self.rate


class _Pending:
    """One in-flight query: a compiled plan plus everyone waiting on it."""

    __slots__ = ("point", "keep", "plan", "sig", "complete", "sinks",
                 "cache_result", "enqueued_at", "event", "result", "error",
                 "callbacks", "trace_ctx")

    def __init__(self, point: LatticePoint, keep: Tuple[CtVar, ...],
                 plan: ContractionPlan, complete: bool = False):
        self.point, self.keep, self.plan = point, keep, plan
        self.complete = complete
        # complete-CT buckets never mix with positive buckets, even when
        # the output shapes coincide: the execution semantics differ
        self.sig = ("complete" if complete else "pos",
                    plan.shape_signature())
        self.sinks: List[Sink] = []
        self.cache_result = False      # a sink-less client wants it cached
        self.enqueued_at = time.perf_counter()
        self.event = threading.Event()
        self.result: Optional[CtTable] = None
        self.error: Optional[BaseException] = None
        # parent span for this query's service-side spans: set from the
        # submitter's trace context (e.g. the router's submit span), then
        # re-pointed at the queue-residency span once drained
        self.trace_ctx: Optional[SpanContext] = None
        # fired (once each) after the event is set: the asyncio bridge —
        # waiters that cannot block a thread park a loop.call_soon_threadsafe
        # hook here instead (callbacks must be idempotent: the
        # append-then-check handshake in settle/on_settled may run one twice)
        self.callbacks: List[Callable[[], None]] = []

    def settle(self) -> None:
        """Mark done and wake every waiter — threads via the event,
        asyncio waiters via their callbacks."""
        self.event.set()
        for cb in list(self.callbacks):
            try:
                cb()
            except Exception:          # noqa: BLE001 — a dead event loop
                pass                   # must not break sibling waiters

    def on_settled(self, cb: Callable[[], None]) -> None:
        """Register an idempotent done-callback; fires immediately if the
        entry already settled (append-then-check closes the race with a
        concurrent :meth:`settle`)."""
        self.callbacks.append(cb)
        if self.event.is_set():
            cb()


class CountTicket:
    """Handle for a submitted query; ``result()`` blocks (flushing the
    service if needed) until the count table is available.

    Usage::

        ticket = service.submit(point)
        tab = ticket.result(timeout=30.0)
    """

    def __init__(self, service: "CountingService",
                 entry: Optional[_Pending] = None,
                 result: Optional[CtTable] = None):
        self._service = service
        self._entry = entry
        self._result = result

    @property
    def done(self) -> bool:
        return self._result is not None or (
            self._entry is not None and self._entry.event.is_set())

    def result(self, timeout: Optional[float] = None) -> CtTable:
        """The count table for this query.

        Args:
            timeout: seconds to wait after flushing (None = forever).

        Returns:
            The positive :class:`~repro.core.ct.CtTable` over the query's
            ``keep`` axes.

        Raises:
            TimeoutError: the query did not complete within ``timeout``.
            BaseException: whatever the executing batch raised — every
                waiter of a failed batch sees the same exception.
        """
        if self._result is not None:
            return self._result
        assert self._entry is not None
        if not self._entry.event.is_set():
            self._service.flush()          # our entry may ride this drain …
            if not self._entry.event.wait(timeout):   # … or a concurrent one
                raise TimeoutError("count query did not complete in time")
        if self._entry.error is not None:  # execution failed: every waiter
            raise self._entry.error        # sees the batch's exception
        self._result = self._entry.result
        return self._result

    async def aresult(self) -> CtTable:
        """Asyncio-native :meth:`result`: awaits the count table without
        blocking the event loop.

        With the dispatcher thread running, completion is event-driven —
        a done-callback wakes the awaiting task via
        ``loop.call_soon_threadsafe``, so thousands of concurrent awaiters
        cost no threads.  Without a dispatcher, the blocking ``result()``
        (which flushes the queue) runs in the loop's default thread-pool
        executor instead.

        Usage::

            tab = await service.submit(point).aresult()
        """
        if self._result is not None:
            return self._result
        entry = self._entry
        assert entry is not None
        loop = asyncio.get_running_loop()
        if not (self._service.running
                and self._service.max_wait_s is not None):
            # nothing will fire the batch on its own: drive the blocking
            # flush+wait path off-loop instead of parking forever
            return await loop.run_in_executor(None, self.result)
        fut: "asyncio.Future[CtTable]" = loop.create_future()

        def settle() -> None:          # runs on the loop
            if fut.done():
                return
            if entry.error is not None:
                fut.set_exception(entry.error)
            else:
                fut.set_result(entry.result)

        entry.on_settled(lambda: loop.call_soon_threadsafe(settle))
        self._result = await fut
        return self._result


class CountingService:
    """Signature-bucketed micro-batching scheduler over a
    :class:`~repro.core.engine.CountingEngine`.

    Args:
        engine: the planner/executor/cache stack to execute against.
        max_batch_size: dispatch a signature bucket at this many queries.
        max_wait_s: dispatch everything once the oldest pending query is
            this stale.  Checked on submit; with the dispatcher thread
            running (:meth:`start` / ``dispatcher=True``) the deadline
            fires on its own, no submit needed.  ``None`` disables the
            trigger.
        max_in_flight: backpressure — force a full drain beyond this many
            pending queries.
        max_pending_bytes: backpressure — force a full drain beyond this
            many estimated result bytes pending (defaults to the engine's
            cache budget).
        dispatcher: start the dispatcher thread immediately (equivalent
            to calling :meth:`start` after construction).
        use_butterfly: Möbius evaluation order for complete-CT queries
            (see :func:`~repro.core.mobius.complete_ct`).
        metrics: counters sink; defaults to a fresh
            :class:`~repro.serve.metrics.ServiceMetrics`.
        tracer: request tracer wired through the service, its engine,
            executor, and cache (see :mod:`repro.obs.trace`); defaults to
            :func:`~repro.obs.trace.default_tracer` — the free no-op
            tracer unless the ``REPRO_TRACE`` env var enables one.
        tenant: the logical database this service fronts (stamped on
            stats snapshots and trace spans; the default keeps single-DB
            deployments tenant-blind).
        admission_max: per-tenant admission bound — the most queries this
            tenant may have pending at once, ON TOP of the pool-level
            ``max_in_flight``/byte backpressure (``None`` disables the
            gate).
        admission_policy: what a submit over the bound does — ``"queue"``
            drains the tenant's own queue inline on the flooding thread
            (bounded depth, no rejection), ``"shed"`` raises
            :class:`TenantAdmissionError` (load shedding).
        rate_limit: per-tenant sustained-rate bound as ``(n, window_s)`` —
            a token bucket admitting at most ``n`` NEW queries per
            ``window_s`` seconds with bursts up to ``n`` (``None``
            disables it).  Cache hits and coalesces are free.  Over-rate
            submits follow ``admission_policy``: ``"shed"`` raises
            :class:`TenantAdmissionError`, ``"queue"`` sleeps the
            flooding thread (off-lock) until a token accrues.

    Raises:
        ValueError: ``max_batch_size < 1``, an unknown
            ``admission_policy``, or a non-positive ``rate_limit``.

    Usage::

        svc = CountingService(CountingEngine(db, "sparse"), max_batch_size=32)
        tab = svc.count(point)
    """

    def __init__(self, engine: CountingEngine,
                 max_batch_size: int = 64,
                 max_wait_s: Optional[float] = None,
                 max_in_flight: int = 1024,
                 max_pending_bytes: Optional[int] = None,
                 dispatcher: bool = False,
                 use_butterfly: bool = True,
                 metrics: Optional[ServiceMetrics] = None,
                 tracer: Optional[NullTracer] = None,
                 tenant: str = DEFAULT_TENANT,
                 admission_max: Optional[int] = None,
                 admission_policy: str = "queue",
                 rate_limit: Optional[Tuple[int, float]] = None):
        if max_batch_size < 1:
            raise ValueError("max_batch_size must be >= 1")
        if admission_policy not in ("queue", "shed"):
            raise ValueError(f"unknown admission_policy "
                             f"{admission_policy!r} (queue|shed)")
        self.engine = engine
        self.tenant = tenant
        self.admission_max = admission_max
        self.admission_policy = admission_policy
        self.rate_limit = rate_limit
        self._rate_bucket = (_TokenBucket(*rate_limit)
                             if rate_limit is not None else None)
        self.max_batch_size = max_batch_size
        self.max_wait_s = max_wait_s
        self.max_in_flight = max_in_flight
        self.max_pending_bytes = (max_pending_bytes if max_pending_bytes
                                  is not None else engine.cache.budget_bytes)
        self.use_butterfly = use_butterfly
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.set_tracer(tracer if tracer is not None else default_tracer())
        self._lock = threading.RLock()         # queue state
        # execution + cache writes; re-entrant so a caller already holding
        # the fence() (e.g. the router's all-shard delta fence) can invoke
        # apply_delta without deadlocking on itself
        self._exec_lock = threading.RLock()
        self._wake = threading.Condition(self._lock)  # dispatcher wake-ups
        self._pending: Dict[Tuple, _Pending] = {}
        self._by_sig: Dict[Tuple, List[Tuple]] = {}   # sig -> [req_key]
        self._pending_bytes = 0
        self._policy: Optional[OnDemandPositives] = None  # complete-CT path
        self._dispatcher_thread: Optional[threading.Thread] = None
        self._shut_down = False
        self._defer_depth = 0          # see defer_drains()
        self._discovery = None         # lazily built DiscoveryService
        if dispatcher:
            self.start()

    def set_tracer(self, tracer: NullTracer) -> "CountingService":
        """Wire one tracer through the whole stack this service fronts:
        the service itself, its engine (``apply_delta`` spans), the
        engine's executor (jit-dispatch spans), and the shared cache
        (hit/miss/evict events).  Pass :data:`~repro.obs.trace
        .NULL_TRACER` to turn tracing back off.

        Usage::

            svc.set_tracer(Tracer())
        """
        self.tracer = tracer
        eng = self.engine
        eng.tracer = tracer
        eng.executor.tracer = tracer
        eng.cache.tracer = tracer
        return self

    # -- client API ---------------------------------------------------------
    def submit(self, point: LatticePoint,
               keep: Optional[Sequence[CtVar]] = None,
               sink: Optional[Sink] = None,
               trace_ctx: Optional[SpanContext] = None) -> CountTicket:
        """Enqueue one positive-count query; returns immediately.

        With no ``sink`` the result is cached under the engine's on-demand
        positive key (and cache-resident queries short-circuit here); a
        ``sink(point, keep, tab)`` callback routes the result elsewhere
        (e.g. a strategy policy's absorb hook).

        Args:
            point: lattice point to count (>= 1 relationship atom).
            keep: ct-table axes; defaults to every entity/edge attribute
                of the point.
            sink: optional result callback, called during batch execution.
            trace_ctx: parent span context for this query's service-side
                spans — pass the submitter's span (e.g. the router's) to
                keep the whole request in one trace.

        Returns:
            A :class:`CountTicket` (already ``done`` on a cache hit).

        Usage::

            ticket = svc.submit(point, keep)
        """
        plan = self.engine.plan(point, keep)
        return self._enqueue(point, plan.keep, plan, sink, complete=False,
                             trace_ctx=trace_ctx)

    def submit_complete(self, point: LatticePoint,
                        keep: Optional[Sequence[CtVar]] = None,
                        sink: Optional[Sink] = None,
                        trace_ctx: Optional[SpanContext] = None
                        ) -> CountTicket:
        """Enqueue one complete-CT query (positive + Möbius negative
        phase); returns immediately.

        ``keep`` may contain entity-attr axes AND relationship indicator
        axes of the point (edge-attr axes are legal too; they fall back
        to the blockwise Möbius join per query).  The result is cached
        under the same ``"fam"`` key the strategies' :meth:`~repro.core
        .strategies.Strategy.family_ct` uses, so a structure search
        sharing the engine is served from the warmed cache.

        Args:
            point: lattice point to count (>= 1 relationship atom).
            keep: ct-table axes; defaults to every entity/edge attribute
                plus every relationship indicator of the point.
            sink: optional result callback, called during batch execution.

        Returns:
            A :class:`CountTicket` (already ``done`` on a cache hit).

        Usage::

            tab = svc.submit_complete(point, keep).result()
        """
        if keep is None:
            keep = point.all_ct_vars(self.engine.db.schema,
                                     include_rind=True)
        keep_t = tuple(keep)
        plan = self.engine.plan(point, keep_t)   # signature + byte estimate
        return self._enqueue(point, keep_t, plan, sink, complete=True,
                             trace_ctx=trace_ctx)

    def _enqueue(self, point: LatticePoint, keep_t: Tuple[CtVar, ...],
                 plan: ContractionPlan, sink: Optional[Sink],
                 complete: bool,
                 trace_ctx: Optional[SpanContext] = None) -> CountTicket:
        to_execute: List[_Pending] = []
        tr = self.tracer
        counted = False          # the requests counter moves once, not per
        while True:              # rate-limit retry
            retry_in = 0.0
            with self._lock:
                if self._shut_down:
                    raise ServiceShutdown("submit on a shut-down service")
                if not counted:
                    self.metrics.inc(requests=1,
                                     complete_requests=int(complete))
                    counted = True
                if sink is None:
                    cache_key = (self._complete_key(point, keep_t) if complete
                                 else self._cache_key(point, keep_t))
                    hit = self.engine.cache.get(cache_key)
                    if hit is not None:
                        self.metrics.inc(cache_hits=1)
                        return CountTicket(self, result=hit)
                req_key = ("complete" if complete else "pos",
                           point.atoms, keep_t)
                entry = self._pending.get(req_key)
                if entry is not None:
                    if sink is not None:
                        entry.sinks.append(sink)
                    else:
                        entry.cache_result = True
                    self.metrics.inc(coalesced=1)
                    if tr.enabled:
                        tr.event("service.coalesced", parent=trace_ctx,
                                 atoms=point.atoms, tenant=self.tenant)
                    return CountTicket(self, entry=entry)
                # per-tenant rate gate: the token bucket bounds this
                # tenant's SUSTAINED admission rate, on top of the depth
                # bound below.  A failed acquire consumes nothing; the
                # over-rate submit sheds or sleeps per admission_policy.
                if self._rate_bucket is not None:
                    retry_in = self._rate_bucket.acquire()
                    if retry_in > 0.0:
                        self.metrics.inc(rate_limited=1)
                        if self.admission_policy == "shed":
                            self.metrics.inc(shed=1)
                            if tr.enabled:
                                tr.event("service.shed", parent=trace_ctx,
                                         atoms=point.atoms,
                                         tenant=self.tenant,
                                         rate_limit=self.rate_limit)
                            raise TenantAdmissionError(
                                f"tenant {self.tenant!r}: rate limit of "
                                f"{self.rate_limit[0]} queries per "
                                f"{self.rate_limit[1]}s exceeded")
                        if tr.enabled:
                            tr.event("service.rate_limited",
                                     parent=trace_ctx, tenant=self.tenant,
                                     retry_in=retry_in)
                if retry_in > 0.0:
                    # fall through to the off-lock sleep below, then retry
                    # the whole gate sequence (the query may coalesce or
                    # cache-hit by then — both free)
                    pass
                else:
                    ticket, to_execute = self._admit(
                        req_key, point, keep_t, plan, sink, complete,
                        trace_ctx)
            if retry_in == 0.0:
                break
            # "queue" policy, over rate: sleep OFF the lock (other tenants'
            # submits keep flowing), then retry from the top
            time.sleep(retry_in)
        if to_execute:       # run OUTSIDE the lock: submits keep flowing
            self._execute(to_execute)
        return ticket

    def _admit(self, req_key: Tuple, point: LatticePoint,
               keep_t: Tuple[CtVar, ...], plan: ContractionPlan,
               sink: Optional[Sink], complete: bool,
               trace_ctx: Optional[SpanContext]
               ) -> Tuple[CountTicket, List[_Pending]]:
        """Admission gate + queue insertion for one NEW query (queue lock
        held by the caller).  Returns the ticket and whatever the dispatch
        triggers say must now execute (outside the lock)."""
        tr = self.tracer
        # per-tenant admission gate: layered UNDER max_in_flight (which
        # protects the pool) — this bound protects the pool FROM one
        # tenant.  Coalesces and cache hits never consume a slot.
        admission_over = (self.admission_max is not None
                          and len(self._pending) >= self.admission_max)
        if admission_over and self.admission_policy == "shed":
            self.metrics.inc(shed=1)
            if tr.enabled:
                tr.event("service.shed", parent=trace_ctx,
                         atoms=point.atoms, tenant=self.tenant,
                         bound=self.admission_max)
            raise TenantAdmissionError(
                f"tenant {self.tenant!r}: admission bound of "
                f"{self.admission_max} pending queries exceeded")
        entry = _Pending(point, keep_t, plan, complete)
        entry.trace_ctx = trace_ctx
        entry.cache_result = sink is None
        if sink is not None:
            entry.sinks.append(sink)
        self._pending[req_key] = entry
        self._by_sig.setdefault(entry.sig, []).append(req_key)
        self._pending_bytes += self._estimate_bytes(plan)
        self.metrics.inc(enqueued=1, admitted=1)
        ticket = CountTicket(self, entry=entry)
        if admission_over:
            # "queue" policy: the flooding tenant pays for its own
            # drain inline, holding its pending depth at the bound
            # (overrides defer_drains, like backpressure does)
            self.metrics.inc(throttled=1)
            if tr.enabled:
                tr.event("service.flush", trigger="admission",
                         tenant=self.tenant)
            to_execute = self._drain_all()
        else:
            to_execute = self._drain_triggered(entry)
        self._wake.notify_all()      # dispatcher re-arms its deadline
        return ticket, to_execute

    def count(self, point: LatticePoint,
              keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous convenience: :meth:`submit` + blocking ``result()``.

        Usage::

            tab = svc.count(point)
        """
        return self.submit(point, keep).result()

    def count_many(self, queries: Sequence[Tuple[LatticePoint,
                                                 Optional[Sequence[CtVar]]]]
                   ) -> List[CtTable]:
        """Submit a whole query list, dispatch it bucketed, return results
        in submission order — the natural API for a client that has its
        round's frontier in hand.

        Args:
            queries: ``(point, keep)`` pairs (``keep=None`` = all axes).

        Returns:
            One :class:`~repro.core.ct.CtTable` per query, positionally
            aligned with ``queries``.

        Usage::

            tabs = svc.count_many([(p, None) for p in lattice])
        """
        tickets = [self.submit(point, keep) for point, keep in queries]
        self.flush()
        return [t.result() for t in tickets]

    def count_complete(self, point: LatticePoint,
                       keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous complete-CT convenience: :meth:`submit_complete` +
        blocking ``result()``.

        Usage::

            tab = svc.count_complete(point)
        """
        return self.submit_complete(point, keep).result()

    def complete_many(self, queries: Sequence[Tuple[LatticePoint,
                                                    Optional[Sequence[CtVar]]]]
                      ) -> List[CtTable]:
        """Submit a whole complete-CT query list, dispatch it bucketed
        (both phases), return results in submission order.

        Args:
            queries: ``(point, keep)`` pairs (``keep=None`` = all
                attribute + indicator axes).

        Returns:
            One complete :class:`~repro.core.ct.CtTable` per query,
            positionally aligned with ``queries``.

        Usage::

            tabs = svc.complete_many([(p, None) for p in lattice])
        """
        tickets = [self.submit_complete(point, keep)
                   for point, keep in queries]
        self.flush()
        return [t.result() for t in tickets]

    # -- asyncio client surface ---------------------------------------------
    async def acount(self, point: LatticePoint,
                     keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Asyncio-native :meth:`count`: submit + ``await`` the result
        without blocking the event loop.

        Designed for the dispatcher deployment (``dispatcher=True`` with a
        ``max_wait_s`` deadline): a flood of concurrent ``acount`` awaiters
        costs no threads — each parks on a future that the executing batch
        wakes via ``loop.call_soon_threadsafe`` — and the dispatcher's
        deadline batches them exactly like threaded clients.  Without a
        dispatcher the blocking flush runs in the loop's thread pool.

        Usage::

            svc = CountingService(engine, max_wait_s=0.005, dispatcher=True)
            tabs = await asyncio.gather(*(svc.acount(p) for p in points))
        """
        return await self.submit(point, keep).aresult()

    async def acomplete(self, point: LatticePoint,
                        keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Asyncio-native :meth:`count_complete`: complete-CT query
        (positive + Möbius negative phase) awaited without blocking the
        loop — same bridging as :meth:`acount`.

        Usage::

            tab = await svc.acomplete(point)
        """
        return await self.submit_complete(point, keep).aresult()

    # -- mutations ----------------------------------------------------------
    @contextmanager
    def fence(self):
        """Hold the store still: blocks new submits from reading the cache
        AND waits out any mid-flight bucket execution, so a mutation +
        cache reconcile inside the fence is atomic with respect to every
        query.  Queries already queued (but not executing) simply run
        after the fence — against the post-delta store, which their
        metadata-only plans are agnostic to."""
        with self._lock, self._exec_lock:
            yield self

    def apply_delta(self, delta=None, *,
                    mutate: Optional[Callable[[], object]] = None,
                    **kw) -> Optional[DeltaReport]:
        """Apply one store mutation and reconcile the engine's cache,
        fenced against in-flight buckets (the version bump never tears a
        running batch, and no submit can read a stale entry in between).

        Args:
            delta: a :class:`~repro.core.database.FactDelta` or
                :class:`~repro.core.database.AttrDelta` already applied
                to the engine's database — pass it when the mutation
                itself happened elsewhere (e.g. the router mutated a
                :class:`~repro.core.database.ShardedDatabase` under this
                service's fence).
            mutate: alternatively, a thunk that performs the mutation and
                returns the delta; it runs INSIDE the fence (this is what
                :meth:`insert_facts` / :meth:`delete_facts` use).
            **kw: forwarded to :meth:`~repro.core.engine.CountingEngine
                .apply_delta` (e.g. ``max_update_fraction``).

        Returns:
            The engine's :class:`~repro.core.engine.DeltaReport`, or
            ``None`` for an empty delta.

        Usage::

            report = svc.apply_delta(mutate=lambda: db.insert_facts(...))
        """
        with self.fence():
            if mutate is not None:
                delta = mutate()
            if delta is None:
                return None
            report = self.engine.apply_delta(delta, **kw)
        self.metrics.inc(deltas=1, delta_updated=report.updated,
                         delta_invalidated=report.invalidated,
                         delta_retained=report.retained)
        return report

    def insert_facts(self, rel: str, src, dst,
                     attrs=None, **kw) -> Optional[DeltaReport]:
        """Fenced convenience: :meth:`~repro.core.database.RelationalDB
        .insert_facts` on the engine's database + cache reconcile, as one
        atomic step (see :meth:`apply_delta`).

        Usage::

            svc.insert_facts("Rated", src, dst, {"rating": vals})
        """
        return self.apply_delta(
            mutate=lambda: self.engine.db.insert_facts(rel, src, dst, attrs),
            **kw)

    def delete_facts(self, rel: str, src, dst, **kw) -> Optional[DeltaReport]:
        """Fenced convenience: :meth:`~repro.core.database.RelationalDB
        .delete_facts` + cache reconcile, as one atomic step.

        Usage::

            svc.delete_facts("Rated", src, dst)
        """
        return self.apply_delta(
            mutate=lambda: self.engine.db.delete_facts(rel, src, dst), **kw)

    def update_attrs(self, etype: str, rows, attrs,
                     **kw) -> Optional[DeltaReport]:
        """Fenced convenience: :meth:`~repro.core.database.RelationalDB
        .update_attrs` (entity-attribute writes) + cache reconcile, as one
        atomic step.  Entries whose dependency stamps intersect the
        written ``(etype, attr)`` pairs are invalidated; everything else
        is retained untouched (see :meth:`~repro.core.engine
        .CountingEngine.apply_delta`).

        Usage::

            svc.update_attrs("user", rows, {"age": new_ages})
        """
        return self.apply_delta(
            mutate=lambda: self.engine.db.update_attrs(etype, rows, attrs),
            **kw)

    def prefetch(self, policy, queries: Sequence[Tuple[LatticePoint,
                                                       Tuple[CtVar, ...]]]
                 ) -> int:
        """Batch-warm a positive policy's cache: ask the policy which of
        ``queries`` it would have to contract from data
        (:meth:`~repro.core.engine._Policy.batchable_misses`), execute those
        in signature buckets, and hand each result back through the
        policy's absorb hook.

        Args:
            policy: a positive policy from :mod:`repro.core.engine`
                (``batchable_misses``/``absorb`` protocol).
            queries: the ``(point, keep)`` positive sub-queries about to
                be issued (see :func:`repro.core.mobius.positive_queries`).

        Returns:
            The number of queries actually executed (cache misses).

        Usage::

            n = svc.prefetch(strategy.provider, positive_queries(point, keep))
        """
        todo = policy.batchable_misses(list(queries))
        if not todo:
            return 0
        for point, keep in todo:
            self.submit(point, keep, sink=policy.absorb)
        self.flush()
        return len(todo)

    # -- dispatcher lifecycle -----------------------------------------------
    def start(self) -> "CountingService":
        """Start the dispatcher thread (idempotent).

        The dispatcher sleeps until the oldest pending query's
        ``max_wait_s`` deadline, then drains and executes the queue on its
        own — no subsequent submit needed.  Submits wake it so the
        deadline is always armed against the current oldest entry.  With
        ``max_wait_s=None`` the thread stays parked until :meth:`shutdown`
        (all other triggers run on the submitting thread).

        Returns:
            ``self``, for chaining.

        Raises:
            ServiceShutdown: the service was already shut down.

        Usage::

            svc = CountingService(engine, max_wait_s=0.01).start()
        """
        with self._lock:
            if self._shut_down:
                raise ServiceShutdown("start on a shut-down service")
            if self._dispatcher_thread is not None:
                return self
            t = threading.Thread(target=self._dispatch_loop,
                                 name="counting-dispatcher", daemon=True)
            self._dispatcher_thread = t
        t.start()
        return self

    @property
    def running(self) -> bool:
        """Whether the dispatcher thread is alive."""
        t = self._dispatcher_thread
        return t is not None and t.is_alive()

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the service: halt the dispatcher thread and settle every
        pending query.  Idempotent; subsequent submits raise
        :class:`ServiceShutdown`.

        Args:
            drain: ``True`` executes the remaining queue before returning
                (every waiter gets its result); ``False`` fails every
                pending waiter with :class:`ServiceShutdown` immediately —
                a clean error, never a hang.
            timeout: seconds to wait for the dispatcher thread to exit
                (``None`` = forever).

        Usage::

            svc.shutdown()                 # graceful: drain, then stop
            svc.shutdown(drain=False)      # fast: fail pending waiters
        """
        with self._lock:
            if self._shut_down:
                return
            self._shut_down = True
            entries = self._drain_all()
            self._wake.notify_all()
            thread, self._dispatcher_thread = self._dispatcher_thread, None
        if thread is not None:
            thread.join(timeout)
        if not entries:
            return
        if drain:
            try:
                self._execute(entries)
            except BaseException:      # noqa: BLE001 — each waiter already
                pass                   # holds the batch's error; shutdown
                                       # itself must not throw (callers
                                       # run it in finally blocks)
            return
        err = ServiceShutdown(
            f"counting service shut down with {len(entries)} queries "
            f"pending")
        for e in entries:
            e.error = err
            e.settle()

    def _dispatch_loop(self) -> None:
        while True:
            entries: List[_Pending] = []
            with self._lock:
                if self._shut_down:
                    return
                timeout = None
                if self.max_wait_s is not None and self._pending:
                    oldest = min(e.enqueued_at
                                 for e in self._pending.values())
                    due = self.max_wait_s - (time.perf_counter() - oldest)
                    if due <= 0:
                        self.metrics.inc(wait_flushes=1)
                        if self.tracer.enabled:
                            self.tracer.event("service.flush",
                                              trigger="deadline")
                        entries = self._drain_all()
                    else:
                        timeout = due
                if not entries:
                    self._wake.wait(timeout)
                    continue
            try:
                self._execute(entries)
            except BaseException:      # noqa: BLE001 — waiters already got
                pass                   # the error via their tickets; the
                                       # dispatcher survives to serve the
                                       # next deadline

    # -- scheduler ----------------------------------------------------------
    def flush(self) -> None:
        """Drain and execute every pending query."""
        with self._lock:
            entries = self._drain_all()
        if entries:
            self._execute(entries)

    @contextmanager
    def defer_drains(self):
        """Suspend the size/deadline dispatch triggers inside the block:
        submits only QUEUE, nothing executes on the caller's thread until
        its own :meth:`flush`.  For callers that hold a whole flood and
        flush immediately after — the router enqueues every shard's full
        query list under this and then drains all shards CONCURRENTLY, so
        one shard's inline size-triggered drain can't serialise the other
        shard's execution behind it.  Backpressure (in-flight count/byte
        limits) stays armed — a runaway submit loop still force-drains.
        Re-entrant and thread-safe."""
        with self._lock:
            self._defer_depth += 1
        try:
            yield self
        finally:
            with self._lock:
                self._defer_depth -= 1

    def pending(self) -> int:
        """Number of queries currently queued (not yet dispatched)."""
        with self._lock:
            return len(self._pending)

    # -- external (router-fused) execution -----------------------------------
    def drain_pending(self) -> List[_Pending]:
        """Take the whole queue for an EXTERNAL executor — the router's
        fused cross-shard dispatch runs every shard's drained plans under
        one jit.  The caller OWNS the drained entries: it must either
        hand each a table via :meth:`deliver_external`, execute them with
        :meth:`execute_drained`, or settle them with an error — an entry
        dropped on the floor hangs its waiters forever."""
        with self._lock:
            return self._drain_all()

    def execute_drained(self, entries: List[_Pending]) -> None:
        """Run previously drained entries through the normal batch path
        (the fused router flush falls back here when shard queues don't
        align)."""
        if entries:
            self._execute(entries)

    def deliver_external(self, delivered: Sequence[Tuple[_Pending,
                                                         CtTable]]) -> None:
        """Deliver externally computed tables for drained entries: the
        usual sink/cache/result routing under the exec lock, then settle.
        The tables must be exactly what :meth:`_execute` would have
        produced (the fused path evaluates the same plans)."""
        tr = self.tracer
        try:
            with self._exec_lock:
                now = time.perf_counter()
                for e, tab in delivered:
                    self.metrics.observe_wait(now - e.enqueued_at)
                    if tr.enabled:
                        e.trace_ctx = tr.record(
                            "service.queue", e.enqueued_at, now,
                            parent=e.trace_ctx, external=True,
                            tenant=self.tenant)
                    self._deliver(e, tab)
        finally:
            self._settle_all([e for e, _ in delivered])

    def _drain_all(self) -> List[_Pending]:
        """Take the whole queue (lock held)."""
        entries = list(self._pending.values())
        self._pending.clear()
        self._by_sig.clear()
        self._pending_bytes = 0
        if entries:
            self.metrics.inc(flushes=1)
        return entries

    def _drain_bucket(self, sig: Tuple) -> List[_Pending]:
        """Take one signature bucket (lock held)."""
        keys = self._by_sig.pop(sig, [])
        entries = [self._pending.pop(k) for k in keys]
        self._pending_bytes -= sum(self._estimate_bytes(e.plan)
                                   for e in entries)
        if entries:
            self.metrics.inc(flushes=1)
        return entries

    def _drain_triggered(self, entry: _Pending) -> List[_Pending]:
        """Apply the dispatch triggers after admitting ``entry`` (lock
        held); returns whatever must now execute."""
        over_count = len(self._pending) > self.max_in_flight
        over_bytes = (self.max_pending_bytes is not None
                      and self._pending_bytes > self.max_pending_bytes
                      and len(self._pending) > 1)
        tr = self.tracer
        if over_count or over_bytes:
            self.metrics.inc(backpressure_flushes=1)
            if tr.enabled:
                tr.event("service.flush", trigger="backpressure",
                         over_count=over_count, over_bytes=over_bytes)
            return self._drain_all()
        if self._defer_depth:
            return []                  # caller flushes itself; see
        if len(self._by_sig.get(entry.sig, ())) >= self.max_batch_size:
            self.metrics.inc(size_flushes=1)
            if tr.enabled:
                tr.event("service.flush", trigger="size", sig=entry.sig)
            return self._drain_bucket(entry.sig)
        if self.max_wait_s is not None:
            oldest = min(e.enqueued_at for e in self._pending.values())
            if time.perf_counter() - oldest >= self.max_wait_s:
                self.metrics.inc(wait_flushes=1)
                if tr.enabled:
                    tr.event("service.flush", trigger="deadline")
                return self._drain_all()
        return []

    def _execute(self, entries: List[_Pending]) -> None:
        # one batch executes at a time: the exec lock serialises engine
        # stats bumps, metrics, cache writes and sink callbacks across
        # client threads (the queue lock is NOT held here).  Entries are
        # already out of the queue, so every event MUST be set even on
        # failure — a waiter left unsignalled would hang forever.
        eng = self.engine
        tr = self.tracer
        try:
            with self._exec_lock:
                now = time.perf_counter()
                for e in entries:
                    self.metrics.observe_wait(now - e.enqueued_at)
                    if tr.enabled:
                        # the queue span is only known now (retroactive);
                        # re-point the entry at it so its exec span nests
                        e.trace_ctx = tr.record(
                            "service.queue", e.enqueued_at, now,
                            parent=e.trace_ctx, sig=e.sig,
                            tenant=self.tenant)
                positives = [e for e in entries if not e.complete]
                completes = [e for e in entries if e.complete]
                if positives:
                    t0 = time.perf_counter()
                    with eng.stats.timer("positive"):
                        tabs = execute_bucketed(
                            eng.executor, eng.db,
                            [e.plan for e in positives],
                            eng.stats, max_batch_size=self.max_batch_size,
                            metrics=self.metrics, tracer=tr)
                    if tr.enabled:
                        t1 = time.perf_counter()
                        for e in positives:
                            tr.record("service.exec", t0, t1,
                                      parent=e.trace_ctx, phase="positive",
                                      batch=len(positives))
                    for e, tab in zip(positives, tabs):
                        self._deliver(e, tab)
                if completes:
                    t0 = time.perf_counter()
                    tabs = execute_complete_bucketed(
                        eng, self._complete_policy(),
                        [(e.point, e.keep) for e in completes],
                        eng.stats, max_batch_size=self.max_batch_size,
                        metrics=self.metrics,
                        use_butterfly=self.use_butterfly)
                    if tr.enabled:
                        t1 = time.perf_counter()
                        for e in completes:
                            tr.record("service.exec", t0, t1,
                                      parent=e.trace_ctx, phase="complete",
                                      batch=len(completes))
                    for e, tab in zip(completes, tabs):
                        self._deliver(e, tab)
        except BaseException as err:
            for e in entries:
                if e.result is None and e.error is None:
                    e.error = err          # propagate to every waiter
            raise
        finally:
            self._settle_all(entries)

    def _settle_all(self, entries: Sequence[_Pending]) -> None:
        """Wake every waiter, then record each entry's submit→settle
        latency (and offer it to the slow-query log when tracing)."""
        done = time.perf_counter()
        slow = self.tracer.slow
        for e in entries:
            e.settle()
            dt = done - e.enqueued_at
            self.metrics.observe_e2e(dt)
            if slow is not None:
                slow.offer("service.e2e", dt, sig=e.sig,
                           complete=e.complete, atoms=e.point.atoms)

    def _deliver(self, e: _Pending, tab: CtTable) -> None:
        """Route one finished query: sinks, cache write, result slot."""
        eng = self.engine
        for sink in e.sinks:
            sink(e.point, e.keep, tab)
        if e.cache_result or not e.sinks:
            if e.complete:
                # family-table namespace; the positives inside already did
                # their own ct_rows accounting through the policy
                eng.cache.put(self._complete_key(e.point, e.keep), tab)
            else:
                key = self._cache_key(e.point, e.keep)
                eng.count_rows_once(key, tab)
                eng.cache.put(key, tab)
        e.result = tab

    # -- bookkeeping --------------------------------------------------------
    def _cache_key(self, point: LatticePoint,
                   keep: Tuple[CtVar, ...]) -> Tuple:
        # same namespace as OnDemandPositives: a search sharing this engine
        # is served straight from the warmed cache
        return ("pos", self.engine.executor.name, point.atoms, tuple(keep))

    def _complete_key(self, point: LatticePoint,
                      keep: Tuple[CtVar, ...]) -> Tuple:
        # same namespace as Strategy.family_ct: a search sharing this
        # engine is served straight from the warmed family cache
        return ("fam", point.atoms, tuple(keep))

    def _complete_policy(self) -> OnDemandPositives:
        """The positive policy backing complete-CT queries (lazy; shares
        the engine's cache and row accounting with any co-resident
        search)."""
        if self._policy is None:
            self._policy = OnDemandPositives(self.engine)
        return self._policy

    def _estimate_bytes(self, plan: ContractionPlan) -> int:
        itemsize = np.dtype(self.engine.dtype).itemsize
        return int(np.prod(plan.out_shape, dtype=np.int64)) * itemsize

    def discovery(self, **kwargs):
        """The model-discovery service running over this counting service
        (built lazily on first call, then shared — so every caller's
        searches hit one warm score memo).  Keyword arguments are
        forwarded to :class:`~repro.discover.service.DiscoveryService`
        on first construction and ignored afterwards.

        Usage::

            result = svc.discovery().discover()
        """
        if self._discovery is None:
            from ..discover import DiscoveryService
            self._discovery = DiscoveryService(self, tracer=self.tracer,
                                               **kwargs)
        return self._discovery

    def stats(self) -> dict:
        """Service + cache health snapshot (JSON-able; see
        :meth:`~repro.serve.metrics.ServiceMetrics.snapshot`).

        Usage::

            print(svc.stats()["qps"], svc.stats()["cache"]["hits"])
        """
        out = self.metrics.snapshot(self.engine.cache)
        out["tenant"] = self.tenant
        out["tracer"] = self.tracer.snapshot()
        if self._discovery is not None:
            out["discovery"] = self._discovery.stats()
        return out
