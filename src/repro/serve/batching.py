"""Bucketed plan execution: the bridge between the service scheduler and
the executors' stacked entry point.

The scheduler (:mod:`repro.serve.service`) thinks in *shape signatures*
(:meth:`~repro.core.plan.ContractionPlan.shape_signature` — its quota and
metrics unit); the executors stack on the stricter
:func:`~repro.core.executors.plan_stack_key` (same topology AND array
sizes).  :func:`execute_bucketed` sits between the two: it chops an
arbitrary mix of compiled plans into same-shape micro-batches of at most
``max_batch_size``, hands each to
:meth:`~repro.core.executors.Executor.positive_batch` (which re-groups by
stack key and vmaps what it can, loops what it can't), and reports each
micro-batch's latency to the service metrics.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence

from ..core.contract import CostStats
from ..core.ct import CtTable
from ..core.database import RelationalDB
from ..core.executors import Executor, plan_input_arrays, plan_stack_key
from ..core.plan import ContractionPlan, group_by_signature
from .metrics import ServiceMetrics

__all__ = ["execute_bucketed", "plan_input_arrays", "plan_stack_key"]


def execute_bucketed(executor: Executor, db: RelationalDB,
                     plans: Sequence[ContractionPlan],
                     stats: Optional[CostStats] = None,
                     max_batch_size: Optional[int] = None,
                     metrics: Optional[ServiceMetrics] = None
                     ) -> List[CtTable]:
    """Evaluate ``plans`` in shape-signature micro-batches.

    Results align positionally with ``plans`` and are numerically identical
    to per-plan :meth:`~repro.core.executors.Executor.positive` execution;
    only the dispatch granularity changes.

    Args:
        executor: the backend to evaluate with.
        db: the database the plans were compiled against.
        plans: compiled :class:`~repro.core.plan.ContractionPlan` list.
        stats: optional :class:`~repro.core.contract.CostStats` for
            join/row accounting.
        max_batch_size: cap per micro-batch (``None``/0 = one batch per
            signature bucket).
        metrics: optional :class:`~repro.serve.metrics.ServiceMetrics`
            that receives one ``observe_batch`` per micro-batch.

    Returns:
        One :class:`~repro.core.ct.CtTable` per plan, in input order.

    Usage::

        tabs = execute_bucketed(engine.executor, db, plans, engine.stats)
    """
    results: List[Optional[CtTable]] = [None] * len(plans)
    for sig, idxs in group_by_signature(plans, key="shape").items():
        step = max_batch_size if max_batch_size else len(idxs)
        for s in range(0, len(idxs), max(step, 1)):
            chunk = idxs[s:s + max(step, 1)]
            t0 = time.perf_counter()
            tabs = executor.positive_batch(db, [plans[i] for i in chunk],
                                           stats)
            dt = time.perf_counter() - t0
            if metrics is not None:
                metrics.observe_batch(sig, len(chunk), dt)
            for i, tab in zip(chunk, tabs):
                results[i] = tab
    return results
